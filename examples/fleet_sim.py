"""Fleet simulation tour, scenario-first: every cell below is a checked-in
declarative spec (``benchmarks/scenarios/*.json``) run through the one
``repro.core.scenario.run()`` entry point — the same specs the benchmark
suite and CI drive through ``python -m repro.experiments``.

The questions the multi-worker engine answers beyond the single-worker model:

  1. Degenerate check — 1 worker / 1 instance per function reproduces the
     paper's Fig. 7 numbers, including the ~88 % memory-saving headline
     (asserted against the legacy ``simulate()`` wrapper).
  2. Does image-affinity placement beat round-robin on a skewed workload?
     (one spec, ``sweep()`` over ``placement.name``)
  3. What does pool capacity pressure do to each method?
  4. How do keep-alive / pre-warm policies trade latency for residency?
     (``sweep()`` over ``prewarm.name`` — the PREWARM_POLICIES registry)
  5. What does an instance cap do to the tail? (queue-accurate P50/P95/P99)
  6. What does a cold start actually *cost* when it is priced page by page?
     (page-granular cost model + cluster-shared image cache — the
     ``bounded_cache`` spec vs the same spec with affinity placement)

    PYTHONPATH=src python examples/fleet_sim.py
"""
import os

from repro.core import CostModel, KeepAlivePolicy, PageCostModel, simulate
from repro.core.scenario import Scenario, run, sweep
from repro.core.traces import TRACE_GENERATORS, sharing_degrees

SCENARIOS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                         "scenarios")


def spec(name: str) -> Scenario:
    return Scenario.from_file(os.path.join(SCENARIOS, f"{name}.json"))


def main() -> None:
    cm = CostModel.paper_table2()

    # --- 1. degenerate point == the paper's simulation --------------------------
    res = run(spec("degenerate"))
    rw = res.methods["warmswap"]
    ref = simulate(res.traces, "warmswap", cm, KeepAlivePolicy(15.0))
    print(f"degenerate: scenario avg {rw.avg_latency_s * 1e3:.2f} ms "
          f"== simulate() {ref.avg_latency_s * 1e3:.2f} ms; "
          f"memory saving {res.summary['memory_saving_vs_prebaking'] * 100:.1f} % "
          f"(paper: 88 %)\n")
    assert abs(rw.total_latency_s - ref.total_latency_s) < 1e-6

    # --- a skewed 40-function fleet over 4 shared images ------------------------
    base = spec("fleet_base")
    n_fns = base.traces.kwargs["n_functions"]
    traces = TRACE_GENERATORS.build(base.traces.name, **base.traces.kwargs)
    print(f"fleet workload: {n_fns} fns, sharing degrees "
          f"{sharing_degrees(traces)}")

    # --- 2. placement policies under identical everything else ------------------
    # (the shipped spec runs all three methods for the bench suite; this tour
    # only reads warmswap, so don't simulate the other two)
    print("\nplacement (4 workers, pool capacity = 2 images each, warmswap):")
    for scn in sweep(spec("placement").with_overrides({"methods": ["warmswap"]}),
                     {"placement.name": ["affinity", "least_loaded",
                                         "round_robin"]}):
        mr = run(scn).methods["warmswap"]
        print(f"  {scn.placement.name:13s} avg {mr.avg_latency_s * 1e3:7.1f} ms | "
              f"cold {mr.n_cold:5d} | pool misses {mr.pool_misses:4d} | "
              f"evictions {mr.evictions:4d} | peak mem {mr.memory_bytes >> 20} MB")

    # --- 3. capacity pressure per method ----------------------------------------
    print("\npool capacity (4 workers, affinity):")
    for cap in (1, 2, None):
        r = run(base.with_overrides({"worker_capacity_bytes": (
            None if cap is None else cap * cm.image_bytes)}))
        row = [f"{m} {mr.avg_latency_s * 1e3:6.1f} ms/"
               f"{mr.memory_bytes >> 20:4d} MB"
               for m, mr in r.methods.items()]
        print(f"  {str(cap or 'unlimited'):>9s} images/worker: " + " | ".join(row))

    # --- 4. pre-warm policies ----------------------------------------------------
    print("\npre-warm policy (4 workers, warmswap): latency vs residency")
    for scn in sweep(spec("prewarm"),
                     {"prewarm.name": ["none", "histogram", "spes"]}):
        mr = run(scn).methods["warmswap"]
        print(f"  {scn.prewarm.name:9s} avg {mr.avg_latency_s * 1e3:7.1f} ms | "
              f"cold {mr.n_cold:5d} | warm-instance residency "
              f"{mr.instance_resident_min:9.0f} inst-min | "
              f"prewarm spawns/hits {mr.prewarm_spawns}/{mr.prewarm_hits}")
    peak = run(base.with_overrides(
        {"worker_capacity_bytes": None, "methods": ["warmswap"]}))
    print("\nconcurrency: arrivals overlapping a busy instance spawn new ones "
          "(peak concurrent instances of one function above: "
          f"{peak.methods['warmswap'].max_concurrent_instances})")

    # --- 5. queueing: instance caps make the tail visible ------------------------
    print("\ninstance cap (2 workers, warmswap): queue delay shows in the tail")
    for scn in sweep(spec("queueing"), {"max_instances_per_fn": [None, 2, 1]}):
        mr = run(scn).methods["warmswap"]
        p = mr.latency_percentiles_s
        print(f"  cap={str(scn.max_instances_per_fn):>4s} "
              f"avg {mr.avg_latency_s * 1e3:7.1f} ms | "
              f"P50 {p['p50'] * 1e3:6.1f} | P95 {p['p95'] * 1e3:7.1f} | "
              f"P99 {p['p99'] * 1e3:7.1f} ms | queued {mr.n_queued:4d} "
              f"({mr.queue_delay_s:.1f}s waiting)")

    # --- 6. page-granular cold starts + the cluster-shared image cache ----------
    model = PageCostModel(cost=cm)
    n_img = model.image_pages()
    print(f"\npage-granular cost model ({n_img} pages x "
          f"{model.page_size >> 20} MiB for the {cm.image_bytes >> 20} MB image):")
    for tier, label in (("local", "local pool hit (memcpy)"),
                        ("remote", "remote peer via shared cache (DCN)"),
                        ("miss", "source-store fetch (cache miss)")):
        lat = model.cold_latency_s("warmswap", tier=tier)
        print(f"  warmswap cold, {label:36s} {lat * 1e3:7.1f} ms")
    half = model.cold_latency_s("warmswap", tier="remote",
                                resident_pages=n_img // 2)
    print(f"  warmswap cold, remote + half-resident image   {half * 1e3:7.1f} ms"
          f"  (partial residency: only missing pages move)")
    print(f"  baseline  cold (full source fetch, no cache)  "
          f"{model.cold_latency_s('baseline') * 1e3:7.1f} ms | "
          f"dependency-loading speedup "
          f"{model.dependency_loading_speedup():.2f}x (paper band: 2.2-3.2x)")

    print("\ncluster-shared cache (4 workers, pool = 1 image each, shared tier"
          " = 2 images, round-robin to force cross-worker traffic):")
    r = run(spec("bounded_cache")).methods["warmswap"]
    print(f"  cold starts by tier: local {r.cache_hits['local']} | "
          f"remote {r.cache_hits['remote']} | source miss {r.cache_hits['miss']} | "
          f"cluster evictions {r.shared_cache_evictions}")
    print(f"  network page volume {r.pages_transferred} pages | avg latency "
          f"{r.avg_latency_s * 1e3:.1f} ms | shared-tier peak "
          f"{r.shared_cache_peak_bytes >> 20} MB")
    ra = run(spec("bounded_cache").with_overrides(
        {"placement.name": "affinity"})).methods["warmswap"]
    print(f"  ...with bandwidth-aware affinity placement instead: local "
          f"{ra.cache_hits['local']} | remote {ra.cache_hits['remote']} | miss "
          f"{ra.cache_hits['miss']} | {ra.pages_transferred} pages moved "
          f"({ra.avg_latency_s * 1e3:.1f} ms avg)")

    # --- 7. large sweeps: the parallel, resumable executor ----------------------
    # Grid points fan out over a process pool; each validated result streams
    # to an append-only JSONL store keyed by spec content hash, so a killed
    # sweep resumes by skipping finished points — and serial vs parallel
    # runs store byte-identical results (docs/API.md).
    import tempfile

    from repro.experiments.executor import run_sweep

    store = os.path.join(tempfile.mkdtemp(prefix="warmswap-sweep-"),
                         "sweep.jsonl")
    axes = {"traces.kwargs.seed": [0, 1]}
    report = run_sweep(spec("degenerate"), axes, smoke=True, parallel=2,
                       store_path=store)
    resumed = run_sweep(spec("degenerate"), axes, smoke=True,
                        store_path=store, resume=True)
    print(f"\nexecutor sweep ({len(report.points)} points, 2 processes) -> "
          f"{store}")
    for point, result in zip(report.points, report.results):
        ws = result["methods"]["warmswap"]
        print(f"  {point.name}: warmswap avg "
              f"{ws['avg_latency_s'] * 1e3:.2f} ms | cold {ws['n_cold']} | "
              f"saving {result['summary']['memory_saving_vs_prebaking']:.1%}")
    assert resumed.n_run == 0 and resumed.n_skipped == len(report.points)
    assert resumed.results == report.results
    print(f"  re-run with --resume: {resumed.n_skipped} stored points "
          f"skipped, 0 recomputed")


if __name__ == "__main__":
    main()
