"""Sharded execution on an 8-device host mesh (subprocess: device count must be set
before jax init). Verifies the production sharding rules don't just compile — they
RUN, and sharded results match single-device results."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.api import make_train_step, make_serve_step
from repro.models.sharding import param_pspecs, decode_state_pspecs, batch_pspecs
from repro.models.transformer import init_params, init_decode_state, forward
from repro.optim import adamw_init

arch = os.environ["TEST_ARCH"]
cfg = get_reduced(arch, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
opt = adamw_init(params)
data = DataConfig(global_batch=4, seq_len=16, seed=0)
batch = {k: jnp.asarray(v) for k, v in SyntheticTokenPipeline.batch_at(cfg, data, 0).items()}
step = make_train_step(cfg, remat="none", total_steps=10)

# single-device reference
p1, o1, m1 = jax.jit(step)(params, opt, batch, jnp.int32(0))

# sharded run
p_specs = param_pspecs(cfg, params, 4)
ns = lambda s: NamedSharding(mesh, s)
with mesh:
    params_s = jax.device_put(params, jax.tree.map(ns, p_specs))
    b_specs = batch_pspecs(cfg, batch, ("data",), 2)
    batch_s = jax.device_put(batch, {k: ns(v) for k, v in b_specs.items()})
    opt_s = jax.device_put(opt, jax.tree.map(lambda _: ns(P()), opt))
    p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s, jnp.int32(0))

err = abs(float(m1["loss"]) - float(m2["loss"]))
max_p_err = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(jax.device_get(p2))))

# sharded decode
state = init_decode_state(cfg, 4, 32, jnp.float32)
st_specs = decode_state_pspecs(cfg, state, ("data",), 2, 4, 4)
serve = make_serve_step(cfg)
with mesh:
    state_s = jax.device_put(state, jax.tree.map(ns, st_specs))
    tok = jax.device_put(jnp.zeros((4, 1), jnp.int32), ns(P("data", None)))
    nt1, st1 = jax.jit(serve)(params_s, state_s, tok)
nt_ref, _ = jax.jit(serve)(params, state, jnp.zeros((4, 1), jnp.int32))
decode_match = bool(jnp.array_equal(jax.device_get(nt1), jax.device_get(nt_ref)))

print(json.dumps({"loss_err": err, "max_p_err": max_p_err,
                  "decode_match": decode_match}))
"""


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "gemma2_27b", "falcon_mamba_7b",
                                  "moonshot_v1_16b_a3b"])
def test_sharded_train_and_decode_match_single_device(arch):
    env = dict(os.environ)
    env["TEST_ARCH"] = arch
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["loss_err"] < 1e-3, out
    assert out["max_p_err"] < 1e-3, out
    assert out["decode_match"], out
