"""Shared benchmark scaffolding: fleet setup, timing, CSV emission, and the
one validated-result path every simulation bench goes through."""
from __future__ import annotations

import json
import os
import statistics
import tempfile
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
SCENARIOS_DIR = os.path.join(os.path.dirname(__file__), "scenarios")


def scenario_path(name: str) -> str:
    """Path of a checked-in scenario spec (``benchmarks/scenarios/*.json``)."""
    return os.path.join(SCENARIOS_DIR, f"{name}.json")


def validated_samples(r, label: str):
    """NaN / negative per-request latencies are impossible under a correct
    queueing model — fail loudly rather than report them. ``r`` is an
    engine-native ``SimResult`` / ``FleetResult``; returns its samples."""
    import numpy as np

    s = np.asarray(r.latency_samples_s)
    if s.size and (not np.isfinite(s).all() or (s < 0).any()):
        raise RuntimeError(f"{label}: NaN or negative latency samples")
    if r.queue_delay_s < 0 or not np.isfinite(r.queue_delay_s):
        raise RuntimeError(f"{label}: invalid queue delay "
                           f"{r.queue_delay_s!r}")
    return s


def scenario_cell(result, label: str, prefix: str = "fleet") -> Dict:
    """One benchmark cell from a scenario ``Result``: per-method dict of the
    headline numbers (validated via :func:`validated_samples`), one CSV row
    emitted per method. Every simulation bench shares this path."""
    from repro.core.simulator import quartile_percentiles

    out: Dict = {}
    for method, raw in result.raw.items():
        validated_samples(raw, f"{prefix}/{label}/{method}")
        mr = result.methods[method]
        pct = mr.latency_percentiles_s
        out[method] = {
            "avg_latency_s": mr.avg_latency_s,
            "latency_percentiles_s": pct,
            "quartile_latency_s": mr.quartile_latency_s,
            "quartile_percentiles_s": quartile_percentiles(result.traces, raw),
            "peak_memory_mb": mr.memory_bytes / 1e6,
            "cold": mr.n_cold, "warm": mr.n_warm,
            "queued": mr.n_queued, "queue_delay_s": mr.queue_delay_s,
            "pool_misses": mr.pool_misses, "evictions": mr.evictions,
            "max_concurrent_instances": mr.max_concurrent_instances,
            "instance_resident_min": mr.instance_resident_min,
            "prewarm_dropped": mr.prewarm_dropped,
        }
        emit(f"{prefix}/{label}/{method}", mr.avg_latency_s * 1e6,
             f"p99={pct['p99'] * 1e3:.1f}ms mem={mr.memory_bytes / 1e6:.0f}MB "
             f"cold={mr.n_cold} queued={mr.n_queued} "
             f"miss={mr.pool_misses} evict={mr.evictions}")
    return out


def set_smoke(on: bool = True) -> None:
    """Switch the whole bench suite to smoke (CI) scale. This is the ONE
    place smoke scale is decided: the driver's ``--smoke`` flag and CI both
    route through it (and through a spec's own ``smoke_overrides``), and
    benches size their sweep axes with :func:`pick` — nothing re-derives
    smoke overrides on its own."""
    os.environ["REPRO_SMOKE"] = "1" if on else "0"


def smoke_mode() -> bool:
    """True when the driver was invoked with ``--smoke`` (CI-sized runs)."""
    return os.environ.get("REPRO_SMOKE") == "1"


def pick(full, smoke):
    """The full-scale or smoke-scale variant of a bench knob (sweep axis
    lists, sizes), chosen by :func:`smoke_mode` — so every bench scales
    through the same switch instead of re-deriving it."""
    return smoke if smoke_mode() else full


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The assignment's CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def median(xs: List[float]) -> float:
    return statistics.median(xs) if xs else 0.0


_STACK = None


def build_fleet(functions: Optional[List[str]] = None, link=None):
    """One shared provider stack for all cold-start benchmarks (images built once,
    exactly like a provider would)."""
    global _STACK
    from repro.core import (ColdStartConfig, ColdStartOrchestrator,
                            DependencyManager, FunctionRegistry)
    from repro.core import workloads as wl

    if _STACK is not None:
        return _STACK
    functions = functions or list(wl.WORKLOADS)
    tmp = tempfile.mkdtemp(prefix="warmswap-bench-")
    mgr = DependencyManager(disk_dir=os.path.join(tmp, "pool"),
                            link=link or __import__(
                                "repro.core.migration", fromlist=["LinkModel"]
                            ).LinkModel())
    reg = FunctionRegistry(store_dir=os.path.join(tmp, "store"))
    mgr.register_image("py-base", "py-base", wl.py_base_builder)
    needed_images = {wl.WORKLOADS[f].image_id for f in functions}
    for img_id in sorted(needed_images - {"py-base"}):
        builder = wl.model_params_builder(img_id)
        execs = wl.make_model_executables(img_id)
        wl.warm_executables(execs, builder(), img_id)
        mgr.register_image(img_id, img_id, builder, executables=execs)
    for fn in functions:
        w = wl.WORKLOADS[fn]
        bb = (wl.model_params_builder(w.image_id)
              if w.image_id in wl.IMAGE_CONFIGS else wl.py_base_builder)
        reg.register(fn, w.image_id, w.handler_builder, w.handler_fn,
                     base_params_builder=bb, write_baseline_checkpoint=True)
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig())
    _STACK = (mgr, reg, orch)
    return _STACK
