"""Property tests for the simulation core's load-bearing invariants:

  * the event heap (core/events.py) is a TOTAL order over (time, kind,
    insertion seq) — ties at one timestamp resolve by kind rank, and within
    one (time, kind) bucket strictly FIFO;
  * per-function service starts are monotone under cap=1 (busy_until only
    moves forward — the Lindley recursion);
  * queue delays are never negative and every latency sample is wait +
    service, in BOTH fleet engines.

Runs under real `hypothesis` when installed (one CI tier-1 leg installs it);
otherwise tests/conftest.py substitutes the deterministic seeded-fuzz shim
(tests/_hypothesis_fallback.py) with the same API surface.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.events import Event, EventKind, EventQueue
from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import simulate_fleet_vec
from repro.core.simulator import CostModel
from repro.core.traces import generate_fleet_traces

CM = CostModel.paper_table2()

#: Few distinct timestamps on purpose: ties are the interesting case.
_TIMES = st.sampled_from([0.0, 0.5, 1.0, 1.0 + 2**-40, 2.0, 7.25])
_KINDS = st.sampled_from([EventKind.INSTANCE_FREE, EventKind.PREWARM_SPAWN,
                          EventKind.ARRIVAL, EventKind.KEEPALIVE_EXPIRY])


@st.composite
def _event_batches(draw):
    n = draw(st.integers(0, 40))
    return [(draw(_TIMES), draw(_KINDS)) for _ in range(n)]


@st.composite
def _fleet_cases(draw):
    return {
        "n_functions": draw(st.integers(1, 8)),
        "n_images": draw(st.integers(1, 3)),
        "horizon_min": draw(st.sampled_from([60.0, 240.0, 720.0])),
        "total_rate_per_min": draw(st.floats(0.5, 20.0)),
        "seed": draw(st.integers(0, 10_000)),
        "method": draw(st.sampled_from(["warmswap", "prebaking", "baseline"])),
        "cap": draw(st.sampled_from([None, 1, 2])),
        "keep_alive_min": draw(st.floats(0.5, 20.0)),
    }


def _run_case(case, impl):
    traces = generate_fleet_traces(
        n_functions=case["n_functions"], horizon_min=case["horizon_min"],
        seed=case["seed"], n_images=case["n_images"], rate_model="zipf",
        total_rate_per_min=case["total_rate_per_min"])
    fc = FleetConfig(n_workers=1, max_instances_per_fn=case["cap"],
                     keep_alive_min=case["keep_alive_min"])
    return traces, impl(traces, case["method"], CM, fc)


# ---------------------------------------------------------------------------------
# Event heap: total order
# ---------------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(_event_batches())
def test_event_heap_total_order(batch):
    """Pops come out sorted by (time, kind) with strict FIFO inside each
    (time, kind) bucket — the payload tags recover insertion order."""
    q = EventQueue()
    for i, (t, k) in enumerate(batch):
        q.push(t, k, payload=i)
    assert len(q) == len(batch)
    popped = []
    while q:
        assert q.peek_key() == (q.heap[0][0], q.heap[0][1])
        t, k, _, tag = q.pop_raw()
        popped.append((t, k, tag))
    keys = [(t, k) for t, k, _ in popped]
    assert keys == sorted(keys), "heap violated (time, kind) order"
    for (t1, k1, g1), (t2, k2, g2) in zip(popped, popped[1:]):
        if (t1, k1) == (t2, k2):
            assert g1 < g2, "FIFO broken within a (time, kind) bucket"
    assert sorted(g for _, _, g in popped) == list(range(len(batch)))


@settings(max_examples=20, deadline=None)
@given(_TIMES, _KINDS)
def test_event_pop_wraps_typed_view(t, k):
    q = EventQueue()
    q.push(t, k, payload="p")
    ev = q.pop()
    assert ev == Event(t, EventKind(k), "p")
    assert isinstance(ev.kind, EventKind)


def test_event_kind_ranks_are_the_documented_tiebreak():
    """The rank values ARE the semantics; renumbering them silently reorders
    same-instant events (free before spawn before arrival before expiry)."""
    assert (EventKind.INSTANCE_FREE < EventKind.PREWARM_SPAWN
            < EventKind.ARRIVAL < EventKind.KEEPALIVE_EXPIRY)
    assert [EventKind.INSTANCE_FREE, EventKind.PREWARM_SPAWN,
            EventKind.ARRIVAL, EventKind.KEEPALIVE_EXPIRY] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------------
# Engine invariants: Lindley waits, service-start monotonicity
# ---------------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(_fleet_cases())
def test_queue_delays_never_negative_both_engines(case):
    for impl in (_simulate_fleet_impl, simulate_fleet_vec):
        _, r = _run_case(case, impl)
        assert (r.queue_wait_s >= 0.0).all(), impl.__name__
        assert (r.latency_samples_s >= r.queue_wait_s).all(), impl.__name__
        assert not np.isnan(r.latency_samples_s).any(), impl.__name__
        assert r.n_queued == int((r.queue_wait_s > 0).sum()), impl.__name__
        assert r.total_latency_s == float(r.latency_samples_s.sum())
        assert r.queue_delay_s == float(r.queue_wait_s.sum())
        # every sample decomposes as wait + one of the method's two service
        # times (warm or cold — no page model in these cases), up to the
        # float error of reconstructing svc = sample - wait
        svc = r.latency_samples_s - r.queue_wait_s
        assert (svc > 0.0).all(), impl.__name__
        assert len(np.unique(np.round(svc, 6))) <= 2, impl.__name__


@settings(max_examples=25, deadline=None)
@given(_fleet_cases())
def test_service_starts_monotone_per_fn_cap1(case):
    """busy_until only moves forward: with a single worker and cap=1, each
    function's instance serves FIFO, so reconstructed service starts
    (arrival + wait) are nondecreasing per function — in both engines."""
    case = dict(case, cap=1)
    for impl in (_simulate_fleet_impl, simulate_fleet_vec):
        traces, r = _run_case(case, impl)
        all_t = np.concatenate([t.arrivals_min for t in traces]) \
            if traces else np.empty(0)
        all_fn = np.concatenate(
            [np.full(len(t.arrivals_min), t.fn_index) for t in traces]) \
            if traces else np.empty(0, np.int64)
        order = np.argsort(all_t, kind="stable")
        t_sorted, fn_sorted = all_t[order], all_fn[order]
        assert np.array_equal(fn_sorted, r.sample_fn)
        starts = t_sorted + r.queue_wait_s / 60.0
        for fn in np.unique(fn_sorted):
            s = starts[fn_sorted == fn]
            assert (np.diff(s) >= -1e-9).all(), \
                f"{impl.__name__}: fn {fn} service starts went backwards"
