"""repro-lint spec/registry cross-validator: a stale scenario fixture (renamed
component, extra kwarg, missing required arg) is caught without running a
simulation, and every checked-in benchmarks/scenarios spec stays clean."""
import glob
import json
import os

from tools.analysis import specs
from tools.analysis.base import REPO_ROOT


def valid_spec():
    return {
        "name": "fixture",
        "schema_version": 1,
        "engine": "fleet",
        "methods": ["warmswap"],
        "traces": {"name": "fleet",
                   "kwargs": {"n_functions": 4, "horizon_min": 60.0,
                              "seed": 0}},
        "cost": {"name": "paper_table2", "kwargs": {}},
        "prewarm": {"name": "none", "kwargs": {}},
        "placement": {"name": "affinity", "kwargs": {}},
    }


def rules(findings):
    return sorted(f.rule for f in findings)


def test_valid_spec_clean():
    assert specs.check_spec(valid_spec(), "x.json") == []


def test_renamed_component_unknown_with_did_you_mean():
    spec = valid_spec()
    spec["traces"]["name"] = "fleet_traces"      # renamed out from under us
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["unknown-component"]
    assert "'fleet'" in found[0].message         # did-you-mean
    assert found[0].scope == "traces.fleet_traces"


def test_extra_kwarg_unknown_with_did_you_mean():
    spec = valid_spec()
    spec["prewarm"] = {"name": "none",
                       "kwargs": {"keep_alive_mins": 15.0}}   # typo'd kwarg
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["unknown-kwarg"]
    assert "keep_alive_min" in found[0].message  # did-you-mean

def test_missing_required_arg():
    spec = valid_spec()
    del spec["traces"]["kwargs"]["n_functions"]
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["missing-required-arg"]
    assert "'n_functions'" in found[0].message


def test_runtime_injected_kwargs_not_required():
    # page_cost factories take the resolved CostModel as 'cost' — injected by
    # run(), so the spec must NOT be asked to provide it
    spec = valid_spec()
    spec["page_cost"] = {"name": "degenerate", "kwargs": {}}
    assert specs.check_spec(spec, "x.json") == []


def test_malformed_component_shape_invalid_spec():
    spec = valid_spec()
    spec["cost"] = {"nm": "paper_table2"}
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["invalid-spec"]


def test_string_component_form_accepted():
    spec = valid_spec()
    spec["cost"] = "paper_table2"
    assert specs.check_spec(spec, "x.json") == []


def test_non_scenario_json_passes_through(tmp_path):
    p = tmp_path / "artifact.json"
    p.write_text(json.dumps({"headline": {"speedup": 2.7}}))
    assert specs.check_file(str(p)) == []


def test_unreadable_json_invalid_spec(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    assert rules(specs.check_file(str(p))) == ["invalid-spec"]


def test_stale_spec_fixture_file_roundtrip(tmp_path):
    """One file carrying all three rot shapes at once (the checker keeps
    going past the first bad component)."""
    spec = valid_spec()
    spec["traces"]["name"] = "fleet_traces"
    spec["prewarm"] = {"name": "none", "kwargs": {"keep_alive_mins": 1.0}}
    spec["placement"] = {"name": "affinty", "kwargs": {}}
    p = tmp_path / "stale.json"
    p.write_text(json.dumps(spec))
    found = specs.check_file(str(p))
    assert rules(found) == ["unknown-component", "unknown-component",
                            "unknown-kwarg"]


def test_adversarial_generators_validate_clean():
    """The four adversarial generators' full kwarg surfaces cross-validate
    against the live registry signatures."""
    cases = {
        "diurnal": {"n_functions": 8, "horizon_min": 120.0, "seed": 1,
                    "amplitude": 0.5, "peak_min": 840.0, "stream": True,
                    "block_min": 60.0, "chunk_min": 120.0},
        "bursts": {"n_functions": 8, "horizon_min": 120.0, "seed": 1,
                   "n_bursts": 2, "burst_multiplier": 10.0, "retries": 1},
        "tenant_mix": {"n_tenants": 2, "fns_per_tenant": 4,
                       "horizon_min": 120.0, "seed": 1,
                       "noisy_multiplier": 2.0},
        "rollout": {"n_functions": 6, "horizon_min": 240.0, "seed": 1,
                    "n_rollouts": 1, "rollout_stagger_min": 30.0},
    }
    for name, kwargs in cases.items():
        spec = valid_spec()
        spec["traces"] = {"name": name, "kwargs": kwargs}
        assert specs.check_spec(spec, "x.json") == [], name


def test_adversarial_generator_stale_kwarg_caught():
    spec = valid_spec()
    spec["traces"] = {"name": "diurnal",
                      "kwargs": {"n_functions": 8, "horizon_min": 120.0,
                                 "amplitud": 0.5}}          # typo'd kwarg
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["unknown-kwarg"]
    assert "amplitude" in found[0].message       # did-you-mean


def test_stream_with_disruption_flagged():
    spec = valid_spec()
    spec["traces"]["kwargs"]["stream"] = True
    spec["traces"]["name"] = "diurnal"
    spec["disruption"] = {"name": "churn", "kwargs": {}}
    found = specs.check_spec(spec, "x.json")
    assert "stream-with-disruption" in rules(found)


def test_stream_with_single_engine_flagged():
    spec = valid_spec()
    spec["engine"] = "single"
    del spec["placement"]                        # single engine: no placement
    spec["traces"]["kwargs"]["stream"] = True
    spec["traces"]["name"] = "bursts"
    found = specs.check_spec(spec, "x.json")
    assert "stream-with-single-engine" in rules(found)


def test_stream_false_not_flagged():
    spec = valid_spec()
    spec["traces"]["name"] = "diurnal"
    spec["traces"]["kwargs"]["stream"] = False
    spec["disruption"] = {"name": "churn", "kwargs": {}}
    assert specs.check_spec(spec, "x.json") == []


def test_all_checked_in_scenarios_clean():
    paths = sorted(glob.glob(
        os.path.join(REPO_ROOT, "benchmarks", "scenarios", "*.json")))
    assert paths, "no checked-in scenario specs found"
    for p in paths:
        assert specs.check_file(p) == [], p
