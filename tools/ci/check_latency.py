#!/usr/bin/env python
"""Latency sanity over the fleet bench artifact: every latency-shaped number
must be finite and non-negative (a NaN or negative latency means the queueing
model broke). Runs locally and in CI's smoke job.

    python tools/ci/check_latency.py [results/bench_fleet.json]
"""
import json
import math
import sys


def walk(node, path=""):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from walk(v, f"{path}/{k}")
    elif isinstance(node, (int, float)):
        yield path, node


def main(path="results/bench_fleet.json"):
    data = json.load(open(path))
    bad = [(p, v) for p, v in walk(data)
           if ("latency" in p or "queue_delay" in p or p.rsplit("/", 1)[-1]
               in ("p50", "p95", "p99", "mean", "max"))
           and (not math.isfinite(v) or v < 0)]
    if bad:
        print("NaN/negative latency values:", bad[:20])
        return 1
    pcts = [v for p, v in walk(data) if p.endswith("/p99")]
    print(f"ok: {len(pcts)} p99 values in {path}, all finite and non-negative")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
