"""Shared infrastructure for repro-lint checkers: parsed source files,
suppression pragmas, scope (qualname) resolution, and file collection.

Pragma grammar (full catalog in docs/ANALYSIS.md):

* ``# repro-lint: allow[rule-a,rule-b]`` — suppress those rules on this
  physical line and the next (so a standalone comment line sanctions the
  statement below it);
* ``# repro-lint: allow-file[rule-a]`` — suppress a rule file-wide;
* ``# guarded-by: <lockattr>`` / ``# requires-lock: <lockattr>`` — the
  lock-discipline annotations, parsed by ``tools/analysis/locks.py``.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.analysis.findings import Finding

#: Repo root = the directory holding ``tools/`` (fingerprints are relative
#: to it, so runs from any cwd produce identical baselines).
REPO_ROOT = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))

_PRAGMA = re.compile(r"#\s*repro-lint:\s*(allow|allow-file)\[([^\]]+)\]")


def rel_path(path: str) -> str:
    """``path`` relative to the repo root, posix separators."""
    return os.path.relpath(os.path.abspath(path),
                           REPO_ROOT).replace(os.sep, "/")


@dataclass
class SourceFile:
    """One parsed Python source file plus its suppression pragmas."""
    path: str                      # absolute
    rel: str                       # repo-relative (fingerprint key)
    text: str
    lines: List[str]               # 1-indexed via line(n)
    tree: ast.Module
    allow: Dict[int, Set[str]] = field(default_factory=dict)
    allow_file: Set[str] = field(default_factory=set)

    @classmethod
    def parse(cls, path: str) -> "SourceFile":
        with open(path) as f:
            text = f.read()
        lines = text.splitlines()
        tree = ast.parse(text, filename=path)
        allow: Dict[int, Set[str]] = {}
        allow_file: Set[str] = set()
        for i, raw in enumerate(lines, start=1):
            for kind, rules in _PRAGMA.findall(raw):
                names = {r.strip() for r in rules.split(",") if r.strip()}
                if kind == "allow-file":
                    allow_file |= names
                else:
                    # a pragma covers its own line and the one below, so a
                    # standalone comment can sanction the next statement
                    allow.setdefault(i, set()).update(names)
                    allow.setdefault(i + 1, set()).update(names)
        return cls(path=path, rel=rel_path(path), text=text, lines=lines,
                   tree=tree, allow=allow, allow_file=allow_file)

    def line(self, n: int) -> str:
        """The 1-indexed physical source line (empty when out of range)."""
        return self.lines[n - 1] if 1 <= n <= len(self.lines) else ""

    def allowed(self, lineno: int, rule: str) -> bool:
        if rule in self.allow_file:
            return True
        return rule in self.allow.get(lineno, ())

    def finding(self, checker: str, rule: str, node: ast.AST, message: str,
                scope: str = "", suggestion: str = "") -> Optional[Finding]:
        """A :class:`Finding` at ``node`` — or ``None`` when a pragma on the
        node's line (or the line above) suppresses the rule."""
        lineno = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        if self.allowed(lineno, rule):
            return None
        return Finding(checker=checker, rule=rule, path=self.rel,
                       line=lineno, col=col, message=message, scope=scope,
                       snippet=self.line(lineno).strip(),
                       suggestion=suggestion)


# -------------------------------------------------------------- scope walking

def qualname_index(tree: ast.Module) -> Dict[ast.AST, str]:
    """node -> dotted qualname of the innermost enclosing class/function
    (``""`` at module level), for every node in ``tree``."""
    index: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, scope: str) -> None:
        for child in ast.iter_child_nodes(node):
            child_scope = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                child_scope = f"{scope}.{child.name}" if scope else child.name
            index[child] = child_scope
            walk(child, child_scope)

    index[tree] = ""
    walk(tree, "")
    return index


def enclosing_function_name(index: Dict[ast.AST, str], node: ast.AST) -> str:
    """Last component of the node's scope qualname (``""`` at module level).
    Used to match config-sanctioned entry points by function name."""
    scope = index.get(node, "")
    return scope.rsplit(".", 1)[-1] if scope else ""


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else ``None``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ------------------------------------------------------------ file collection

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules",
              ".hypothesis", "results"}


def collect_files(paths: Iterable[str]) -> Tuple[List[str], List[str]]:
    """Expand CLI ``paths`` (files or directories) into sorted
    ``(python_files, json_files)`` absolute-path lists."""
    py: Set[str] = set()
    js: Set[str] = set()
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            (py if p.endswith(".py") else
             js if p.endswith(".json") else set()).add(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for name in files:
                if name.endswith(".py"):
                    py.add(os.path.join(root, name))
                elif name.endswith(".json"):
                    js.add(os.path.join(root, name))
    return sorted(py), sorted(js)
