"""PartitionSpec rules for every parameter / batch / decode-state tensor.

Policy (DESIGN.md §5):
  * `model` axis = tensor parallelism: attention heads, FFN hidden, expert axis
    (true EP when n_experts % tp == 0, else TP inside the expert), vocab.
  * `data` (+ `pod`) axes = data parallelism over the batch; when the batch cannot
    cover them (long_500k, batch=1) the KV-cache *sequence* dimension is sharded over
    `data` instead (sequence parallelism for decode).
  * Archs whose head counts don't divide the model axis (whisper 12H, internvl2 14H,
    granite 24H, recurrentgemma 10H/MQA) replicate attention projections and shard
    FFN + vocab — recorded per-arch by :func:`arch_sharding_caps`.

All rules are path-based over the pytrees produced by ``init_params`` /
``init_decode_state``, so they apply equally to real arrays and ShapeDtypeStructs
(the dry-run path).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (dp_axes, model_axis)."""
    names = mesh.axis_names
    assert names[-1] == "model", f"mesh must end with 'model', got {names}"
    return tuple(names[:-1]), "model"


def arch_sharding_caps(cfg: ArchConfig, tp: int) -> Dict[str, bool]:
    return {
        "shard_q": cfg.n_heads % tp == 0,
        "shard_kv": cfg.n_kv_heads % tp == 0,
        "shard_ff": (cfg.d_ff % tp == 0) and cfg.d_ff > 0,
        "shard_experts": cfg.n_experts > 0 and cfg.n_experts_padded % tp == 0,
        "shard_expert_ff": cfg.n_experts > 0 and cfg.d_ff % tp == 0,
        "shard_inner": (cfg.d_inner % tp == 0),
        "shard_lru": (cfg.resolved_lru_width % tp == 0),
    }


def _param_rule(name: str, caps: Dict[str, bool], cfg: ArchConfig) -> P:
    m = "model"
    # embeddings
    if name == "tok":
        return P(m, None)
    if name == "head":
        return P(None, m)
    # attention
    if name == "wq":
        return P(None, m) if caps["shard_q"] else P(None, None)
    if name in ("wk", "wv"):
        return P(None, m) if caps["shard_kv"] else P(None, None)
    if name == "wo":
        return P(m, None) if caps["shard_q"] else P(None, None)
    if name == "bq":
        return P(m) if caps["shard_q"] else P(None)
    if name in ("bk", "bv"):
        return P(m) if caps["shard_kv"] else P(None)
    if name in ("q_norm", "k_norm"):
        return P(None)
    # dense MLP
    if name in ("w_gate", "w_in"):
        if cfg.n_experts > 0:  # expert tensors (E, D, F)
            if caps["shard_experts"]:
                return P(m, None, None)
            return P(None, None, m) if caps["shard_expert_ff"] else P(None, None, None)
        return P(None, m) if caps["shard_ff"] else P(None, None)
    if name == "w_out":
        if cfg.n_experts > 0:  # (E, F, D)
            if caps["shard_experts"]:
                return P(m, None, None)
            return P(None, m, None) if caps["shard_expert_ff"] else P(None, None, None)
        return P(m, None) if caps["shard_ff"] else P(None, None)
    if name == "router":
        return P(None, None)
    # mamba
    if name == "in_proj":
        return P(None, m) if caps["shard_inner"] else P(None, None)
    if name in ("conv_w",):
        return P(m, None) if caps["shard_inner"] else P(None, None)
    if name in ("conv_b", "dt_bias", "D"):
        return P(m) if caps["shard_inner"] else P(None)
    if name == "x_proj":
        return P(m, None) if caps["shard_inner"] else P(None, None)
    if name == "dt_proj":
        return P(None, m) if caps["shard_inner"] else P(None, None)
    if name == "A_log":
        return P(m, None) if caps["shard_inner"] else P(None, None)
    if name == "out_proj":
        sharded = caps["shard_inner"] if cfg.d_ff == 0 else caps["shard_lru"]
        return P(m, None) if sharded else P(None, None)
    # rg-lru
    if name in ("linear_x", "linear_y", "w_a", "w_x"):
        return P(None, m) if caps["shard_lru"] else P(None, None)
    if name in ("b_a", "b_x", "lambda"):
        return P(m) if caps["shard_lru"] else P(None)
    # norms / scalars
    if name in ("scale",):
        return P(None)
    return P()  # default: replicate


def _leaf_name(path) -> Tuple[str, bool]:
    """(final dict key, is_stacked) — stacked = inside 'unit'/'enc' (leading units dim)."""
    keys = []
    stacked = False
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            keys.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            keys.append(p.name)
    if keys and keys[0] in ("unit", "enc"):
        stacked = True
    name = keys[-1] if keys else ""
    return name, stacked


def param_pspecs(cfg: ArchConfig, params: Any, tp: int):
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs)."""
    caps = arch_sharding_caps(cfg, tp)

    def rule(path, leaf):
        name, stacked = _leaf_name(path)
        # conv weights are shared-name between ssm and rglru; pick caps accordingly
        if name in ("conv_w", "conv_b") and cfg.resolved_lru_width and cfg.d_ff > 0 \
                and "rec" in jax.tree_util.keystr(path):
            spec = (P("model", None) if caps["shard_lru"] else P(None, None)) \
                if name == "conv_w" else (P("model") if caps["shard_lru"] else P(None))
        else:
            spec = _param_rule(name, caps, cfg)
        if len(spec) > leaf.ndim:
            spec = P(*spec[: leaf.ndim])
        if stacked:
            spec = P(None, *spec)
            if len(spec) > leaf.ndim:
                spec = P(*spec[: leaf.ndim])
        if len(spec) < leaf.ndim:
            spec = P(*spec, *([None] * (leaf.ndim - len(spec))))
        return spec

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_pspecs(cfg: ArchConfig, opt_state: Any, params_specs: Any):
    return {
        "mu": params_specs,
        "nu": params_specs,
        "count": P(),
    }


def batch_pspecs(cfg: ArchConfig, batch: Dict[str, Any], dp_axes: Tuple[str, ...],
                 dp_size: int):
    """Shard the batch over DP axes (replicate if batch doesn't cover them)."""
    specs = {}
    for k, v in batch.items():
        bdim = dp_axes if v.shape[0] % dp_size == 0 and v.shape[0] >= dp_size else None
        specs[k] = P(bdim, *([None] * (v.ndim - 1)))
    return specs


def decode_state_pspecs(cfg: ArchConfig, state: Any, dp_axes: Tuple[str, ...],
                        dp_size: int, tp: int, batch: int):
    """KV caches: batch over DP when possible, else sequence over 'data' (SP);
    kv-heads over model when divisible. Recurrent states: width over model."""
    caps = arch_sharding_caps(cfg, tp)
    batch_covers = batch % dp_size == 0 and batch >= dp_size
    kv_axis = "model" if caps["shard_kv"] else None
    # Cache sequence-dim sharding (perf iteration A, EXPERIMENTS.md §Perf):
    #  * batch doesn't cover DP (long_500k): seq takes the DP 'data' axis (SP);
    #  * kv heads don't divide the model axis: seq takes 'model' — otherwise the
    #    cache would be REPLICATED tp-ways and every decode step all-gathers it.
    #    Decode attention reduces over seq, so a seq-sharded cache costs only small
    #    logsumexp all-reduces (the explicit max/exp/sum form in attention.py).
    import os
    baseline = os.environ.get("REPRO_PERF_BASELINE", "") == "1"
    seq_parts = []
    if not batch_covers:
        seq_parts.append("data" if "data" in dp_axes else dp_axes[-1])
    if not caps["shard_kv"] and not baseline:
        seq_parts.append("model")
    seq_axis = tuple(seq_parts) if seq_parts else None

    def rule(path, leaf):
        kp = jax.tree_util.keystr(path)
        name, _ = _leaf_name(path)
        lead = (None,) if (kp.startswith("['unit']") or "cross" in kp) else ()
        if name == "pos" or leaf.ndim == 0:
            bspec = dp_axes if (leaf.ndim == 1 and batch_covers) else None
            return P(*([bspec] * leaf.ndim))
        if leaf.dtype == jax.numpy.int32:                      # k_pos (B,C) [+lead]
            bspec = dp_axes if batch_covers else None
            dims = lead + (bspec, seq_axis)
            return P(*dims[-leaf.ndim:]) if leaf.ndim <= len(dims) else \
                P(*dims, *([None] * (leaf.ndim - len(dims))))
        # whisper cross-attention KV keeps (B, Senc, Hkv, hd) layout; Senc=1500 and
        # Hkv=12 don't divide the model axis -> batch sharding only (it's small)
        if "cross" in kp:
            bspec = dp_axes if batch_covers else None
            dims = lead + (bspec,) + (None,) * (leaf.ndim - len(lead) - 1)
            return P(*dims[: leaf.ndim])
        # KVCache k/v: (B, Hkv, C, hd) [+unit lead]
        if leaf.ndim - len(lead) == 4:
            bspec = dp_axes if batch_covers else None
            return P(*lead, bspec, kv_axis, seq_axis, None)
        bspec = dp_axes if batch_covers else None
        # ssm h: (B, di, N) [+lead] — keyed by field name, not dtype
        if name == "h" and leaf.ndim - len(lead) == 3:
            inner = "model" if caps["shard_inner"] else None
            return P(*lead, bspec, inner, None)
        # conv tail states (B, w-1, C) [+lead]
        if name == "conv" and leaf.ndim - len(lead) == 3:
            ch = "model" if (caps["shard_inner"] or caps["shard_lru"]) else None
            return P(*lead, bspec, None, ch)
        # rglru h (B, W) [+lead]
        if leaf.ndim - len(lead) == 2:
            ch = "model" if caps["shard_lru"] else None
            return P(*lead, bspec, ch)
        if leaf.ndim - len(lead) == 3:
            return P(*lead, bspec, None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, state)


def to_shardings(mesh: Mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        pspecs, is_leaf=lambda x: isinstance(x, P))
