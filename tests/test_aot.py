"""AOT executable serialization: the image's compile cache survives the disk tier
(paper §3.2 — revive without re-running initialization OR recompiling)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aot import (
    deserialize_executables,
    executables_nbytes,
    serialize_executables,
)


def test_executable_roundtrip_no_recompile():
    @jax.jit
    def step(w, x):
        return jnp.tanh(x @ w).sum(axis=-1)

    w = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    expected = step(w, x)

    blobs = serialize_executables({"step": step}, {"step": (w, x)})
    assert executables_nbytes(blobs) > 0
    execs = deserialize_executables(blobs)
    out = execs["step"](w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-6)


def test_serialized_blob_is_portable_bytes():
    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(8.0)
    blobs = serialize_executables({"f": f}, {"f": (x,)})
    assert isinstance(blobs["f"], bytes)
    # survives a (de)serialization through raw bytes (e.g. disk/network)
    execs = deserialize_executables({"f": bytes(blobs["f"])})
    np.testing.assert_allclose(np.asarray(execs["f"](x)), np.asarray(f(x)))
