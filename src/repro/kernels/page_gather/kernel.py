"""Pallas TPU paged-gather: assemble contiguous weights from pooled pages.

The device-side hot path of WarmSwap restore: the dependency pool keeps parameter
pages in a big HBM buffer shared by all tenants; instance bring-up gathers each
tenant's page list into its contiguous parameter buffers. This is pure data movement,
so the kernel is shaped around the DMA engine: grid ``(K,)`` over destination pages,
with the *scalar-prefetched* page-id list driving the input index map — the DMA for
page i+1 issues while page i copies (double buffering), sustaining HBM bandwidth.

Scalar prefetch (``pltpu.PrefetchScalarGridSpec``) is exactly the TPU idiom for this
"pointer-chase then stream" pattern (same as paged attention's block tables).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _gather_kernel(page_ids_ref, pool_ref, out_ref):
    # pool_ref block was selected by the index map via the prefetched page id;
    # the body is a VMEM->VMEM copy.
    out_ref[...] = pool_ref[...]


def page_gather_pallas(
    pool: jax.Array,         # (P, E)
    page_ids: jax.Array,     # (K,) int32
    *,
    interpret: bool = False,
) -> jax.Array:
    P, E = pool.shape
    K = page_ids.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, E), lambda i, ids: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, E), lambda i, ids: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, E), pool.dtype),
        interpret=interpret,
    )(page_ids, pool)
