"""End-to-end system behaviour tests for the paper's technique.

Scenario: a provider runs a multi-tenant serving fleet. Ten endpoints share one base
model. The provider pre-warms ONE dependency image; every endpoint cold-starts by
live migration; results are correct, warm starts are unaffected, pool memory is
O(images); the Prebaking alternative costs O(functions) memory for comparable speed.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ColdStartConfig,
    ColdStartOrchestrator,
    DependencyManager,
    FunctionRegistry,
    LinkModel,
    RestorePolicy,
)
from repro.core import workloads as wl


@pytest.fixture(scope="module")
def fleet():
    tmp = tempfile.mkdtemp()
    mgr = DependencyManager(disk_dir=tmp + "/pool")
    reg = FunctionRegistry(store_dir=tmp + "/store")
    builder = wl.model_params_builder("model-tiny")
    execs = wl.make_model_executables("model-tiny")
    wl.warm_executables(execs, builder(), "model-tiny")
    mgr.register_image("model-tiny", "model-tiny", builder, executables=execs)
    # ten tenants sharing the image, each with its own private head
    w = wl.WORKLOADS["lr_serving"]
    for i in range(10):
        reg.register(f"tenant-{i}", "model-tiny",
                     wl._head_builder("model-tiny", seed=i), w.handler_fn,
                     base_params_builder=builder,
                     write_baseline_checkpoint=(i == 0))
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig())
    return mgr, reg, orch


def test_ten_tenants_share_one_image(fleet):
    mgr, reg, orch = fleet
    size_before = mgr.pool_bytes()
    instances = []
    for i in range(10):
        inst, t = orch.cold_start_warmswap(f"tenant-{i}")
        instances.append(inst)
        assert t.dependency_init == 0.0            # no from-scratch initialization
    assert mgr.pool_bytes() == size_before          # O(#images) memory
    assert mgr.stats.builds == 1                    # initialization ran exactly once
    # tenants are isolated: same base, different heads, different outputs
    req = wl.WORKLOADS["lr_serving"].request_builder()
    outs = [tuple(np.asarray(inst.invoke(req)[0]).tolist()) for inst in instances]
    assert len(set(outs)) > 1


def test_cold_start_correctness_vs_baseline(fleet):
    _, reg, orch = fleet
    req = wl.WORKLOADS["lr_serving"].request_builder()
    inst_b, tb = orch.cold_start_baseline("tenant-0")
    inst_w, tw = orch.cold_start_warmswap("tenant-0")
    assert np.array_equal(np.asarray(inst_b.invoke(req)[0]),
                          np.asarray(inst_w.invoke(req)[0]))
    assert tw.total < tb.total                      # dependency-heavy: WarmSwap wins


def test_remote_pool_link(fleet):
    """Paper §3.4: a remote central pool works too; communication cost rises but the
    cold start stays correct."""
    mgr, reg, orch = fleet
    restored = mgr.request_migration("model-tiny", RestorePolicy.BULK,
                                     LinkModel(latency_s=0.002, bandwidth_bps=2e9))
    params = restored.as_pytree()
    assert restored.resident_fraction() == 1.0
    assert restored.stats.bytes_transferred >= restored.metadata.page_table.nbytes_payload


def test_lightweight_function_overhead():
    """Paper Fig. 5a: for tiny dependencies over a remote link, WarmSwap's
    communication overhead can exceed the from-scratch init — reproduced, not
    hidden."""
    tmp = tempfile.mkdtemp()
    link = LinkModel(latency_s=0.02, bandwidth_bps=1e8)
    mgr = DependencyManager(disk_dir=tmp, link=link)
    reg = FunctionRegistry(store_dir=tmp)
    mgr.register_image("py-base", "py-base", wl.py_base_builder)
    w = wl.WORKLOADS["helloworld"]
    reg.register("helloworld", "py-base", w.handler_builder, w.handler_fn,
                 base_params_builder=wl.py_base_builder,
                 write_baseline_checkpoint=False)
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig(link=link))
    _, tb = orch.cold_start_baseline("helloworld")
    _, tw = orch.cold_start_warmswap("helloworld")
    assert tw.communication + tw.migration > tb.dependency_init
