"""Jitted public wrapper for the paged-gather kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.page_gather.kernel import page_gather_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("interpret",))
def page_gather(pool: jax.Array, page_ids: jax.Array, *, interpret=None) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    return page_gather_pallas(pool, page_ids, interpret=interp)
