"""Finding model, fingerprints, and the baseline-diff workflow of repro-lint.

A finding is one rule violation at one source location. Its *fingerprint* is
deliberately line-number-free — ``checker | rule | path | scope | normalized
source line`` — so unrelated edits above a grandfathered violation don't churn
the baseline, while any change to the offending line itself (or moving it to
another function) makes it a *new* finding again.

The baseline file (``tools/analysis/baseline.json``) maps fingerprints to
counts: pre-existing violations are grandfathered, new ones fail the run.
Workflow and grammar: docs/ANALYSIS.md.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Set, Tuple

#: Version of the findings-JSON artifact layout (``--json`` output).
FINDINGS_SCHEMA_VERSION = 1
#: Version of the baseline file layout.
BASELINE_SCHEMA_VERSION = 1

_WS = re.compile(r"\s+")


@dataclass
class Finding:
    """One rule violation at one source location.

    ``scope`` is the dotted qualname of the enclosing class/function
    (``""`` at module level); ``snippet`` the stripped offending source
    line. Both feed the line-number-free fingerprint.
    """
    checker: str
    rule: str
    path: str                 # repo-relative, posix separators
    line: int
    col: int
    message: str
    scope: str = ""
    snippet: str = ""
    suggestion: str = ""

    @property
    def fingerprint(self) -> str:
        key = "|".join((self.checker, self.rule, self.path, self.scope,
                        _WS.sub(" ", self.snippet.strip())))
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        return d

    def render(self, fix_suggestions: bool = False) -> str:
        out = (f"{self.path}:{self.line}:{self.col}: "
               f"[{self.checker}/{self.rule}] {self.message}")
        if fix_suggestions and self.suggestion:
            out += f"\n    fix: {self.suggestion}"
        return out


# ------------------------------------------------------------------- baseline

def load_baseline(path: Optional[str]) -> Dict[str, int]:
    """Fingerprint -> grandfathered count from a baseline file; an absent
    path or missing file is an empty baseline (nothing grandfathered)."""
    if not path:
        return {}
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    version = data.get("baseline_schema_version")
    if version != BASELINE_SCHEMA_VERSION:
        raise ValueError(f"unsupported baseline_schema_version {version!r} "
                         f"in {path} (expected {BASELINE_SCHEMA_VERSION})")
    return {fp: int(entry["count"])
            for fp, entry in data.get("findings", {}).items()}


def load_baseline_entries(path: Optional[str]) -> Dict[str, Dict[str, Any]]:
    """Fingerprint -> full baseline entry (count/rule/path/scope/snippet),
    for stale-entry detection; absent path or file is empty."""
    if not path:
        return {}
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    version = data.get("baseline_schema_version")
    if version != BASELINE_SCHEMA_VERSION:
        raise ValueError(f"unsupported baseline_schema_version {version!r} "
                         f"in {path} (expected {BASELINE_SCHEMA_VERSION})")
    return dict(data.get("findings", {}))


def stale_baseline_findings(entries: Mapping[str, Mapping[str, Any]],
                            findings: List[Finding],
                            scanned_rels: Set[str]) -> List[Finding]:
    """One ``baseline/stale-entry`` finding per grandfathered fingerprint
    that no current finding consumes — a dead suppression is how a
    grandfathered bug hides after the offending line changed. Entries whose
    recorded path was *not* scanned this run are skipped (a partial-path run
    says nothing about them)."""
    live = {f.fingerprint for f in findings}
    stale: List[Finding] = []
    for fp in sorted(entries):
        entry = entries[fp]
        path = str(entry.get("path", ""))
        if fp in live or path not in scanned_rels:
            continue
        stale.append(Finding(
            "baseline", "stale-entry", path or "tools/analysis/baseline.json",
            1, 0,
            f"baseline fingerprint {fp} ({entry.get('rule', '?')}) no "
            f"longer matches any finding — the grandfathered violation "
            f"was fixed or moved; prune the entry",
            scope=str(entry.get("scope", "")),
            snippet=str(entry.get("snippet", "")),
            suggestion="re-run with --write-baseline after an audit, or "
                       "delete the entry"))
    return stale


def write_baseline(path: str, findings: List[Finding]) -> None:
    """Write ``findings`` as the new grandfathered baseline (sorted, with
    a human-readable locator per fingerprint so reviews can audit it)."""
    entries: Dict[str, Dict[str, Any]] = {}
    for f in findings:
        fp = f.fingerprint
        if fp in entries:
            entries[fp]["count"] += 1
        else:
            entries[fp] = {"count": 1, "rule": f"{f.checker}/{f.rule}",
                           "path": f.path, "scope": f.scope,
                           "snippet": _WS.sub(" ", f.snippet.strip())}
    payload = {
        "baseline_schema_version": BASELINE_SCHEMA_VERSION,
        "findings": {fp: entries[fp] for fp in sorted(entries)},
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=False)
        f.write("\n")


def diff_baseline(findings: List[Finding],
                  baseline: Mapping[str, int]) -> Tuple[List[Finding],
                                                        List[Finding]]:
    """Split ``findings`` into (new, grandfathered) against ``baseline``.

    A fingerprint grandfathers at most ``baseline[fp]`` occurrences — if a
    grandfathered violation is duplicated, the extra copies are new."""
    remaining = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        fp = f.fingerprint
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


def findings_json(findings: List[Finding], new: List[Finding],
                  baselined: List[Finding]) -> Dict[str, Any]:
    """The machine-readable artifact CI uploads (``--json``)."""
    return {
        "analysis_schema_version": FINDINGS_SCHEMA_VERSION,
        "n_findings": len(findings),
        "n_new": len(new),
        "n_baselined": len(baselined),
        "findings": [f.to_dict() for f in findings],
        "new": [f.fingerprint for f in new],
    }
