from repro.kernels.diag_recurrence.ops import diag_recurrence
from repro.kernels.diag_recurrence.ref import diag_recurrence_ref

__all__ = ["diag_recurrence", "diag_recurrence_ref"]
