"""Fleet-level request scheduling with straggler mitigation.

Routes requests across serving replicas, tracking per-replica EWMA step latency.
A replica whose in-flight request exceeds ``straggler_factor``x its EWMA is flagged;
flagged work is re-dispatched to the fastest healthy replica (backup-request
strategy), and repeatedly-flagged replicas are quarantined and replaced through the
WarmSwap pool (fast re-warm — the recovery path fault_tolerance.py measures).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import numpy as np


@dataclass
class ReplicaHealth:
    ewma_s: float = 0.0
    n: int = 0
    flags: int = 0
    quarantined: bool = False

    def observe(self, dt: float, alpha: float = 0.2) -> None:
        self.ewma_s = dt if self.n == 0 else (1 - alpha) * self.ewma_s + alpha * dt
        self.n += 1


@dataclass
class SchedulerConfig:
    straggler_factor: float = 3.0
    min_observations: int = 5
    quarantine_after_flags: int = 3


class FleetScheduler:
    """Dispatch + straggler handling over a set of named replicas."""

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig()):
        self.cfg = cfg
        self.health: Dict[str, ReplicaHealth] = {}
        self.dispatch_log: List[tuple] = []

    def register_replica(self, name: str) -> None:
        self.health.setdefault(name, ReplicaHealth())

    def remove_replica(self, name: str) -> None:
        self.health.pop(name, None)

    def healthy(self) -> List[str]:
        return [n for n, h in self.health.items() if not h.quarantined]

    def pick(self) -> Optional[str]:
        """Least-loaded-ish: lowest EWMA among healthy replicas."""
        h = self.healthy()
        if not h:
            return None
        return min(h, key=lambda n: (self.health[n].ewma_s, n))

    def observe(self, name: str, dt: float) -> bool:
        """Record a completed unit of work; returns True if it was a straggler."""
        rh = self.health[name]
        is_straggler = (rh.n >= self.cfg.min_observations and
                        dt > self.cfg.straggler_factor * max(rh.ewma_s, 1e-9))
        rh.observe(dt)
        if is_straggler:
            rh.flags += 1
            if rh.flags >= self.cfg.quarantine_after_flags:
                rh.quarantined = True
        return is_straggler

    def run(self, work: List[Callable[[], float]],
            execute: Callable[[str, Callable], float]) -> Dict[str, int]:
        """Dispatch work items; re-dispatch stragglers once to the best other
        replica. ``execute(replica, item)`` returns measured seconds."""
        counts: Dict[str, int] = collections.Counter()
        for item in work:
            name = self.pick()
            if name is None:
                raise RuntimeError("no healthy replicas")
            dt = execute(name, item)
            counts[name] += 1
            if self.observe(name, dt):
                backup = self.pick()
                if backup is not None and backup != name:
                    dt2 = execute(backup, item)          # backup request
                    self.observe(backup, dt2)
                    counts[backup] += 1
                    self.dispatch_log.append(("redispatch", name, backup))
        return dict(counts)
