"""Trace-driven fleet simulation: WarmSwap vs Prebaking vs Baseline (paper §4.5).

Discrete-event simulation over per-function invocation traces:

  * each function keeps at most one instance; an invocation within the keep-alive
    window is a **warm start**, otherwise a **cold start** (the >99 % case the paper
    scopes to, §2.2);
  * queue-accurate: an arrival while the (single) instance is still executing
    waits for it — latency = queue delay + warm cost, and the instance's
    completion time never rewinds (Lindley recursion over each trace);
  * cold-start latency comes from a per-method :class:`CostModel` — either measured
    numbers produced by ``benchmarks/bench_coldstart.py`` on this machine, or the
    paper's own Table 2 values for a paper-faithful simulation;
  * memory accounting follows each method's structure: WarmSwap = one shared image
    per *dependency* + per-function metadata/handler; Prebaking = one full snapshot
    per *function*; Baseline = nothing resident.

Outputs match Fig. 7: average latency per invocation-rate quartile + required cache
memory, and the headline "X % memory saved when N functions share one image".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.keepalive import KeepAlivePolicy
from repro.core.registry import Registry
from repro.core.traces import Trace, quartile_groups

#: Name -> scalar cost-model factory. Scenario specs address cost models by
#: key: ``paper_table2`` is the paper's measured Table 2 numbers, ``scalar``
#: builds a :class:`CostModel` from explicit kwargs.
COST_MODELS = Registry("cost model")


@dataclass
class CostModel:
    """Per-method start latencies (seconds) and memory shapes (bytes).

    This is the *scalar* model: one constant cold-start latency per method.
    ``core/costmodel.PageCostModel`` wraps it to price cold starts by page
    transfer volume instead; there the ``cold_*_s`` values are read as the
    zero-transfer base (boot + init compute + handler) and the page-transfer
    term is added on top. Under ``PageCostModel.degenerate`` the two models
    agree exactly (see docs/SIMULATION.md).
    """
    cold_warmswap_s: float
    cold_prebaking_s: float
    cold_baseline_s: float
    warm_s: float
    container_s: float = 0.5          # included for cold starts of BOTH methods (§4.5)
    image_bytes: int = 230 << 20      # one shared dependency image (paper: 260 MB total
    metadata_bytes: int = 3 << 20     #   = image + 10 x per-fn metadata, §4.5)
    snapshot_bytes: int = 230 << 20   # one prebaked snapshot per function (~2.3 GB /10)
    image_revive_s: float = 0.4       # extra cold-start cost when the worker's pool
                                      #   must revive/rebuild the image first
                                      #   (disk-tier revive, §3.2; fleet sim only)

    @classmethod
    def paper_table2(cls) -> "CostModel":
        """The paper's measured rnn_serving-class numbers (Table 2 / §4.5)."""
        return cls(cold_warmswap_s=0.89, cold_prebaking_s=0.91, cold_baseline_s=2.2,
                   warm_s=0.004)


COST_MODELS.register("scalar", CostModel)
COST_MODELS.register("paper_table2", CostModel.paper_table2)


def method_cold_latency_s(cost: CostModel, method: str) -> float:
    """Scalar cold-start latency (seconds) for ``method``, pool hit assumed.

    Args:
        cost: the scalar cost model.
        method: ``'warmswap' | 'prebaking' | 'baseline'``.

    Returns:
        Per-method cold latency including the flat container overhead.
        Shared by ``simulate()`` and ``fleet.simulate_fleet()``; the
        page-granular model (``costmodel.PageCostModel``) uses it as the
        zero-transfer base.
    """
    return {
        "warmswap": cost.cold_warmswap_s + cost.container_s,
        "prebaking": cost.cold_prebaking_s + cost.container_s,
        "baseline": cost.cold_baseline_s + cost.container_s,
    }[method]


def method_memory_bytes(cost: CostModel, method: str, n_functions: int,
                        shared_images: int = 1) -> int:
    """Single-worker resident-memory model (bytes).

    Args:
        cost: the scalar cost model (``image_bytes`` / ``metadata_bytes`` /
            ``snapshot_bytes``).
        method: ``'warmswap' | 'prebaking' | 'baseline'``.
        n_functions: functions served by this worker.
        shared_images: distinct dependency images across those functions.

    Returns:
        WarmSwap = shared images + per-function metadata (O(#images));
        Prebaking = one full snapshot per function (O(#functions));
        Baseline = nothing resident.
    """
    return {
        "warmswap": shared_images * cost.image_bytes
                    + n_functions * cost.metadata_bytes,
        "prebaking": n_functions * cost.snapshot_bytes,
        "baseline": 0,
    }[method]


def latency_percentiles(samples: np.ndarray) -> Dict[str, float]:
    """P50/P95/P99 (+ mean/max) over per-request latency samples (seconds)."""
    samples = np.asarray(samples, np.float64)
    if samples.size == 0:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    p50, p95, p99 = np.percentile(samples, [50.0, 95.0, 99.0])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(samples.mean()), "max": float(samples.max())}


@dataclass
class SimResult:
    """One ``simulate()`` run's outputs (latencies in seconds, memory in
    bytes; ``latency_samples_s`` is per request, in per-trace order)."""
    method: str
    n_invocations: int
    n_cold: int
    n_warm: int
    total_latency_s: float
    memory_bytes: int
    per_fn_latency: Dict[int, float] = field(default_factory=dict)
    per_fn_invocations: Dict[int, int] = field(default_factory=dict)
    n_queued: int = 0                    # arrivals that waited on a busy instance
    queue_delay_s: float = 0.0           # total time arrivals spent waiting
    latency_samples_s: np.ndarray = field(
        default_factory=lambda: np.empty(0))   # per request (per-trace order)
    sample_fn: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))  # fn index per sample

    @property
    def avg_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_invocations, 1)

    def latency_percentiles(self) -> Dict[str, float]:
        return latency_percentiles(self.latency_samples_s)


def _simulate_trace(arrivals: np.ndarray, ka: float, cold_s: float,
                    warm_s: float):
    """Queue-accurate single-instance scan over one trace.

    Returns ``(lats_s, waits_s, n_cold)``. An arrival within the keep-alive
    window of the previous completion is warm; if the instance is still
    executing it queues behind it (single-server FIFO), so its latency is
    queue delay + warm cost and the completion time never rewinds.

    Vectorized: an arrival whose gap to its predecessor is <= ka is
    *guaranteed* warm (the previous completion is >= the previous arrival, so
    its expiry covers the gap). Only gap > ka arrivals can cold-start, which
    splits the trace into segments headed by a potential cold start followed
    by all-warm interiors. Each interior is a Lindley recursion with constant
    (warm) service — solved in closed form with a running maximum — so a
    multi-million-arrival high-rate trace costs a few numpy passes, not a
    Python loop per request.
    """
    n = len(arrivals)
    lats = np.empty(n)
    waits = np.zeros(n)
    if n == 0:
        return lats, waits, 0
    w_min = warm_s / 60.0
    heads = np.concatenate(
        ([0], np.flatnonzero(np.diff(arrivals) > ka) + 1))
    n_cold = 0
    free_at = -np.inf                  # completion time of the in-flight request
    for s, h in enumerate(heads):
        end = heads[s + 1] if s + 1 < len(heads) else n    # segment [h, end)
        t_h = float(arrivals[h])
        if t_h > free_at + ka:
            # instance expired (or first arrival): fresh cold start, no wait
            n_cold += 1
            start, svc = t_h, cold_s
        else:
            # warm; a long backlog can still cover a gap > ka, so the head may
            # queue behind the in-flight request
            start, svc = max(t_h, free_at), warm_s
        waits[h] = (start - t_h) * 60.0
        lats[h] = waits[h] + svc
        free_at = start + svc / 60.0
        if end > h + 1:
            # interior j in (h, end): completion c_j = max(t_j, c_{j-1}) + w.
            # With u_p = t_p - p*w (p = interior position), the recursion
            # unrolls to c_p = (p+1)*w + max(c_head, runmax(u_0..u_p)).
            seg = arrivals[h + 1: end]
            p = np.arange(end - h - 1, dtype=np.float64)
            peak = np.maximum(np.maximum.accumulate(seg - p * w_min), free_at)
            starts = peak + p * w_min                     # = c_j - w_min
            waits[h + 1: end] = (starts - seg) * 60.0
            lats[h + 1: end] = waits[h + 1: end] + warm_s
            free_at = float(starts[-1]) + w_min
    return lats, waits, n_cold


def simulate(
    traces: List[Trace],
    method: str,                       # 'warmswap' | 'prebaking' | 'baseline'
    cost: CostModel,
    keep_alive: Optional[KeepAlivePolicy] = None,
    shared_images: int = 1,            # distinct dependency images across the fleet
    page_cost: Optional["PageCostModel"] = None,  # page-granular cold pricing
) -> SimResult:
    """Single-worker, queue-accurate trace simulation (paper Fig. 7).

    Thin wrapper over the declarative entry point
    (:func:`repro.core.scenario.run` with ``engine='single'``): the engine
    body is :func:`_simulate_impl`, and this signature survives for callers
    that already hold resolved components (traces, a cost-model instance).
    New code should build a :class:`~repro.core.scenario.Scenario` instead.

    Args:
        traces: per-function arrival traces (times in minutes).
        method: ``'warmswap' | 'prebaking' | 'baseline'``.
        cost: scalar cost model (latencies in seconds, sizes in bytes).
        keep_alive: fixed keep-alive window (minutes); default 15 (paper §4.5).
        shared_images: distinct dependency images, for the memory model.
        page_cost: optional :class:`~repro.core.costmodel.PageCostModel`.
            When given, each cold start is priced page-granularly at the
            ``local`` tier (the single worker's pool always holds the image,
            so pages move at host-memcpy speed; the container starts with
            zero resident pages). ``PageCostModel.degenerate(cost)``
            reproduces the default scalar results exactly.

    Returns:
        A :class:`SimResult` with counts, total/per-function latency
        (seconds), static per-method memory (bytes), queueing stats, and
        per-request latency samples.
    """
    # deferred: scenario imports this module (the engine impl lives here)
    from repro.core.scenario import RunOverrides, Scenario, run
    result = run(Scenario(engine="single", methods=[method],
                          shared_images=shared_images),
                 overrides=RunOverrides(traces=traces, cost=cost,
                                        keep_alive=keep_alive,
                                        page_cost=page_cost))
    return result.raw[method]


def _simulate_impl(
    traces: List[Trace],
    method: str,
    cost: CostModel,
    keep_alive: Optional[KeepAlivePolicy] = None,
    shared_images: int = 1,
    page_cost: Optional["PageCostModel"] = None,
) -> SimResult:
    """The single-worker engine body behind :func:`simulate` (same contract);
    called by :func:`repro.core.scenario.run`."""
    keep_alive = keep_alive if keep_alive is not None else KeepAlivePolicy(15.0)
    cold_latency = (page_cost.cold_latency_s(method, tier="local")
                    if page_cost is not None
                    else method_cold_latency_s(cost, method))

    n_cold = n_warm = n_queued = 0
    total = queue_delay = 0.0
    per_fn_lat: Dict[int, float] = {}
    per_fn_n: Dict[int, int] = {}
    sample_chunks: List[np.ndarray] = []
    fn_chunks: List[np.ndarray] = []
    for tr in traces:
        lats, waits, cold = _simulate_trace(
            np.asarray(tr.arrivals_min, np.float64),
            keep_alive.keep_alive_min, cold_latency, cost.warm_s)
        n_cold += cold
        n_warm += len(lats) - cold
        n_queued += int((waits > 0).sum())
        queue_delay += float(waits.sum())
        lat_sum = float(lats.sum())
        total += lat_sum
        per_fn_lat[tr.fn_index] = lat_sum
        per_fn_n[tr.fn_index] = len(tr.arrivals_min)
        sample_chunks.append(lats)
        fn_chunks.append(np.full(len(lats), tr.fn_index, np.int64))

    memory = method_memory_bytes(cost, method, len(traces), shared_images)
    return SimResult(method=method, n_invocations=n_cold + n_warm, n_cold=n_cold,
                     n_warm=n_warm, total_latency_s=total, memory_bytes=memory,
                     per_fn_latency=per_fn_lat, per_fn_invocations=per_fn_n,
                     n_queued=n_queued, queue_delay_s=queue_delay,
                     latency_samples_s=(np.concatenate(sample_chunks)
                                        if sample_chunks else np.empty(0)),
                     sample_fn=(np.concatenate(fn_chunks)
                                if fn_chunks else np.empty(0, np.int64)))


def quartile_latencies(traces: List[Trace], result: SimResult) -> Dict[str, float]:
    """Fig. 7-left: average latency per invocation-rate quartile."""
    groups = quartile_groups(traces)
    out = {}
    for name, members in groups.items():
        lat = sum(result.per_fn_latency.get(t.fn_index, 0.0) for t in members)
        n = sum(result.per_fn_invocations.get(t.fn_index, 0) for t in members)
        out[name] = lat / max(n, 1)
    return out


def quartile_percentiles(traces: List[Trace], result) -> Dict[str, Dict[str, float]]:
    """P50/P95/P99 per invocation-rate quartile, from the per-request latency
    samples. ``result`` is a SimResult or FleetResult (duck-typed: needs
    ``latency_samples_s`` + ``sample_fn``)."""
    groups = quartile_groups(traces)
    samples = np.asarray(result.latency_samples_s)
    sample_fn = np.asarray(result.sample_fn)
    out = {}
    for name, members in groups.items():
        fns = np.array([t.fn_index for t in members], np.int64)
        mask = np.isin(sample_fn, fns)
        out[name] = latency_percentiles(samples[mask])
    return out


def memory_saving_fraction(warmswap: SimResult, prebaking: SimResult) -> float:
    """The paper's headline: WarmSwap saves ~88 % of warm-up memory for 10 functions
    sharing one image."""
    if prebaking.memory_bytes == 0:
        return 0.0
    return 1.0 - warmswap.memory_bytes / prebaking.memory_bytes
