"""RG-LRU recurrent block (Griffin / recurrentgemma-2b).

Real-gated linear recurrent unit:
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The published block uses block-diagonal gate matrices; we use full (W, W) linears
(noted in DESIGN.md) — same compute shape class, simpler sharding. State per layer is
(B, W) fp32 + a conv tail: bounded, so the arch qualifies for long_500k.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import _he
from repro.models.recurrence import (
    causal_conv1d,
    causal_conv1d_step,
    chunked_diag_recurrence,
)

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


class RGLRUState(NamedTuple):
    h: jax.Array        # (B, W) fp32
    conv: jax.Array     # (B, width-1, W)


def init_rglru(key, cfg: ArchConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    # init so that a = exp(-c*softplus(L)) is uniform in [0.9, 0.999]
    a0 = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(a0) / _C))
    return {
        "linear_x": _he(ks[1], (d, w), d, dtype),
        "linear_y": _he(ks[2], (d, w), d, dtype),
        "conv_w": _he(ks[3], (w, cfg.conv1d_width), cfg.conv1d_width, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": _he(ks[4], (w, w), w, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": _he(ks[5], (w, w), w, dtype),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "out_proj": _he(jax.random.fold_in(key, 7), (w, d), w, dtype),
    }


def _gates(params: dict, xb: jax.Array):
    """xb: (B, S, W) -> (a, b) recurrence terms, fp32."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8))
    b = multiplier * (i * xf)
    return a, b


def rglru_prefill(
    params: dict,
    x: jax.Array,               # (B, S, D)
    cfg: ArchConfig,
    *,
    make_state: bool = False,
    chunk: int = 256,
) -> Tuple[jax.Array, RGLRUState | None]:
    B = x.shape[0]
    w = cfg.resolved_lru_width
    xb_pre = x @ params["linear_x"]                     # (B, S, W) pre-conv
    yb = jax.nn.gelu(x @ params["linear_y"], approximate=True)
    xb = causal_conv1d(xb_pre, params["conv_w"], params["conv_b"])
    a, b = _gates(params, xb)
    h0 = jnp.zeros((B, w), jnp.float32)
    h_all, h_final = chunked_diag_recurrence(a, b, h0, chunk=chunk)
    out = (h_all.astype(x.dtype) * yb) @ params["out_proj"]
    state = None
    if make_state:
        tail = xb_pre[:, -(cfg.conv1d_width - 1):]      # conv state holds PRE-conv inputs
        pad = cfg.conv1d_width - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        state = RGLRUState(h=h_final, conv=tail)
    return out, state


def rglru_decode(
    params: dict,
    x: jax.Array,               # (B, 1, D)
    state: RGLRUState,
    cfg: ArchConfig,
) -> Tuple[jax.Array, RGLRUState]:
    xb = x @ params["linear_x"]                         # (B, 1, W)
    yb = jax.nn.gelu(x @ params["linear_y"], approximate=True)
    conv_out, conv_state = causal_conv1d_step(xb, state.conv, params["conv_w"], params["conv_b"])
    a, b = _gates(params, conv_out)
    h = a[:, 0] * state.h + b[:, 0]
    out = (h[:, None].astype(x.dtype) * yb) @ params["out_proj"]
    return out, RGLRUState(h=h, conv=conv_state)


def empty_rglru_state(cfg: ArchConfig, batch: int, dtype) -> RGLRUState:
    w = cfg.resolved_lru_width
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    )
