"""Fleet-disruption schedules: worker churn, preemption waves, eviction storms.

The fleet engine (``core/fleet.py``) is, by default, a fair-weather model:
workers never die and resident images are only evicted by capacity pressure.
This module supplies the foul weather as **data** — a
:class:`DisruptionSchedule` is a frozen, pre-computed list of timed events
the engine merges into its heap at setup (at ranks *after* every
fair-weather kind at the same instant; see ``core/events.py``):

  * ``worker_fail``    — the worker dies: every instance on it is killed,
    its in-flight and queued requests are re-queued (original arrival times
    preserved, so the lost time shows up as queue wait), and its pool is
    dropped (propagating to the cluster-shared tier);
  * ``worker_recover`` — the worker returns with an *empty* pool; re-warming
    happens on demand through the normal cold-start path (the pool-backed
    recovery story of ``runtime/fault_tolerance.py`` — see
    ``replay_disruption`` there, which replays these same schedules against
    a live ``ReplicaSet``);
  * ``cache_flush``    — a shared-image eviction storm: every resident image
    and snapshot is evicted from every worker pool and from the
    cluster-shared tier. Warm instances keep running (a cache eviction does
    not kill containers); subsequent cold starts pay the revive/miss price.

Schedules are **registry-pluggable** (``DISRUPTIONS``): a scenario spec names
one by key (``"disruption": {"name": "churn", "kwargs": {...}}``) and the
runtime injects the fleet shape (``n_workers``, ``horizon_min``) when
building it, so one spec scales with its own ``smoke_overrides``. Every
schedule is a pure function of its kwargs — seeded generators use
``np.random.default_rng`` — which keeps the determinism contract
(docs/SIMULATION.md) intact.

Normative semantics (event ordering, requeue accounting, counter meanings)
live in docs/SIMULATION.md, "Oracle and disruption semantics".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.registry import Registry

#: Valid :class:`DisruptionEvent` kinds, in documentation order.
EVENT_KINDS = ("worker_fail", "worker_recover", "cache_flush")

#: Name -> schedule factory. Factories take the runtime-injected fleet shape
#: (``n_workers``, ``horizon_min``) plus their own kwargs and return a
#: :class:`DisruptionSchedule`.
DISRUPTIONS = Registry("disruption")


@dataclass(frozen=True)
class DisruptionEvent:
    """One timed disruption: ``kind`` at ``t_min`` against ``worker``
    (ignored — conventionally ``-1`` — for fleet-wide ``cache_flush``)."""
    t_min: float
    kind: str
    worker: int = -1

    def __post_init__(self) -> None:
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown disruption event kind {self.kind!r} "
                             f"(choose from {list(EVENT_KINDS)})")
        if self.t_min < 0:
            raise ValueError(f"disruption event time must be >= 0, "
                             f"got {self.t_min}")


@dataclass(frozen=True)
class DisruptionSchedule:
    """A frozen, time-sorted event list the fleet engine replays.

    ``name`` records which registry component produced it (diagnostics only).
    Construction sorts events by time (stable, so same-instant events keep
    their authored order) and validates worker indices against ``n_workers``.
    """
    events: Tuple[DisruptionEvent, ...]
    n_workers: int
    name: str = "custom"

    def __init__(self, events: Sequence[DisruptionEvent], n_workers: int,
                 name: str = "custom"):
        for ev in events:
            if ev.kind != "cache_flush" and not (0 <= ev.worker < n_workers):
                raise ValueError(
                    f"disruption event targets worker {ev.worker} but the "
                    f"fleet has {n_workers} worker(s)")
        object.__setattr__(self, "events",
                           tuple(sorted(events, key=lambda e: e.t_min)))
        object.__setattr__(self, "n_workers", int(n_workers))
        object.__setattr__(self, "name", name)

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)


@DISRUPTIONS.register("churn")
def churn(n_workers: int, horizon_min: float, seed: int = 0,
          mean_uptime_min: float = 720.0, downtime_min: float = 10.0,
          max_failures: int = 64) -> DisruptionSchedule:
    """Random worker churn: each failure hits a uniformly drawn worker after
    an exponentially distributed uptime, and the worker recovers
    ``downtime_min`` later (recoveries past the horizon still fire — residency
    is clamped by the engine). At most ``max_failures`` failures are drawn,
    and a worker that is still down cannot fail again."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if mean_uptime_min <= 0 or downtime_min < 0:
        raise ValueError("mean_uptime_min must be > 0 and downtime_min >= 0")
    rng = np.random.default_rng(seed)
    events: List[DisruptionEvent] = []
    down_until = np.zeros(n_workers)
    t = 0.0
    for _ in range(max_failures):
        t += float(rng.exponential(mean_uptime_min))
        if t >= horizon_min:
            break
        w = int(rng.integers(0, n_workers))
        if t < down_until[w]:
            continue                       # still recovering; skip this draw
        events.append(DisruptionEvent(t, "worker_fail", w))
        events.append(DisruptionEvent(t + downtime_min, "worker_recover", w))
        down_until[w] = t + downtime_min
    return DisruptionSchedule(events, n_workers, name="churn")


@DISRUPTIONS.register("preempt")
def preempt(n_workers: int, horizon_min: float, at_min: float = 0.0,
            at_frac: Optional[float] = 0.5, workers: Optional[List[int]] = None,
            kill_frac: float = 0.5,
            downtime_min: float = 30.0) -> DisruptionSchedule:
    """A spot-preemption wave: at one instant a block of workers is killed
    together and recovers ``downtime_min`` later. The instant is
    ``at_frac * horizon_min`` when ``at_frac`` is given, else ``at_min``;
    the victims are ``workers`` when given, else the first
    ``ceil(kill_frac * n_workers)`` workers (at least one)."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if not 0.0 < kill_frac <= 1.0:
        raise ValueError(f"kill_frac must be in (0, 1], got {kill_frac}")
    t = at_frac * horizon_min if at_frac is not None else at_min
    victims = (list(workers) if workers is not None
               else list(range(max(1, int(np.ceil(kill_frac * n_workers))))))
    events = []
    for w in victims:
        events.append(DisruptionEvent(t, "worker_fail", int(w)))
        events.append(DisruptionEvent(t + downtime_min, "worker_recover",
                                      int(w)))
    return DisruptionSchedule(events, n_workers, name="preempt")


@DISRUPTIONS.register("storm")
def storm(n_workers: int, horizon_min: float, first_at_min: float = 0.0,
          first_at_frac: Optional[float] = 0.25,
          period_min: Optional[float] = None,
          count: int = 1) -> DisruptionSchedule:
    """Shared-image eviction storms: ``count`` fleet-wide cache flushes,
    the first at ``first_at_frac * horizon_min`` (or ``first_at_min`` when
    ``first_at_frac`` is ``None``), then every ``period_min`` (default:
    evenly spaced over the remaining horizon)."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if count < 1:
        raise ValueError(f"count must be >= 1, got {count}")
    t0 = (first_at_frac * horizon_min if first_at_frac is not None
          else first_at_min)
    if period_min is None:
        period_min = (max(horizon_min - t0, 0.0) / count) or 1.0
    if period_min <= 0:
        raise ValueError(f"period_min must be > 0, got {period_min}")
    events = [DisruptionEvent(t0 + i * period_min, "cache_flush")
              for i in range(count)]
    return DisruptionSchedule(events, n_workers, name="storm")
