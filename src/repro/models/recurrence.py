"""Chunked diagonal linear recurrence: h_t = a_t * h_{t-1} + b_t (elementwise).

Shared by the Mamba-1 selective scan (channels = d_inner x ssm_state) and the RG-LRU
(channels = lru_width). Sequence is processed in chunks: an outer ``lax.scan`` carries
the state between chunks (keeping live memory O(B·chunk·channels)), and an inner
``associative_scan`` parallelizes within the chunk (TPU-friendly log-depth).

`repro.kernels.diag_recurrence` is the Pallas realization of the same contract; this
module is its reference semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def chunked_diag_recurrence(
    a: jax.Array,          # (B, S, *C) decay per step
    b: jax.Array,          # (B, S, *C) input per step
    h0: jax.Array,         # (B, *C) initial state
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h_all (B, S, *C), h_final (B, *C))."""
    B, S = a.shape[0], a.shape[1]
    C = min(chunk, S)
    pad = (-S) % C
    if pad:  # identity elements: a=1, b=0
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)) + ((0, 0),) * (b.ndim - 2))
    n_chunks = a.shape[1] // C

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, n_chunks, C, *x.shape[2:]), 1, 0)

    a_c, b_c = to_chunks(a), to_chunks(b)        # (nc, B, C, *ch)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a2 * a1, a2 * b1 + b2

    def body(h, ab):
        ac, bc = ab                               # (B, C, *ch)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = aa * h[:, None] + bb              # fold in the inter-chunk carry
        return h_all[:, -1], h_all

    h_final, h_chunks = jax.lax.scan(body, h0, (a_c, b_c))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(B, n_chunks * C, *a.shape[2:])
    return h_all[:, :S], h_final


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, C); w: (C, width)."""
    width = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    kernel = w.T[:, None, :]                      # (width, 1, C)
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), kernel.astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    ).astype(x.dtype)
    if b is not None:
        out = out + b
    return out


def causal_conv1d_step(
    x_new: jax.Array,       # (B, 1, C)
    conv_state: jax.Array,  # (B, width-1, C) trailing inputs
    w: jax.Array,           # (C, width)
    b: jax.Array | None = None,
):
    """Single-token conv step; returns (out (B,1,C), new_state)."""
    window = jnp.concatenate([conv_state, x_new], axis=1)      # (B, width, C)
    out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x_new.dtype)[:, None]
    if b is not None:
        out = out + b
    return out, window[:, 1:]
