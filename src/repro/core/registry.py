"""Function registry: serverless endpoints = shared image ref + per-tenant handler.

The paper's isolation argument (§1) holds by construction here: the dependency image
contains only the *public* base model; user-specific state (the handler head weights
and the handler callable) never enters the shared pool. What Prebaking would snapshot
per function — base + handler together — the registry keeps factored.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass
class FunctionSpec:
    fn_id: str
    image_id: str                     # shared dependency image this endpoint needs
    handler_builder: Callable[[], Dict[str, np.ndarray]]  # per-tenant weights (small)
    handler_fn: Callable[..., Any]    # handler(params, handler_weights, request)
    # provider-side artifacts
    checkpoint_path: Optional[str] = None   # baseline path: full per-fn checkpoint
    handler_bytes: int = 0
    registered_at: float = field(default_factory=time.time)


class FunctionRegistry:
    def __init__(self, store_dir: Optional[str] = None):
        self.store_dir = store_dir
        self._fns: Dict[str, FunctionSpec] = {}

    def register(
        self,
        fn_id: str,
        image_id: str,
        handler_builder: Callable[[], Dict[str, np.ndarray]],
        handler_fn: Callable[..., Any],
        *,
        base_params_builder: Optional[Callable[[], Any]] = None,
        write_baseline_checkpoint: bool = False,
    ) -> FunctionSpec:
        """Registering a function is the paper's *setup phase* (Fig. 4b): the user
        uploads code + handler; the provider may also write the traditional full
        per-function container checkpoint (what the Baseline cold start loads)."""
        hw = handler_builder()
        hbytes = sum(np.asarray(v).nbytes for v in hw.values())
        ckpt = None
        if write_baseline_checkpoint and self.store_dir and base_params_builder:
            import jax
            os.makedirs(self.store_dir, exist_ok=True)
            ckpt = os.path.join(self.store_dir, f"{fn_id}.npz")
            params = base_params_builder()
            flat = {}
            for i, l in enumerate(jax.tree_util.tree_leaves(params)):
                arr = np.asarray(l)
                if arr.dtype.name == "bfloat16":  # npz can't hold bf16: view as u16
                    flat[f"p{i}:bf16"] = arr.view(np.uint16)
                else:
                    flat[f"p{i}"] = arr
            flat.update({f"h_{k}": np.asarray(v) for k, v in hw.items()})
            np.savez(ckpt, **flat)
        spec = FunctionSpec(fn_id=fn_id, image_id=image_id,
                            handler_builder=handler_builder, handler_fn=handler_fn,
                            checkpoint_path=ckpt, handler_bytes=hbytes)
        self._fns[fn_id] = spec
        return spec

    def get(self, fn_id: str) -> FunctionSpec:
        return self._fns[fn_id]

    def list(self) -> List[str]:
        return sorted(self._fns)

    def functions_sharing(self, image_id: str) -> List[str]:
        return [f for f, s in self._fns.items() if s.image_id == image_id]
