"""Unit tests for core/trace_stream.py: the hardened Azure CSV reader
(gzip auto-detection, malformed rows raise with line numbers), the four
adversarial generators, and the streaming invariants (chunk-size invariance,
re-iterability, residency stats). The engine-level bit-identity contract is
covered separately by tests/test_stream_equiv.py."""
import gzip
import os

import numpy as np
import pytest

from repro.core.trace_stream import (DEFAULT_BLOCK_MIN,
                                     NON_SEMANTIC_TRACE_KWARGS,
                                     AzureCsvStream, CsvSchemaError,
                                     ListTraceStream, TraceStream, block_rng,
                                     ensure_trace_list)
from repro.core.traces import TRACE_GENERATORS, Trace, generate_fleet_traces

FIXTURE = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "data",
                       "azure_sample.csv.gz")

GENERATOR_KWARGS = {
    "diurnal": dict(n_functions=20, horizon_min=360.0, seed=5, n_images=4),
    "bursts": dict(n_functions=16, horizon_min=240.0, seed=6, n_images=3),
    "tenant_mix": dict(n_tenants=3, fns_per_tenant=6, horizon_min=240.0,
                       seed=7),
    "rollout": dict(n_functions=12, horizon_min=480.0, seed=8, n_images=2),
}


def _arr_equal(ta, tb):
    assert len(ta) == len(tb)
    for a, b in zip(ta, tb):
        assert a.fn_index == b.fn_index and a.image_id == b.image_id
        assert np.array_equal(a.arrivals_min, b.arrivals_min)
        assert a.rate_per_min == b.rate_per_min


# --------------------------------------------------------------- registry

def test_all_stream_generators_registered():
    for name in ("azure_csv", "diurnal", "bursts", "tenant_mix", "rollout"):
        assert name in TRACE_GENERATORS, name


# -------------------------------------------------------------- block_rng

def test_block_rng_deterministic_and_keyed():
    a = block_rng(3, 2, 7).random(4)
    b = block_rng(3, 2, 7).random(4)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, block_rng(3, 2, 8).random(4))
    assert not np.array_equal(a, block_rng(3, 1, 7).random(4))


def test_block_rng_rejects_negative_seed():
    with pytest.raises(ValueError, match=">= 0"):
        block_rng(-1, 2)


def test_stream_base_validation():
    class _S(TraceStream):
        pass
    with pytest.raises(ValueError, match="n_functions"):
        _S(n_functions=0, horizon_min=10.0)
    with pytest.raises(ValueError, match="horizon_min"):
        _S(n_functions=1, horizon_min=0.0)
    with pytest.raises(ValueError, match="chunk_min"):
        _S(n_functions=1, horizon_min=10.0, chunk_min=0.0)


# ----------------------------------------------------------- CSV fixture

def test_csv_fixture_parses_with_shared_images():
    st = AzureCsvStream(FIXTURE, n_functions=64, horizon_min=1440.0)
    try:
        meta = st.meta_traces()
        assert st.n_functions == 64
        assert st.total_invocations > 0
        assert len({t.image_id for t in meta}) > 1   # HashApp sharing
        assert all(t.rate_per_min >= 0 for t in meta)
        assert all(len(t.arrivals_min) == 0 for t in meta)
    finally:
        st.close()


def test_csv_gzip_and_plain_bit_identical(tmp_path):
    plain = tmp_path / "t.csv"
    with gzip.open(FIXTURE, "rb") as f:
        plain.write_bytes(f.read())
    a = AzureCsvStream(str(plain), n_functions=8, horizon_min=240.0, seed=1)
    b = AzureCsvStream(FIXTURE, n_functions=8, horizon_min=240.0, seed=1)
    try:
        _arr_equal(a.materialize(), b.materialize())
    finally:
        a.close()
        b.close()


def test_csv_row_cap_and_horizon_trim():
    st = AzureCsvStream(FIXTURE, n_functions=10, horizon_min=60.0)
    try:
        assert st.n_functions == 10
        tr = st.materialize()
        assert all((t.arrivals_min < 60.0).all() for t in tr)
    finally:
        st.close()


def _write_csv(tmp_path, body, name="t.csv"):
    p = tmp_path / name
    p.write_text(body)
    return str(p)


def test_csv_malformed_rows_raise_with_line_numbers(tmp_path):
    header = "HashApp,1,2,3\n"
    cases = [
        ("app0,1,2\n", "line 2: expected 4 columns, got 3"),
        ("app0,1,oops,3\n", "line 2, column '2': invalid invocation"),
        ("app0,1,-2,3\n", "line 2, column '2': negative invocation"),
    ]
    for body, fragment in cases:
        path = _write_csv(tmp_path, header + body)
        with pytest.raises(CsvSchemaError, match=fragment):
            AzureCsvStream(path, n_functions=4, horizon_min=10.0)


def test_csv_schema_errors(tmp_path):
    with pytest.raises(CsvSchemaError, match="empty file"):
        AzureCsvStream(_write_csv(tmp_path, ""), n_functions=1,
                       horizon_min=10.0)
    with pytest.raises(CsvSchemaError, match="no per-minute count columns"):
        AzureCsvStream(_write_csv(tmp_path, "HashApp,foo\napp0,1\n"),
                       n_functions=1, horizon_min=10.0)
    with pytest.raises(CsvSchemaError, match="duplicate minute"):
        AzureCsvStream(_write_csv(tmp_path, "1,2,2\n0,0,0\n"),
                       n_functions=1, horizon_min=10.0)


def test_csv_error_names_file_and_line(tmp_path):
    path = _write_csv(tmp_path, "1,2\n3,4\nbad,5\n")
    with pytest.raises(CsvSchemaError) as exc:
        AzureCsvStream(path, n_functions=4, horizon_min=10.0)
    assert path in str(exc.value) and "line 3" in str(exc.value)


def test_csv_empty_cells_are_zero(tmp_path):
    path = _write_csv(tmp_path, "1,2,3\n2,,1\n")
    st = AzureCsvStream(path, n_functions=1, horizon_min=10.0)
    try:
        tr = st.materialize()
        assert len(tr) == 1 and len(tr[0].arrivals_min) == 3
        assert st.total_invocations == 3
    finally:
        st.close()


def test_csv_close_removes_spill_dir(tmp_path):
    path = _write_csv(tmp_path, "1,2\n1,1\n")
    st = AzureCsvStream(path, n_functions=1, horizon_min=10.0)
    spill = st._spill_dir
    assert os.path.isdir(spill)
    st.close()
    assert not os.path.exists(spill)


# -------------------------------------------- streaming invariants

@pytest.mark.parametrize("name", sorted(GENERATOR_KWARGS))
def test_chunk_min_is_non_semantic(name):
    """Chunk grouping must never change which arrivals exist — only how many
    are resident at once (the chunk-size-invariance half of the contract)."""
    kw = GENERATOR_KWARGS[name]
    base = TRACE_GENERATORS.build(name, stream=False, **kw)
    for chunk_min in (30.0, 120.0, 1e9):
        st = TRACE_GENERATORS.build(name, stream=True, chunk_min=chunk_min,
                                    block_min=30.0, **kw)
        st2 = TRACE_GENERATORS.build(name, stream=True, block_min=30.0, **kw)
        _arr_equal(st.materialize(), st2.materialize())
    # and stream=False vs stream=True agree at the default chunking
    st = TRACE_GENERATORS.build(name, stream=True, **kw)
    _arr_equal(base, st.materialize())


def test_csv_chunk_min_is_non_semantic():
    kw = dict(n_functions=12, horizon_min=480.0, seed=2, block_min=60.0)
    base = AzureCsvStream(FIXTURE, chunk_min=60.0, **kw)
    other = AzureCsvStream(FIXTURE, chunk_min=240.0, **kw)
    try:
        _arr_equal(base.materialize(), other.materialize())
        n_small = sum(1 for _ in base.chunks())
        n_big = sum(1 for _ in other.chunks())
        assert n_small > n_big >= 1
    finally:
        base.close()
        other.close()


@pytest.mark.parametrize("name", sorted(GENERATOR_KWARGS))
def test_chunks_match_materialize_and_are_reiterable(name):
    st = TRACE_GENERATORS.build(name, stream=True, block_min=60.0,
                                chunk_min=60.0, **GENERATOR_KWARGS[name])
    total = sum(len(t.arrivals_min) for t in st.materialize())
    first = [c.t_min.copy() for c in st.chunks()]
    second = [c.t_min.copy() for c in st.chunks()]        # fresh iterator
    assert sum(len(t) for t in first) == total
    assert all(np.array_equal(a, b) for a, b in zip(first, second))
    assert st.stats.n_arrivals == total
    assert st.stats.n_chunks == len(first)
    assert 0 < st.stats.peak_resident_arrivals < max(total, 2)


def test_chunks_sorted_and_windowed():
    st = TRACE_GENERATORS.build("diurnal", stream=True, block_min=30.0,
                                chunk_min=30.0,
                                **GENERATOR_KWARGS["diurnal"])
    prev_end = 0.0
    for c in st.chunks():
        assert (np.diff(c.t_min) >= 0).all()
        assert c.t_min[0] >= c.start_min >= prev_end - 1e-9
        assert c.t_min[-1] <= c.end_min
        prev_end = c.start_min


@pytest.mark.parametrize("name", sorted(GENERATOR_KWARGS))
def test_generator_determinism(name):
    kw = GENERATOR_KWARGS[name]
    a = TRACE_GENERATORS.build(name, stream=False, **kw)
    b = TRACE_GENERATORS.build(name, stream=False, **kw)
    _arr_equal(a, b)
    kw2 = dict(kw, seed=kw["seed"] + 100)
    c = TRACE_GENERATORS.build(name, stream=False, **kw2)
    assert any(not np.array_equal(x.arrivals_min, y.arrivals_min)
               for x, y in zip(a, c))


def test_non_semantic_kwargs_frozen():
    assert NON_SEMANTIC_TRACE_KWARGS == {"stream", "chunk_min"}
    assert "block_min" not in NON_SEMANTIC_TRACE_KWARGS   # block_min IS RNG


def test_ensure_trace_list_accepts_both():
    tr = generate_fleet_traces(n_functions=4, horizon_min=100.0, seed=1)
    assert ensure_trace_list(tr) is tr
    st = ListTraceStream(tr, chunk_size=7)
    _arr_equal(ensure_trace_list(st), tr)


# ----------------------------------------------- generator-specific shape

def test_diurnal_rates_modulate():
    kw = dict(GENERATOR_KWARGS["diurnal"], horizon_min=1440.0)
    st = TRACE_GENERATORS.build("diurnal", stream=True, amplitude=0.95,
                                peak_min=840.0, phase_jitter_min=0.0, **kw)
    tr = st.materialize()
    # day/night split: the 6h around the peak must out-arrive the 6h trough
    t = np.concatenate([x.arrivals_min for x in tr]) % 1440.0
    peak = ((t > 11 * 60) & (t < 17 * 60)).sum()
    trough = ((t > 23 * 60) | (t < 5 * 60)).sum()
    assert peak > 2 * max(trough, 1)


def test_bursts_concentrate_arrivals():
    kw = dict(GENERATOR_KWARGS["bursts"], burst_multiplier=80.0,
              n_bursts=3, burst_duration_min=5.0)
    tr = TRACE_GENERATORS.build("bursts", stream=False, **kw)
    base = TRACE_GENERATORS.build(
        "bursts", stream=False, **dict(kw, burst_multiplier=1.0))
    assert sum(len(t.arrivals_min) for t in tr) > \
        1.5 * sum(len(t.arrivals_min) for t in base)


def test_tenant_mix_partitions_images():
    st = TRACE_GENERATORS.build("tenant_mix", stream=True,
                                **GENERATOR_KWARGS["tenant_mix"])
    meta = st.meta_traces()
    by_tenant = {}
    for t in meta:
        tn = st.tenant_of_fn[t.fn_index]
        by_tenant.setdefault(int(tn), set()).add(t.image_id)
    images = list(by_tenant.values())
    for i, a in enumerate(images):
        for b in images[i + 1:]:
            assert not (a & b), "tenants must not share images"


def test_rollout_introduces_versioned_images():
    kw = GENERATOR_KWARGS["rollout"]
    tr = TRACE_GENERATORS.build("rollout", stream=False, **kw)
    images = {t.image_id for t in tr if len(t.arrivals_min)}
    assert len(images) > kw["n_images"], \
        "rollouts must route traffic to versioned images"
    # later versions arrive strictly later on average
    v0 = np.concatenate([t.arrivals_min for t in tr
                         if t.image_id < kw["n_images"]])
    v_last = np.concatenate([t.arrivals_min for t in tr
                             if t.image_id >= kw["n_images"]])
    assert v_last.mean() > v0.mean()


def test_list_stream_counts_and_stats():
    tr = generate_fleet_traces(n_functions=6, horizon_min=300.0, seed=4,
                               n_images=2, rate_model="zipf",
                               total_rate_per_min=4.0)
    total = sum(len(t.arrivals_min) for t in tr)
    st = ListTraceStream(tr, chunk_size=13)
    seen = sum(len(c) for c in st.chunks())
    assert seen == total == st.stats.n_arrivals
    assert st.stats.peak_resident_arrivals <= 13
    with pytest.raises(ValueError, match="chunk_size"):
        ListTraceStream(tr, chunk_size=0)
