"""Multi-tenant fleet under Azure-statistics traffic: the paper's §4.5 case study as
a runnable scenario — 10 endpoints, one shared image, trace-driven cold/warm starts,
with live memory accounting vs the Prebaking alternative.

Two runs of the same workload:

  1. **live replay** — real cold/warm starts against the live Dependency-
     Manager pool (actual page migration, actual memory);
  2. **simulated twin** — the checked-in declarative spec
     ``benchmarks/scenarios/multi_tenant.json`` through the one
     ``repro.core.scenario.run()`` entry point, so the measured replay and
     the model share a workload definition.

    PYTHONPATH=src python examples/multi_tenant_fleet.py [--hours 4]
"""
import argparse
import os
import tempfile

from repro.core import (
    ColdStartConfig,
    ColdStartOrchestrator,
    DependencyManager,
    FunctionRegistry,
    KeepAlivePolicy,
)
from repro.core import workloads as wl
from repro.core.scenario import Scenario, run as run_scenario
from repro.core.traces import generate_traces

SPEC = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                    "scenarios", "multi_tenant.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hours", type=float, default=4.0)
    ap.add_argument("--tenants", type=int, default=10)
    args = ap.parse_args()

    tmp = tempfile.mkdtemp(prefix="warmswap-fleet-")
    mgr = DependencyManager(disk_dir=f"{tmp}/pool")
    reg = FunctionRegistry(store_dir=f"{tmp}/store")
    image_id = "model-tiny"
    builder = wl.model_params_builder(image_id)
    execs = wl.make_model_executables(image_id)
    wl.warm_executables(execs, builder(), image_id)
    mgr.register_image(image_id, image_id, builder, executables=execs)
    w = wl.WORKLOADS["lr_serving"]
    for i in range(args.tenants):
        reg.register(f"fn-{i}", image_id, wl._head_builder(image_id, seed=i),
                     w.handler_fn, base_params_builder=builder)
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig())

    # trace-driven replay: real cold/warm starts against the live pool
    horizon = args.hours * 60
    traces = generate_traces(args.tenants, horizon_min=horizon, seed=0,
                             rates=[0.02 + 0.05 * i for i in range(args.tenants)])
    keep = KeepAlivePolicy(15.0)
    instances, expiry = {}, {}
    events = sorted((t_min, tr.fn_index) for tr in traces
                    for t_min in tr.arrivals_min)
    cold = warm = 0
    cold_s = warm_s = 0.0
    for t_min, fi in events:
        fn = f"fn-{fi}"
        if fn in instances and t_min <= expiry[fn]:
            _, dt = instances[fn].invoke(w.request_builder())
            warm += 1
            warm_s += dt
        else:
            inst, t = orch.cold_start_warmswap(fn)
            instances[fn] = inst
            cold += 1
            cold_s += t.total
        expiry[fn] = t_min + keep.keep_alive_min

    prebake_bytes = args.tenants * mgr.pool_bytes()  # what Prebaking would pin
    print(f"[fleet] {len(events)} invocations over {args.hours:.1f}h: "
          f"{cold} cold ({cold_s/max(cold,1)*1e3:.0f}ms avg), "
          f"{warm} warm ({warm_s/max(warm,1)*1e3:.1f}ms avg)")
    print(f"[fleet] pool memory: {mgr.pool_bytes()/1e6:.1f} MB shared by "
          f"{args.tenants} tenants (prebaking would pin "
          f"{prebake_bytes/1e6:.0f} MB -> "
          f"{(1 - mgr.pool_bytes()/prebake_bytes)*100:.0f}% saved)")
    print(f"[fleet] image initialized {mgr.stats.builds} time(s)")

    # --- the simulated twin: same workload as a declarative scenario spec ------
    scn = Scenario.from_file(SPEC)
    if args.hours * 60 != scn.traces.kwargs["horizon_min"] or \
            args.tenants != scn.traces.kwargs["n_functions"]:
        scn = scn.with_overrides({
            "traces.kwargs.horizon_min": args.hours * 60,
            "traces.kwargs.n_functions": args.tenants,
            "traces.kwargs.rates": [0.02 + 0.05 * i
                                    for i in range(args.tenants)]})
    res = run_scenario(scn)
    sim_w = res.methods["warmswap"]
    print(f"[sim]   scenario twin ({os.path.basename(SPEC)}): "
          f"{sim_w.n_cold} cold / {sim_w.n_warm} warm, "
          f"avg {sim_w.avg_latency_s * 1e3:.0f} ms | memory saving vs "
          f"prebaking {res.summary['memory_saving_vs_prebaking'] * 100:.0f} % "
          f"(paper: 88 %)")


if __name__ == "__main__":
    main()
