"""Jitted public wrapper for the diagonal-recurrence kernel."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro.kernels.diag_recurrence.kernel import diag_recurrence_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def diag_recurrence(
    a: jax.Array, b: jax.Array, h0: jax.Array,
    *, chunk: int = 128, block_c: int = 2048, interpret=None,
) -> Tuple[jax.Array, jax.Array]:
    interp = (not _on_tpu()) if interpret is None else interpret
    return diag_recurrence_pallas(a, b, h0, chunk=chunk, block_c=block_c,
                                  interpret=interp)
