"""Project-specific scoping for repro-lint: which trees each checker walks
and which call sites are *declared* configuration entry points.

The determinism contract (docs/SIMULATION.md) binds the simulation path —
engines, experiment plumbing, traces, benches, examples — not the live
serving/model stack, which legitimately reads wall clocks and env vars. The
scopes below encode that boundary once, so checkers don't grow per-file
carve-outs; point sanctions inside scoped code use inline
``# repro-lint: allow[rule]`` pragmas instead (docs/ANALYSIS.md).
"""
from __future__ import annotations

from typing import Set, Tuple

#: Trees the determinism checker walks: every module whose behavior must be
#: a pure function of (spec, seed). ``src/repro/core/`` includes
#: ``traces.py`` and both fleet engines.
DETERMINISM_SCOPE: Tuple[str, ...] = (
    "src/repro/core/",
    "src/repro/experiments/",
    "benchmarks/",
    "examples/",
)

#: Trees the shared-state checker walks — the determinism scope plus the
#: concurrent serving/runtime layers (where a shared mutable default is a
#: cross-thread bug, the PR-1 class) and the analyzer itself.
SHARED_STATE_SCOPE: Tuple[str, ...] = DETERMINISM_SCOPE + (
    "src/repro/serving/",
    "src/repro/runtime/",
    "tools/",
)

#: The *declared* environment entry points: ``(repo-relative path, function
#: name)`` pairs that are allowed to read/write ``os.environ``. Everything
#: else in the determinism scope must take configuration through a spec or
#: an argument. Keep this list short — each entry is a documented knob:
#:   * ``set_smoke``/``smoke_mode`` — the ONE smoke-scale switch
#:     (benchmarks/common.py; docs/API.md);
#:   * ``_scan_enabled`` — the REPRO_FLEET_VEC_SCAN opt-in for the jitted
#:     scan path (docs/SIMULATION.md, "Vectorized engine").
SANCTIONED_ENVIRON: Set[Tuple[str, str]] = {
    ("benchmarks/common.py", "set_smoke"),
    ("benchmarks/common.py", "smoke_mode"),
    ("src/repro/core/fleet_vec.py", "_scan_enabled"),
}

#: Wall-clock readers that are fine anywhere: monotonic *interval* timers
#: used by benches and the live manager's stats. ``time.time`` /
#: ``datetime.now`` / ``time.monotonic`` are NOT here — absolute clocks
#: leak into simulated state; sanction individual live-side sites with
#: ``# repro-lint: allow[wall-clock]``.
SANCTIONED_TIMERS: Set[str] = {
    "perf_counter", "perf_counter_ns", "process_time", "process_time_ns",
}

#: Scenario component field -> kwargs the runtime injects when building it
#: (``run()`` passes the resolved cost model into page-cost factories), so
#: the spec checker doesn't demand them from the JSON.
SPEC_INJECTED_KWARGS = {
    "page_cost": {"cost"},
    "disruption": {"n_workers", "horizon_min"},
}


def in_scope(rel: str, scope: Tuple[str, ...]) -> bool:
    """True when repo-relative ``rel`` lives under one of ``scope``'s trees."""
    return any(rel == s or rel.startswith(s) for s in scope)
