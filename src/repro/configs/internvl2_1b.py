"""internvl2-1b [vlm] — InternViT frontend (STUB) + qwen2-0.5b-class LM backbone.

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655, head_dim=64.
[arXiv:2404.16821; hf]. Per the assignment the vision frontend is a stub:
``input_specs()`` supplies precomputed (batch, n_patches, d_model) patch embeddings
prepended to the token embeddings.
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    head_dim=64,
    attn_pattern=(GLOBAL_ATTN,),
    qkv_bias=True,
    mlp="swiglu",
    frontend="vision_patches",
    n_frontend_tokens=256,   # one 448x448 tile -> 256 visual tokens after pixel-shuffle
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
