"""Pure-jnp oracle for the flash-attention kernel (same contract, naive compute)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(
    q: jax.Array,            # (B, H, Sq, d)
    k: jax.Array,            # (B, Hkv, Sk, d)
    v: jax.Array,            # (B, Hkv, Sk, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(B, Hkv, g, Sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= (qi - ki) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(B, H, Sq, d).astype(q.dtype)
