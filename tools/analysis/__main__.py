"""repro-lint CLI.

    python -m tools.analysis [options] paths...

    --baseline FILE      diff findings against FILE (default:
                         tools/analysis/baseline.json); grandfathered
                         findings pass, new ones exit 1
    --no-baseline        ignore the baseline (every finding is new)
    --write-baseline     rewrite the baseline from the current findings
                         (use after an audited grandfathering decision)
    --json FILE          write the machine-readable findings artifact
    --fix-suggestions    print a suggested fix under each finding
    --checkers a,b       run a subset (determinism, lock-discipline,
                         shared-state, float-determinism, spec-registry,
                         contract, counter-flow, pragma)

Exit status: 0 = no new findings, 1 = new findings, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterable, List, Optional, Sequence

from tools.analysis import (contract, counter_flow, determinism,
                            float_determinism, locks, shared_state, specs)
from tools.analysis.base import (REPO_ROOT, SourceFile, collect_files,
                                 rel_path)
from tools.analysis.findings import (Finding, diff_baseline, findings_json,
                                     load_baseline, load_baseline_entries,
                                     stale_baseline_findings, write_baseline)

#: name -> module for the AST (``.py``) checkers.
PY_CHECKERS = {
    determinism.CHECKER: determinism,
    locks.CHECKER: locks,
    shared_state.CHECKER: shared_state,
    float_determinism.CHECKER: float_determinism,
}
#: name -> module for the repo-level contract checkers: they verify the
#: docs-as-spec contracts of fixed in-tree targets, so they run once per
#: invocation (when selected), independent of the CLI paths.
REPO_CHECKERS = {
    contract.CHECKER: contract,
    counter_flow.CHECKER: counter_flow,
}
#: The stale-pragma pseudo-checker (emitted by ``run_analysis`` itself when
#: every AST checker ran, so "unused" is actually meaningful).
PRAGMA_CHECKER = "pragma"
ALL_CHECKERS = (tuple(PY_CHECKERS) + (specs.CHECKER,)
                + tuple(REPO_CHECKERS) + (PRAGMA_CHECKER,))

DEFAULT_BASELINE = os.path.join(REPO_ROOT, "tools", "analysis",
                                "baseline.json")


def run_analysis(paths: Iterable[str],
                 checkers: Optional[Sequence[str]] = None) -> List[Finding]:
    """All findings from ``checkers`` (default: all) over ``paths``, sorted
    by (path, line, col, rule)."""
    selected = list(checkers) if checkers else list(ALL_CHECKERS)
    unknown = [c for c in selected if c not in ALL_CHECKERS]
    if unknown:
        raise ValueError(f"unknown checker(s) {unknown} "
                         f"(choose from {list(ALL_CHECKERS)})")
    py_files, json_files = collect_files(paths)
    # stale-pragma detection needs every AST checker's suppression hits —
    # after a subset run, "unused" would just mean "not checked"
    all_ast_ran = set(PY_CHECKERS) <= set(selected)
    findings: List[Finding] = []
    for path in py_files:
        try:
            src = SourceFile.parse(path)
        except SyntaxError as e:
            findings.append(Finding("parse", "syntax-error",
                                    os.path.relpath(path, REPO_ROOT),
                                    e.lineno or 1, e.offset or 0, str(e)))
            continue
        for name in selected:
            mod = PY_CHECKERS.get(name)
            if mod is not None:
                findings.extend(mod.check(src))
        if PRAGMA_CHECKER in selected and all_ast_ran:
            for line, rule in src.stale_pragmas():
                findings.append(Finding(
                    PRAGMA_CHECKER, "stale-pragma", src.rel, line, 0,
                    f"pragma allows '{rule}' but suppresses no finding — "
                    f"dead suppressions are how grandfathered bugs hide",
                    snippet=src.line(line).strip(),
                    suggestion="delete the stale pragma (the violation it "
                               "sanctioned is gone)"))
    if specs.CHECKER in selected:
        for path in json_files:
            findings.extend(specs.check_file(path))
    for name, mod in REPO_CHECKERS.items():
        if name in selected:
            findings.extend(mod.check_repo())
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.checker, f.rule))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repro-lint: project-specific static analysis "
                    "(docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="+", help="files or directories to check")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file to diff against (default: "
                         "tools/analysis/baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="write the findings artifact to this path")
    ap.add_argument("--fix-suggestions", action="store_true",
                    help="print a suggested fix under each finding")
    ap.add_argument("--checkers", default=None,
                    help=f"comma-separated subset of {list(ALL_CHECKERS)}")
    args = ap.parse_args(argv)

    checkers = ([c.strip() for c in args.checkers.split(",") if c.strip()]
                if args.checkers else None)
    try:
        findings = run_analysis(args.paths, checkers)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"baseline: {len(findings)} finding(s) grandfathered into "
              f"{os.path.relpath(args.baseline, REPO_ROOT)}")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, old = diff_baseline(findings, baseline)

    if not args.no_baseline:
        # a grandfathered fingerprint nothing consumes is a dead suppression
        py_files, json_files = collect_files(args.paths)
        scanned = {rel_path(p) for p in py_files + json_files}
        stale = stale_baseline_findings(load_baseline_entries(args.baseline),
                                        findings, scanned)
        findings.extend(stale)
        new.extend(stale)
        findings.sort(key=lambda f: (f.path, f.line, f.col, f.checker,
                                     f.rule))
        new.sort(key=lambda f: (f.path, f.line, f.col, f.checker, f.rule))

    for f in new:
        print(f.render(args.fix_suggestions))
    if args.json_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.json_out)),
                    exist_ok=True)
        with open(args.json_out, "w") as fh:
            json.dump(findings_json(findings, new, old), fh, indent=1)
            fh.write("\n")

    print(f"repro-lint: {len(findings)} finding(s), {len(old)} baselined, "
          f"{len(new)} new")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
