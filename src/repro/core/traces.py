"""Azure-like invocation traces (paper §2.2 / §4.5, Shahrad et al. [22]).

The Azure Functions dataset is not redistributable here, so we generate traces with
the *published summary statistics* the paper relies on:

  * extremely skewed per-function invocation rates — >50 % of functions below
    0.001 calls/min; 75th percentile ≈ 0.04 calls/min (paper §4.5);
  * Poisson arrivals per function (the paper's exponential-gap model, Eq. 1).

Rates are sampled from a lognormal fitted to those two quantiles:
    median = 0.001/min  and  P75 = 0.04/min
    => mu = ln(0.001), sigma = (ln 0.04 − ln 0.001) / z_{0.75}, z_{0.75} = 0.6745.

A loader for the real Azure CSV schema is included for environments that have it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.registry import Registry

MEDIAN_RATE = 0.001      # calls/min (paper §2.2: >50 % below this)
P75_RATE = 0.04          # calls/min (paper §4.5)
_Z75 = 0.674489750196

#: Name -> trace generator (a callable returning ``List[Trace]``). Scenario
#: specs address trace sources by key with per-generator kwargs; new sources
#: self-register with ``@TRACE_GENERATORS.register("name")``.
TRACE_GENERATORS = Registry("trace generator")


@dataclass
class Trace:
    fn_index: int
    rate_per_min: float
    arrivals_min: np.ndarray   # sorted invocation times in minutes
    image_id: int = 0          # dependency image this function runs on


def sample_rates(n: int, seed: int = 0) -> np.ndarray:
    mu = math.log(MEDIAN_RATE)
    sigma = (math.log(P75_RATE) - math.log(MEDIAN_RATE)) / _Z75
    rng = np.random.default_rng(seed)
    return np.exp(rng.normal(mu, sigma, size=n))


def poisson_arrivals(rate_per_min: float, horizon_min: float,
                     rng: np.random.Generator) -> np.ndarray:
    if rate_per_min <= 0:
        return np.empty((0,), np.float64)
    n_expected = rate_per_min * horizon_min
    n = rng.poisson(n_expected)
    return np.sort(rng.uniform(0.0, horizon_min, size=n), kind="stable")


def poisson_arrivals_batched(rates: Sequence[float], horizon_min: float,
                             rng: np.random.Generator, *,
                             sorted: bool = True) -> List[np.ndarray]:
    """Per-function Poisson arrival arrays for ALL rates in three vectorized
    draws (counts, then one uniform fill, then per-segment sorts) instead of
    two RNG calls per function — the production-scale path for traces with
    10^5+ functions or 10^6+ invocations.

    Deterministic given ``rng``'s state, but the stream *interleaving* differs
    from per-function :func:`poisson_arrivals` calls (all counts are drawn
    before any arrival times), so for one seed the batched and unbatched
    arrival values differ; each is reproducible on its own. See
    docs/SIMULATION.md.

    ``sorted=False`` skips the per-segment sorts and returns each function's
    arrivals in raw draw order — the same multiset of times, cheaper at
    production scale. Both fleet engines normalize with one global stable
    argsort over the merged stream, so they accept either ordering and
    produce identical results for it (pinned by tests/test_traces_order.py);
    ``Trace.arrivals_min`` is documented as sorted, so unsorted arrays are
    for engine-level consumers only.
    """
    rates = np.asarray(rates, np.float64)
    counts = rng.poisson(np.maximum(rates, 0.0) * horizon_min)
    counts[rates <= 0] = 0
    flat = rng.uniform(0.0, horizon_min, size=int(counts.sum()))
    segs = np.split(flat, np.cumsum(counts)[:-1])
    return [np.sort(seg, kind="stable") for seg in segs] if sorted else segs


@TRACE_GENERATORS.register("azure")
def generate_traces(n_functions: int, horizon_min: float = 2 * 7 * 24 * 60,
                    seed: int = 0,
                    rates: Optional[Sequence[float]] = None,
                    batched: bool = False) -> List[Trace]:
    """Default horizon: two weeks, as in the paper's case study (§4.5).

    ``batched=True`` draws all functions' arrivals in a few vectorized RNG
    passes (:func:`poisson_arrivals_batched`) — same statistics, different
    stream interleaving, so the per-seed values differ from the default
    per-function draws; use it for production-scale traces."""
    rng = np.random.default_rng(seed + 1)
    if rates is None:
        rates = sample_rates(n_functions, seed)
    if batched:
        arrivals = poisson_arrivals_batched(rates, horizon_min, rng)
        return [Trace(i, float(r), a)
                for i, (r, a) in enumerate(zip(rates, arrivals))]
    return [Trace(i, float(r), poisson_arrivals(float(r), horizon_min, rng))
            for i, r in enumerate(rates)]


def zipf_weights(n: int, s: float) -> np.ndarray:
    """Normalized Zipf(s) weights over ranks 1..n (s=0 -> uniform)."""
    w = np.arange(1, n + 1, dtype=np.float64) ** (-s)
    return w / w.sum()


def assign_images(n_functions: int, n_images: int, skew: float = 1.2,
                  seed: int = 0) -> np.ndarray:
    """Function -> dependency-image mapping with Zipf-skewed image popularity.

    With skew > 0 a few images are shared by many functions (the regime the
    paper's 88 %-saving headline lives in); skew = 0 spreads functions evenly.
    Every image gets at least one function when n_functions >= n_images, so the
    requested sharing degree is real rather than probabilistic."""
    if n_images <= 1:
        return np.zeros(n_functions, np.int64)
    rng = np.random.default_rng(seed + 7)
    out = np.empty(n_functions, np.int64)
    head = min(n_images, n_functions)
    out[:head] = np.arange(head)                      # coverage guarantee
    if n_functions > head:
        out[head:] = rng.choice(n_images, size=n_functions - head,
                                p=zipf_weights(n_images, skew))
    rng.shuffle(out)
    return out


@TRACE_GENERATORS.register("fleet")
def generate_fleet_traces(
    n_functions: int,
    horizon_min: float = 2 * 7 * 24 * 60,
    seed: int = 0,
    n_images: int = 1,
    image_skew: float = 1.2,
    rate_model: str = "azure",        # 'azure' (lognormal §4.5) | 'zipf'
    rate_skew: float = 1.1,           # Zipf exponent when rate_model='zipf'
    total_rate_per_min: float = 1.0,  # fleet-wide rate when rate_model='zipf'
    batched: bool = False,            # vectorized arrival draws (see below)
) -> List[Trace]:
    """Synthetic skewed fleet workload: Azure-statistics (or Zipf-ranked)
    per-function rates plus a Zipf-skewed function->image mapping.

    ``batched=True`` draws all arrivals via
    :func:`poisson_arrivals_batched` — the production-scale path
    (million-invocation traces in well under a second). Same statistics,
    different RNG stream interleaving than the per-function default, so
    per-seed arrival values differ between the two modes; each mode is
    deterministic given ``seed``."""
    if rate_model == "azure":
        rates = sample_rates(n_functions, seed)
    elif rate_model == "zipf":
        rates = total_rate_per_min * zipf_weights(n_functions, rate_skew)
    else:
        raise ValueError(f"unknown rate_model: {rate_model!r}")
    images = assign_images(n_functions, n_images, image_skew, seed)
    rng = np.random.default_rng(seed + 1)
    if batched:
        arrivals = poisson_arrivals_batched(rates, horizon_min, rng)
        return [Trace(i, float(r), a, image_id=int(images[i]))
                for i, (r, a) in enumerate(zip(rates, arrivals))]
    return [Trace(i, float(r), poisson_arrivals(float(r), horizon_min, rng),
                  image_id=int(images[i]))
            for i, r in enumerate(rates)]


def sharing_degrees(traces: List[Trace]) -> dict:
    """image_id -> number of functions sharing that image."""
    out: dict = {}
    for t in traces:
        out[t.image_id] = out.get(t.image_id, 0) + 1
    return out


def quartile_groups(traces: List[Trace]) -> dict:
    """Paper Fig. 7 grouping: quartiles by invocation rate."""
    rates = np.array([t.rate_per_min for t in traces])
    qs = np.quantile(rates, [0.25, 0.5, 0.75])
    groups = {"lowest": [], "25-50%": [], "50-75%": [], "highest": []}
    for t in traces:
        if t.rate_per_min <= qs[0]:
            groups["lowest"].append(t)
        elif t.rate_per_min <= qs[1]:
            groups["25-50%"].append(t)
        elif t.rate_per_min <= qs[2]:
            groups["50-75%"].append(t)
        else:
            groups["highest"].append(t)
    return groups


# The Azure CSV reader and the streaming/adversarial generators (azure_csv,
# diurnal, bursts, tenant_mix, rollout) live in core/trace_stream.py and
# self-register into TRACE_GENERATORS when that module loads; this bottom
# import makes `import repro.core.traces` alone populate the full registry.
# (trace_stream imports this module's names, all defined above, so the
# circular import is resolved by the time registration runs.)
from repro.core import trace_stream as _trace_stream  # noqa: E402,F401
