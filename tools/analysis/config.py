"""Project-specific scoping for repro-lint: which trees each checker walks
and which call sites are *declared* configuration entry points.

The determinism contract (docs/SIMULATION.md) binds the simulation path —
engines, experiment plumbing, traces, benches, examples — not the live
serving/model stack, which legitimately reads wall clocks and env vars. The
scopes below encode that boundary once, so checkers don't grow per-file
carve-outs; point sanctions inside scoped code use inline
``# repro-lint: allow[rule]`` pragmas instead (docs/ANALYSIS.md).
"""
from __future__ import annotations

from typing import Dict, Set, Tuple

#: Trees the determinism checker walks: every module whose behavior must be
#: a pure function of (spec, seed). ``src/repro/core/`` includes
#: ``traces.py`` and both fleet engines. ``tools/`` self-hosts: the
#: analyzers and CI gates obey the same rules they enforce.
DETERMINISM_SCOPE: Tuple[str, ...] = (
    "src/repro/core/",
    "src/repro/experiments/",
    "benchmarks/",
    "examples/",
    "tools/",
)

#: Trees the float-determinism checker walks: code shared by the scalar and
#: vectorized engines, where an order-sensitive reduction (unstable sort,
#: accumulation over a set) silently breaks the bit-identity contract
#: (docs/SIMULATION.md, "Vectorized engine").
FLOAT_DETERMINISM_SCOPE: Tuple[str, ...] = (
    "src/repro/core/",
    "src/repro/experiments/",
)

#: Trees the shared-state checker walks — the determinism scope plus the
#: concurrent serving/runtime layers (where a shared mutable default is a
#: cross-thread bug, the PR-1 class) and the analyzer itself.
SHARED_STATE_SCOPE: Tuple[str, ...] = DETERMINISM_SCOPE + (
    "src/repro/serving/",
    "src/repro/runtime/",
    "tests/",
)

#: The *declared* environment entry points: ``(repo-relative path, function
#: name)`` pairs that are allowed to read/write ``os.environ``. Everything
#: else in the determinism scope must take configuration through a spec or
#: an argument. Keep this list short — each entry is a documented knob:
#:   * ``set_smoke``/``smoke_mode`` — the ONE smoke-scale switch
#:     (benchmarks/common.py; docs/API.md);
#:   * ``_scan_enabled`` — the REPRO_FLEET_VEC_SCAN opt-in for the jitted
#:     scan path (docs/SIMULATION.md, "Vectorized engine").
#:   * ``sanitize_enabled`` — the REPRO_SANITIZE opt-in for the runtime
#:     invariant sanitizer (docs/ANALYSIS.md, "Runtime sanitizer").
SANCTIONED_ENVIRON: Set[Tuple[str, str]] = {
    ("benchmarks/common.py", "set_smoke"),
    ("benchmarks/common.py", "smoke_mode"),
    ("src/repro/core/fleet_vec.py", "_scan_enabled"),
    ("src/repro/core/sanitize.py", "sanitize_enabled"),
    # CI output channel, not configuration: GITHUB_STEP_SUMMARY is where the
    # trend gate mirrors its markdown table — it never influences results
    ("tools/ci/check_trend.py", "_emit"),
}

#: Wall-clock readers that are fine anywhere: monotonic *interval* timers
#: used by benches and the live manager's stats. ``time.time`` /
#: ``datetime.now`` / ``time.monotonic`` are NOT here — absolute clocks
#: leak into simulated state; sanction individual live-side sites with an
#: ``allow[wall-clock]`` pragma.
SANCTIONED_TIMERS: Set[str] = {
    "perf_counter", "perf_counter_ns", "process_time", "process_time_ns",
}

#: Scenario component field -> kwargs the runtime injects when building it
#: (``run()`` passes the resolved cost model into page-cost factories), so
#: the spec checker doesn't demand them from the JSON.
SPEC_INJECTED_KWARGS = {
    "page_cost": {"cost"},
    "disruption": {"n_workers", "horizon_min"},
}

#: The declared conservation laws of the fleet engines (docs/SIMULATION.md,
#: "Counter accounting"). Every ``FleetResult`` counter must cite one; the
#: ``counter-flow`` checker fails on a counter with no law, and the runtime
#: sanitizer asserts the checkable ones per run.
COUNTER_LAWS: Dict[str, str] = {
    "service-conservation":
        "n_invocations <= n_cold + n_warm <= n_invocations + requeued "
        "(strict equality with n_invocations when requeued == 0)",
    "latency-accounting":
        "total_latency_s == sum(latency_samples_s); every sample is "
        "wait + service with wait >= 0 (Lindley nonnegativity)",
    "queue-accounting":
        "n_queued == count(queue_wait_s > 0); "
        "queue_delay_s == sum(queue_wait_s)",
    "cold-start-accounting":
        "pool_misses counts cold starts that paid an image revive; "
        "pool_misses <= n_cold + requeued",
    "cache-tier-accounting":
        "each page-model cold start hits exactly one of "
        "local | remote | miss; all tiers are zero without a page model",
    "prewarm-accounting":
        "prewarm_hits <= prewarm_spawns; dropped spawns (past the trace "
        "horizon) never become instances",
    "placement-accounting":
        "placement_warm_hits + placement_pool_hits <= service starts "
        "(n_cold + n_warm)",
    "ledger-books":
        "eviction counters only ever grow; ledger tracked bytes == "
        "sum of entry bytes at every step (sanitizer books-balance)",
    "disruption-accounting":
        "exactly one increment per applied disruption event "
        "(worker_fail / worker_recover / cache_flush)",
    "page-volume":
        "pages_transferred counts pages moved over remote + source links "
        "only (local memcpy is free)",
    "peak-tracking":
        "high-water mark: monotone under max(), equals the largest "
        "instantaneous value observed during the drain",
    "residency-accounting":
        "instance_resident_min == sum of per-instance resident windows, "
        "each clamped to the trace horizon",
}

#: ``FleetResult`` counter -> (conservation law, unified-result projection).
#: The projection is the ``MethodResult`` field the counter surfaces through
#: (dotted for dict-valued fields, e.g. ``cache_hits.local``). The
#: ``counter-flow`` checker verifies every counter here is (a) written by
#: the event engine, (b) covered by a declared law, and (c) actually
#: projected by ``scenario._method_result`` — a dropped increment or an
#: un-projected counter is a finding.
FLEET_COUNTERS: Dict[str, Tuple[str, str]] = {
    "n_invocations": ("service-conservation", "n_invocations"),
    "n_cold": ("service-conservation", "n_cold"),
    "n_warm": ("service-conservation", "n_warm"),
    "requeued": ("service-conservation", "requeued"),
    "total_latency_s": ("latency-accounting", "total_latency_s"),
    "n_queued": ("queue-accounting", "n_queued"),
    "queue_delay_s": ("queue-accounting", "queue_delay_s"),
    "pool_misses": ("cold-start-accounting", "pool_misses"),
    "cache_local_hits": ("cache-tier-accounting", "cache_hits.local"),
    "cache_remote_hits": ("cache-tier-accounting", "cache_hits.remote"),
    "cache_misses": ("cache-tier-accounting", "cache_hits.miss"),
    "prewarm_spawns": ("prewarm-accounting", "prewarm_spawns"),
    "prewarm_hits": ("prewarm-accounting", "prewarm_hits"),
    "prewarm_dropped": ("prewarm-accounting", "prewarm_dropped"),
    "placement_warm_hits": ("placement-accounting", "placement_warm_hits"),
    "placement_pool_hits": ("placement-accounting", "placement_pool_hits"),
    "evictions": ("ledger-books", "evictions"),
    "shared_cache_evictions": ("ledger-books", "shared_cache_evictions"),
    "worker_failures": ("disruption-accounting", "worker_failures"),
    "worker_recoveries": ("disruption-accounting", "worker_recoveries"),
    "cache_flushes": ("disruption-accounting", "cache_flushes"),
    "pages_transferred": ("page-volume", "pages_transferred"),
    "memory_bytes": ("peak-tracking", "memory_bytes"),
    "max_concurrent_instances": ("peak-tracking",
                                 "max_concurrent_instances"),
    "shared_cache_peak_bytes": ("peak-tracking", "shared_cache_peak_bytes"),
    "instance_resident_min": ("residency-accounting",
                              "instance_resident_min"),
}

#: ``FleetResult`` fields that are *not* counters: identity, shape echo,
#: sample arrays, and per-entity breakdowns. Writes to these need no
#: conservation law; writes to anything outside this set and
#: ``FLEET_COUNTERS`` are undeclared (a ``counter-flow`` finding).
FLEET_RESULT_STATE: Set[str] = {
    "method", "n_workers", "horizon_min",
    "latency_samples_s", "queue_wait_s", "sample_fn",
    "per_fn_latency", "per_fn_invocations", "per_worker",
}


def in_scope(rel: str, scope: Tuple[str, ...]) -> bool:
    """True when repo-relative ``rel`` lives under one of ``scope``'s trees."""
    return any(rel == s or rel.startswith(s) for s in scope)
