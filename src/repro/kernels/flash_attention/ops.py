"""Jitted public wrapper for the flash-attention kernel.

``flash_attention(...)`` dispatches to the Pallas kernel on TPU and to interpret mode
elsewhere (this container is CPU-only; interpret mode executes the kernel body
faithfully for validation). The reference semantics live in ``ref.py``.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,            # (B, H, Sq, d)
    k: jax.Array,            # (B, Hkv, Sk, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interp)
