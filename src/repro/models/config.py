"""Architecture configuration system.

Every assigned architecture (plus the paper-workload analogues and reduced smoke
variants) is expressed as an :class:`ArchConfig`. The model code in this package is
written against this single config type, so a new architecture is a new config file,
not new model code.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple


# Layer-type tags used in ``attn_pattern`` (the repeating temporal-mixing unit).
GLOBAL_ATTN = "global"      # full causal attention
LOCAL_ATTN = "local"        # sliding-window causal attention
RECURRENT = "recurrent"     # RG-LRU block (Griffin / recurrentgemma)
SSM = "ssm"                 # Mamba-1 selective-scan block


@dataclass(frozen=True)
class ArchConfig:
    """A complete architecture description.

    ``attn_pattern`` is the repeating unit of temporal-mixing layer types; the model
    applies ``n_layers`` layers by cycling the pattern (remainder layers allowed, e.g.
    recurrentgemma's 26 = 8x(R,R,A) + (R,R)). Scan-over-layers stacks parameters per
    pattern *unit*, keeping the lowered HLO size independent of depth.
    """

    name: str
    family: str                     # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    attn_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)
    window: int = 4096              # sliding-window size for LOCAL_ATTN layers
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    qk_norm: bool = False           # qwen3: RMSNorm on per-head q,k
    qkv_bias: bool = False          # qwen1.5: bias on qkv projections
    mlp: str = "swiglu"             # swiglu | geglu | gelu (plain 2-matrix MLP)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    expert_pad_to: int = 0          # pad expert tensors so EP shards evenly (perf
                                    # iteration B, EXPERIMENTS.md §Perf); 0 = off

    # SSM (Mamba-1)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None   # defaults to ceil(d_model / 16)

    # Hybrid (RG-LRU / Griffin)
    lru_width: Optional[int] = None  # defaults to d_model
    conv1d_width: int = 4

    # Encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    n_enc_positions: int = 1500     # whisper: 1500 audio frames after conv frontend

    # Modality frontend stubs ([audio]/[vlm]: input_specs supplies embeddings)
    frontend: Optional[str] = None  # None | 'audio_frames' | 'vision_patches'
    n_frontend_tokens: int = 0      # prepended embedding tokens for vlm

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    emb_scale: bool = False         # gemma-style sqrt(d_model) embedding scaling
    max_seq_len: int = 1 << 20      # positions supported structurally

    # ---- derived sizes -------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank if self.dt_rank is not None else math.ceil(self.d_model / 16)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width if self.lru_width is not None else self.d_model

    @property
    def n_experts_padded(self) -> int:
        import os
        if os.environ.get("REPRO_PERF_BASELINE", "") == "1":
            return self.n_experts
        return max(self.n_experts, self.expert_pad_to)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.expand * self.d_model

    @property
    def n_pattern_units(self) -> int:
        return self.n_layers // len(self.attn_pattern)

    @property
    def n_remainder_layers(self) -> int:
        return self.n_layers - self.n_pattern_units * len(self.attn_pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(t in (SSM, RECURRENT) for t in self.attn_pattern)

    @property
    def has_bounded_kv(self) -> bool:
        """True when no layer keeps an unbounded (full-sequence) KV cache."""
        return all(t != GLOBAL_ATTN for t in self.attn_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs eligible for the ``long_500k`` shape.

        Per DESIGN.md §4: SSM / hybrid / SWA archs qualify; gemma2's alternating
        local/global also qualifies (decode is O(1) per token per local layer and
        O(seq) only on global layers, with the sharded cache fitting the pod).
        """
        if self.is_encoder_decoder:
            return False
        return any(t in (SSM, RECURRENT, LOCAL_ATTN) for t in self.attn_pattern)

    # ---- parameter counting (for roofline MODEL_FLOPS and pool accounting) ---
    def param_count(self, *, include_embeddings: bool = True) -> int:
        d, h = self.d_model, self.resolved_head_dim
        total = 0
        per_type = {}
        # temporal-mixing layer params by type
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * h
        per_type[GLOBAL_ATTN] = attn
        per_type[LOCAL_ATTN] = attn
        di = self.d_inner
        per_type[SSM] = (
            d * 2 * di                      # in_proj
            + di * self.d_conv              # depthwise conv
            + di * (self.resolved_dt_rank + 2 * self.ssm_state)  # x_proj
            + self.resolved_dt_rank * di + di                    # dt_proj
            + di * self.ssm_state + di      # A_log, D
            + di * d                        # out_proj
        )
        w = self.resolved_lru_width
        per_type[RECURRENT] = (
            2 * d * w                       # linear_x, linear_y branch
            + w * self.conv1d_width         # conv1d
            + 2 * w                         # RG-LRU a-param, input-gate... (diag)
            + 2 * w * w // 1                # gates (approx: input & recurrence gates are diag-block; use w each)
            + w * d                         # out proj
        )
        # MLP params per layer
        if self.n_experts > 0:
            mlp = self.n_experts * (3 if self.mlp in ("swiglu", "geglu") else 2) * d * self.d_ff
            mlp += d * self.n_experts       # router
        else:
            mlp = (3 if self.mlp in ("swiglu", "geglu") else 2) * d * self.d_ff
        for i in range(self.n_layers):
            t = self.attn_pattern[i % len(self.attn_pattern)]
            total += per_type[t]
            if t != SSM:                    # mamba blocks replace attn+mlp together
                total += mlp
            total += 2 * d                  # norms
        if self.is_encoder_decoder:
            enc = self.n_enc_layers * (per_type[GLOBAL_ATTN] + mlp + 2 * d)
            xattn = self.n_layers * per_type[GLOBAL_ATTN]  # cross-attention
            total += enc + xattn
        if include_embeddings:
            total += self.vocab_size * d
            if not self.tie_embeddings:
                total += self.vocab_size * d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count(include_embeddings=False)
        full = self.param_count(include_embeddings=False)
        expert_mlp = (3 if self.mlp in ("swiglu", "geglu") else 2) * self.d_model * self.d_ff
        inactive = (self.n_experts - self.top_k) * expert_mlp * self.n_layers
        return int(full - inactive)

    def validate(self) -> None:
        assert self.n_layers >= len(self.attn_pattern) or self.n_layers > 0
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.is_attention_free
        if self.n_experts:
            assert 0 < self.top_k <= self.n_experts

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family variant for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2 * len(self.attn_pattern)),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            window=min(self.window, 16),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            capacity_factor=4.0,  # no token drops at smoke-test scale

            ssm_state=min(self.ssm_state, 4) if self.ssm_state else 0,
            dt_rank=4 if self.ssm_state else None,
            lru_width=32 if RECURRENT in self.attn_pattern else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_enc_positions=min(self.n_enc_positions, 16),
            n_frontend_tokens=min(self.n_frontend_tokens, 4),
            max_seq_len=1 << 12,
        )
        base.update(overrides)
        out = dataclasses.replace(self, name=self.name + "-reduced", **base)
        out.validate()
        return out


# ---------------------------------------------------------------------------------
# Input shapes assigned to the LM family (assignment: 4 shapes x 10 archs = 40 cells)
# ---------------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
