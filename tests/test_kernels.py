"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies faithfully on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    attention_ref,
    decode_attention,
    decode_attention_ref,
    diag_recurrence,
    diag_recurrence_ref,
    flash_attention,
    page_gather,
    page_gather_ref,
)

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,d,causal,window,cap",
    [
        (2, 4, 2, 256, 64, True, None, None),     # GQA causal
        (1, 4, 4, 128, 64, True, 64, None),       # sliding window
        (2, 2, 1, 200, 32, True, None, 50.0),     # MQA + softcap, ragged seq
        (1, 2, 2, 96, 128, False, None, None),    # non-causal (encoder)
        (1, 8, 2, 320, 64, True, 100, 30.0),      # window + softcap combined
    ],
)
def test_flash_attention_sweep(B, H, Hkv, S, d, causal, window, cap, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,d,cap",
    [(2, 4, 2, 300, 64, None), (1, 8, 1, 512, 128, 50.0), (4, 2, 2, 64, 32, None)],
)
def test_decode_attention_sweep(B, H, Hkv, S, d, cap, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, d), dtype)
    kc = jax.random.normal(ks[1], (B, Hkv, S, d), dtype)
    vc = jax.random.normal(ks[2], (B, Hkv, S, d), dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (S,)).at[0].set(True)
    out = decode_attention(q, kc, vc, valid, softcap=cap, block_k=128)
    ref = decode_attention_ref(q, kc, vc, valid, softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


@pytest.mark.parametrize("B,S,C,chunk,block_c",
                         [(2, 100, 64, 32, 64), (1, 256, 32, 64, 16),
                          (3, 17, 130, 8, 64), (1, 64, 2048, 16, 512)])
def test_diag_recurrence_sweep(B, S, C, chunk, block_c):
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (B, S, C), jnp.float32, 0.5, 1.0)
    b = jax.random.normal(ks[1], (B, S, C), jnp.float32)
    h0 = jax.random.normal(ks[2], (B, C), jnp.float32)
    ha, hf = diag_recurrence(a, b, h0, chunk=chunk, block_c=block_c)
    ra, rf = diag_recurrence_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(ra), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(rf), atol=1e-4, rtol=1e-4)


def test_diag_recurrence_matches_model_scan():
    """The kernel agrees with the model's chunked associative scan too."""
    from repro.models.recurrence import chunked_diag_recurrence
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (2, 50, 24), jnp.float32, 0.3, 1.0)
    b = jax.random.normal(ks[1], (2, 50, 24))
    h0 = jax.random.normal(ks[2], (2, 24))
    ka, kf = diag_recurrence(a, b, h0, chunk=16, block_c=24)
    ma, mf = chunked_diag_recurrence(a, b, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(ka), np.asarray(ma), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(kf), np.asarray(mf), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
@pytest.mark.parametrize("P,E,K", [(64, 256, 20), (16, 128, 16), (8, 512, 1)])
def test_page_gather_sweep(P, E, K, dtype):
    pool = (jax.random.normal(KEY, (P, E)) * 10).astype(dtype)
    ids = jax.random.randint(KEY, (K,), 0, P)
    out = page_gather(pool, ids)
    ref = page_gather_ref(pool, ids)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_flash_attention_matches_model_blockwise():
    """Kernel semantics == the model's jnp blockwise path (the serving oracle)."""
    from repro.models.attention import blockwise_attention
    B, H, Hkv, S, d = 2, 4, 2, 160, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, d))
    k = jax.random.normal(ks[1], (B, Hkv, S, d))
    v = jax.random.normal(ks[2], (B, Hkv, S, d))
    out_kernel = flash_attention(q, k, v, causal=True, window=48, block_q=32,
                                 block_k=32)
    pos = jnp.arange(S, dtype=jnp.int32)
    out_model = blockwise_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        q_positions=pos, k_positions=pos, causal=True, window=48,
        attn_softcap=None, q_chunk=64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=2e-5, rtol=2e-5)
