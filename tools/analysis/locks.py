"""Lock-discipline checker: ``# guarded-by`` annotations, verified at the AST.

The PR-2 BULK-restore race was a classic guarded-state bug: two code paths
touched shared restore state, only one of them under the lock. This checker
makes that contract machine-checked:

* annotate an attribute at its initialization site::

      self._images: Dict[str, Image] = {}   # guarded-by: _lock

* every other read or write of ``self._images`` in that class must then be
  *lexically* inside a ``with self._lock:`` block;
* a helper that is only ever called with the lock already held declares it::

      def _admit(self, img):   # requires-lock: _lock

  its body may touch guarded attributes freely, and in exchange every call
  site of ``self._admit(...)`` must itself hold the lock;
* ``__init__`` is exempt (single-threaded construction happens-before
  publication of the object).

Rules: ``unguarded-access`` (attribute touched without the lock),
``unlocked-call`` (a requires-lock helper invoked without the lock).
Grammar and workflow: docs/ANALYSIS.md.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.base import SourceFile
from tools.analysis.findings import Finding

CHECKER = "lock-discipline"

_GUARDED_BY = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_REQUIRES = re.compile(r"#\s*requires-lock:\s*([A-Za-z_]\w*)")


@dataclass
class _ClassContract:
    guarded: Dict[str, str] = field(default_factory=dict)   # attr -> lock
    requires: Dict[str, str] = field(default_factory=dict)  # method -> lock


def _comment_match(src: SourceFile, regex: re.Pattern,
                   lo: int, hi: int) -> Optional[str]:
    """First ``regex`` group found in the comments of lines [lo, hi]."""
    for n in range(lo, hi + 1):
        m = regex.search(src.line(n))
        if m:
            return m.group(1)
    return None


def _collect_contract(src: SourceFile, cls: ast.ClassDef) -> _ClassContract:
    contract = _ClassContract()
    for method in cls.body:
        if not isinstance(method, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # requires-lock: on the def line(s) or the first body line
        first_body = method.body[0].lineno if method.body else method.lineno
        lock = _comment_match(src, _REQUIRES, method.lineno, first_body)
        if lock:
            contract.requires[method.name] = lock
        for node in ast.walk(method):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        hi = getattr(node, "end_lineno", node.lineno)
                        g = _comment_match(src, _GUARDED_BY, node.lineno, hi)
                        if g:
                            contract.guarded[t.attr] = g
    return contract


def _with_locks(item: ast.withitem) -> Optional[str]:
    """The lock attr name when ``item`` is ``self.<lock>`` (with or without
    ``as``), else ``None``."""
    e = item.context_expr
    if isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name) and \
            e.value.id == "self":
        return e.attr
    return None


def check(src: SourceFile) -> List[Finding]:
    # fast path: nothing to do in files without annotations
    if "guarded-by:" not in src.text and "requires-lock:" not in src.text:
        return []
    findings: List[Finding] = []
    for node in ast.walk(src.tree):
        if isinstance(node, ast.ClassDef):
            contract = _collect_contract(src, node)
            if contract.guarded or contract.requires:
                findings.extend(_check_class(src, node, contract))
    return findings


def _check_class(src: SourceFile, cls: ast.ClassDef,
                 contract: _ClassContract) -> List[Finding]:
    findings: List[Finding] = []

    def visit(node: ast.AST, held: Set[str], method: ast.FunctionDef) -> None:
        """Walk ``method``'s body tracking the lexically-held lock set."""
        for child in ast.iter_child_nodes(node):
            child_held = held
            if isinstance(child, (ast.With, ast.AsyncWith)):
                acquired = {lk for item in child.items
                            for lk in [_with_locks(item)] if lk}
                child_held = held | acquired

            exempt = method.name == "__init__"
            holds_for = contract.requires.get(method.name)

            if isinstance(child, ast.Attribute) and \
                    isinstance(child.value, ast.Name) and \
                    child.value.id == "self":
                lock = contract.guarded.get(child.attr)
                if lock and not exempt and lock not in child_held and \
                        holds_for != lock:
                    kind = ("write" if isinstance(child.ctx,
                                                  (ast.Store, ast.Del))
                            else "read")
                    f = src.finding(
                        CHECKER, "unguarded-access", child,
                        f"{kind} of 'self.{child.attr}' (guarded-by: {lock}) "
                        f"outside 'with self.{lock}' in "
                        f"{cls.name}.{method.name}",
                        scope=f"{cls.name}.{method.name}",
                        suggestion=f"wrap the access in 'with self.{lock}:' "
                                   f"or declare the method "
                                   f"'# requires-lock: {lock}'")
                    if f is not None:
                        findings.append(f)

            if isinstance(child, ast.Call) and \
                    isinstance(child.func, ast.Attribute) and \
                    isinstance(child.func.value, ast.Name) and \
                    child.func.value.id == "self":
                lock = contract.requires.get(child.func.attr)
                if lock and not exempt and lock not in child_held and \
                        holds_for != lock:
                    f = src.finding(
                        CHECKER, "unlocked-call", child,
                        f"call to 'self.{child.func.attr}()' "
                        f"(requires-lock: {lock}) without holding "
                        f"'self.{lock}' in {cls.name}.{method.name}",
                        scope=f"{cls.name}.{method.name}",
                        suggestion=f"acquire 'with self.{lock}:' around the "
                                   f"call")
                    if f is not None:
                        findings.append(f)

            visit(child, child_held, method)

    for member in cls.body:
        if isinstance(member, (ast.FunctionDef, ast.AsyncFunctionDef)):
            visit(member, set(), member)
    return findings
