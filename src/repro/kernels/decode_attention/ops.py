"""Jitted public wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.decode_attention.kernel import decode_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("softcap", "scale", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,            # (B, H, d)
    k_cache: jax.Array,      # (B, Hkv, S, d)
    v_cache: jax.Array,
    valid: jax.Array,        # (S,) bool
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    return decode_attention_pallas(q, k_cache, v_cache, valid, softcap=softcap,
                                   scale=scale, block_k=block_k, interpret=interp)
