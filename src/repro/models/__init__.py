from repro.models.config import ArchConfig, ShapeConfig, SHAPES
from repro.models.transformer import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
)

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES",
    "decode_step", "forward", "init_decode_state", "init_params",
]
