"""Multi-host / multi-pod process bootstrap for the production meshes.

On a real v5e fleet every host runs the same binary; this module provides the
per-process initialization that the dry-run stands in for:

    python -m repro.launch.cluster --role train --arch gemma2_27b ...

  * ``jax.distributed.initialize`` from environment (COORDINATOR_ADDRESS,
    NUM_PROCESSES, PROCESS_ID — set by the scheduler; GKE/TPU-VM metadata is
    auto-detected by jax when unset);
  * builds the production mesh across all processes' devices
    (``make_production_mesh`` — the same function the dry-run compiles against,
    so dry-run artifacts predict the real launch);
  * host-sharded data: each process generates only its slice
    (``SyntheticTokenPipeline(host_index=process_index, host_count=process_count)``);
  * checkpoint directory must be shared storage (GCS/NFS); restores re-shard to the
    current mesh, so the job may resume at a different pod count (elastic restart —
    see tests/test_elastic.py for the single-host proof).

``scripts/launch_pod.sh`` shows the per-host invocation for a 2-pod (512-chip) job.
"""
from __future__ import annotations

import argparse
import os


def initialize_distributed() -> tuple:
    """Returns (process_index, process_count). Single-process when no coordinator."""
    import jax
    coord = os.environ.get("COORDINATOR_ADDRESS")
    nproc = os.environ.get("NUM_PROCESSES")
    if coord and nproc:
        jax.distributed.initialize(
            coordinator_address=coord,
            num_processes=int(nproc),
            process_id=int(os.environ.get("PROCESS_ID", "0")),
        )
    elif os.environ.get("TPU_WORKER_HOSTNAMES"):
        jax.distributed.initialize()  # TPU-VM metadata autodetection
    return jax.process_index(), jax.process_count()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--role", choices=["train", "serve", "dryrun"], default="train")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default="fnbench_tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=os.environ.get("CKPT_DIR",
                                                         "results/cluster_ckpt"))
    args, passthrough = ap.parse_known_args()

    pid, pcount = initialize_distributed()
    import jax
    print(f"[cluster] process {pid}/{pcount}, "
          f"{jax.local_device_count()} local / {jax.device_count()} global devices")

    if args.role == "dryrun":
        from repro.launch.dryrun import main as dryrun_main
        import sys
        sys.argv = ["dryrun"] + passthrough
        dryrun_main()
        return

    from repro.launch.mesh import make_production_mesh, make_local_mesh
    try:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    except RuntimeError:
        mesh = make_local_mesh()  # smaller fleets: whatever is attached
    print(f"[cluster] mesh: {dict(mesh.shape)}")

    if args.role == "train":
        import sys
        sys.argv = (["train", "--arch", args.arch, "--steps", str(args.steps),
                     "--ckpt-dir", args.ckpt_dir, "--resume"] + passthrough)
        from repro.launch.train import main as train_main
        train_main()
    else:
        import sys
        sys.argv = ["serve", "--arch", args.arch, "--reduced"] + passthrough
        from repro.launch.serve import main as serve_main
        serve_main()


if __name__ == "__main__":
    main()
