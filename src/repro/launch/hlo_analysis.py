"""Post-compile HLO analysis: cost, memory, and collective-byte extraction.

``cost_analysis``/``memory_analysis`` come straight from the compiled executable.
Collective bytes are NOT in cost_analysis — we parse the optimized (post-SPMD,
per-device) HLO text and sum the **output bytes** of every collective op. Notes on
the approximation (documented in EXPERIMENTS.md §Roofline):

  * the partitioned module is the per-device program, so parsed byte counts are
    per-device;
  * output bytes are the transfer proxy: exact for all-gather (output = gathered) and
    collective-permute; all-reduce moves ~2·(N−1)/N ≈ 2x its operand bytes on a ring —
    we report raw output bytes and apply the 2x in the roofline term for all-reduce;
  * '-start'/'-done' async pairs are counted once (on the start op).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any, Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[2,128]{1,0} all-gather(%x), replica_groups=...
#        %ar = (f32[16]{0}, f32[16]{0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes and op counts by collective kind, from optimized HLO."""
    by_kind_bytes: Dict[str, int] = defaultdict(int)
    by_kind_count: Dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue
        kind = m.group("op")
        by_kind_bytes[kind] += _type_bytes(m.group("type"))
        by_kind_count[kind] += 1
    total = sum(by_kind_bytes.values())
    # ring-transfer proxy: all-reduce moves ~2x its bytes
    weighted = total + by_kind_bytes.get("all-reduce", 0)
    return {
        "bytes_by_kind": dict(by_kind_bytes),
        "count_by_kind": dict(by_kind_count),
        "total_output_bytes": total,
        "ring_weighted_bytes": weighted,
    }


def cost_summary(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
    except Exception:
        ca = {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        if hasattr(ma, field):
            out[field] = float(getattr(ma, field))
    if out:
        # donation (alias) overlaps args and outputs
        out["live_bytes"] = (out.get("argument_size_in_bytes", 0.0)
                             + out.get("output_size_in_bytes", 0.0)
                             + out.get("temp_size_in_bytes", 0.0)
                             - out.get("alias_size_in_bytes", 0.0))
    return out


# ------------------------------------------------------------------ roofline terms
# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12         # bf16 FLOP/s
HBM_BW = 819e9              # bytes/s
ICI_BW = 50e9               # bytes/s per link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> Dict[str, Any]:
    compute_s = flops_per_device / PEAK_FLOPS
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    total = max(sum(terms.values()), 1e-30)
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "bound_fraction": terms[bottleneck] / total,
        "step_lower_bound_s": max(terms.values()),   # perfect-overlap model
    }
