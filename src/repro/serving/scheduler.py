"""Fleet-level request scheduling: placement + straggler mitigation.

Placement (``place_invocation``) is image-affinity routing: prefer a worker that
already has a warm instance, then one whose Dependency-Manager pool holds the
needed live image (migration is a local memcpy there), then least-loaded. The
same function drives both the live :class:`FleetScheduler` and the discrete-event
fleet simulator (``repro.core.fleet``), so simulated placement decisions match
what the serving layer would do.

Straggler mitigation routes requests across serving replicas, tracking
per-replica EWMA step latency. A replica whose in-flight request exceeds
``straggler_factor``x its EWMA is flagged; flagged work is re-dispatched to the
fastest healthy replica (backup-request strategy), and repeatedly-flagged
replicas are quarantined and replaced through the WarmSwap pool (fast re-warm —
the recovery path fault_tolerance.py measures).
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.registry import Registry

#: Name -> placement-strategy factory. A built strategy is a callable
#: ``strategy(workers, context: PlacementContext) -> worker``; the fleet
#: engine (and scenario specs / the experiments CLI) address strategies by
#: key, so new strategies plug in with ``@PLACEMENTS.register("name")``
#: without touching the engine.
PLACEMENTS = Registry("placement strategy")


@dataclass
class PlacementContext:
    """Everything a placement strategy may consult for one invocation.

    All signals are callables over a single worker (so strategies only pay
    for what they read); optional ones are ``None`` when the caller has no
    such signal. ``arrival_seq`` is the index of this arrival in the merged
    stream — stateless strategies like round-robin rotate on it.
    """
    load: Callable                           # worker -> in-flight requests
    has_warm: Optional[Callable] = None      # worker -> idle warm instance?
    holds_image: Optional[Callable] = None   # worker -> pool holds the image?
    queue_depth: Optional[Callable] = None   # worker -> queued (not running)
    start_cost: Optional[Callable] = None    # worker -> est. transfer seconds
    fn: Optional[int] = None                 # function index (informational)
    t_min: float = 0.0                       # arrival time (minutes)
    arrival_seq: int = 0                     # position in the arrival stream


def place_invocation(
    workers: Sequence,
    context: Optional[PlacementContext] = None,
    *,
    load: Optional[Callable] = None,
    has_warm: Optional[Callable] = None,
    holds_image: Optional[Callable] = None,
    queue_depth: Optional[Callable] = None,
    start_cost: Optional[Callable] = None,
):
    """Image-affinity placement over ``workers`` (any hashable ids).

    Priority: (1) a worker with a warm idle instance of the function,
    (2a) with ``start_cost`` — the worker with the cheapest estimated
    cold-start transfer (seconds: 0-ish where the image is hot in the local
    pool, a network transfer where a peer holds it, a source fetch where
    nobody does — the bandwidth/residency-aware ranking the page-granular
    cost model feeds), ties broken by load;
    (2b) without it — a worker whose pool already holds the live dependency
    image (the boolean residency special case);
    (3) the least-loaded worker.

    ``queue_depth`` (requests waiting for an instance, not yet running) adds
    to the load — a worker with a deep queue is as bad as one with that many
    in-flight requests. Ties break on position in ``workers``, so placement
    is deterministic and worker ids never need to be orderable.

    Args:
        workers: candidate workers (any hashable ids).
        context: a :class:`PlacementContext` bundling all signals — the
            preferred calling convention.
        load / has_warm / holds_image / queue_depth / start_cost:
            **deprecated** keyword form (one callable per signal, same
            semantics as the context fields). Kept as a back-compat shim;
            pass a ``PlacementContext`` instead. Mixing both forms raises.

    Returns:
        The chosen worker, or ``None`` when ``workers`` is empty.
    """
    if context is None:
        if load is None:
            raise TypeError("place_invocation needs a PlacementContext "
                            "(or, deprecated, a load= callable)")
        context = PlacementContext(load=load, has_warm=has_warm,
                                   holds_image=holds_image,
                                   queue_depth=queue_depth,
                                   start_cost=start_cost)
    elif any(s is not None for s in (load, has_warm, holds_image,
                                     queue_depth, start_cost)):
        raise TypeError("pass signals via PlacementContext OR the deprecated "
                        "kwargs, not both")
    if not workers:
        return None
    # Single-pass selection with first-minimum tie-breaks (== the historical
    # ``min`` over ``(signal, position)`` keys, without building a rank dict
    # and per-worker key tuples — this is the fleet engine's hottest call).
    load, queue_depth = context.load, context.queue_depth
    has_warm, start_cost = context.has_warm, context.start_cost

    def eff_load(w):
        return load(w) + queue_depth(w) if queue_depth is not None else load(w)

    if has_warm is not None:
        best = None
        best_load = 0
        for w in workers:
            if has_warm(w):
                l = eff_load(w)
                if best is None or l < best_load:
                    best, best_load = w, l
        if best is not None:
            return best
    if start_cost is not None:
        best = workers[0]
        best_cost, best_load = start_cost(best), eff_load(best)
        for w in workers[1:]:
            c = start_cost(w)
            if c > best_cost:
                continue
            l = eff_load(w)
            if c < best_cost or l < best_load:
                best, best_cost, best_load = w, c, l
        return best
    if context.holds_image is not None:
        holds_image = context.holds_image
        best = None
        best_load = 0
        for w in workers:
            if holds_image(w):
                l = eff_load(w)
                if best is None or l < best_load:
                    best, best_load = w, l
        if best is not None:
            return best
    best = workers[0]
    best_load = eff_load(best)
    for w in workers[1:]:
        l = eff_load(w)
        if l < best_load:
            best, best_load = w, l
    return best


@PLACEMENTS.register("affinity")
def _affinity_strategy():
    """Warm-instance, then image/transfer-cost affinity, then least-loaded —
    the full :func:`place_invocation` priority chain."""
    def place(workers, ctx: PlacementContext):
        return place_invocation(workers, ctx)
    return place


@PLACEMENTS.register("least_loaded")
def _least_loaded_strategy():
    """Load (in-flight + queue depth) only: ignores warmth and residency."""
    def place(workers, ctx: PlacementContext):
        return place_invocation(workers, replace(
            ctx, has_warm=None, holds_image=None, start_cost=None))
    return place


@PLACEMENTS.register("round_robin")
def _round_robin_strategy():
    """Rotate on the arrival sequence number, blind to every other signal."""
    def place(workers, ctx: PlacementContext):
        return workers[ctx.arrival_seq % len(workers)] if workers else None
    return place


@dataclass
class ReplicaHealth:
    ewma_s: float = 0.0
    n: int = 0
    flags: int = 0
    quarantined: bool = False

    def observe(self, dt: float, alpha: float = 0.2) -> None:
        self.ewma_s = dt if self.n == 0 else (1 - alpha) * self.ewma_s + alpha * dt
        self.n += 1


@dataclass
class SchedulerConfig:
    straggler_factor: float = 3.0
    min_observations: int = 5
    quarantine_after_flags: int = 3


class FleetScheduler:
    """Dispatch + straggler handling over a set of named replicas."""

    def __init__(self, cfg: Optional[SchedulerConfig] = None):
        # fresh config per scheduler: a shared default instance would leak
        # threshold mutations across schedulers
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.health: Dict[str, ReplicaHealth] = {}
        self.dispatch_log: List[tuple] = []

    def register_replica(self, name: str) -> None:
        self.health.setdefault(name, ReplicaHealth())

    def remove_replica(self, name: str) -> None:
        self.health.pop(name, None)

    def healthy(self) -> List[str]:
        return [n for n, h in self.health.items() if not h.quarantined]

    def pick(self) -> Optional[str]:
        """Least-loaded-ish: lowest EWMA among healthy replicas."""
        h = self.healthy()
        if not h:
            return None
        return min(h, key=lambda n: (self.health[n].ewma_s, n))

    def pick_affine(self, image_id: str,
                    residency: Dict[str, Iterable[str]]) -> Optional[str]:
        """Placement that prefers healthy replicas whose pool holds ``image_id``
        (``residency``: replica -> live image ids), then lowest EWMA."""
        return place_invocation(self.healthy(), PlacementContext(
            load=lambda n: self.health[n].ewma_s,
            holds_image=lambda n: image_id in residency.get(n, ()),
        ))

    def observe(self, name: str, dt: float) -> bool:
        """Record a completed unit of work; returns True if it was a straggler."""
        rh = self.health[name]
        is_straggler = (rh.n >= self.cfg.min_observations and
                        dt > self.cfg.straggler_factor * max(rh.ewma_s, 1e-9))
        rh.observe(dt)
        if is_straggler:
            rh.flags += 1
            if rh.flags >= self.cfg.quarantine_after_flags:
                rh.quarantined = True
        return is_straggler

    def run(self, work: List[Callable[[], float]],
            execute: Callable[[str, Callable], float]) -> Dict[str, int]:
        """Dispatch work items; re-dispatch stragglers once to the best other
        replica. ``execute(replica, item)`` returns measured seconds."""
        counts: Dict[str, int] = collections.Counter()
        for item in work:
            name = self.pick()
            if name is None:
                raise RuntimeError("no healthy replicas")
            dt = execute(name, item)
            counts[name] += 1
            if self.observe(name, dt):
                backup = self.pick()
                if backup is not None and backup != name:
                    dt2 = execute(backup, item)          # backup request
                    self.observe(backup, dt2)
                    counts[backup] += 1
                    self.dispatch_log.append(("redispatch", name, backup))
        return dict(counts)
