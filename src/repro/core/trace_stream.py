"""Streaming, out-of-core trace ingestion (docs/TRACES.md).

A :class:`TraceStream` presents an arrival workload as an ordered sequence of
:class:`TraceChunk` s — merged ``(time, fn)`` arrays covering disjoint time
windows — instead of a fully materialized ``List[Trace]``. The event engine
(``core/fleet.py``) consumes chunks natively, so a trace far larger than RAM
replays with peak arrival residency bounded by the largest chunk; the
vectorized engine falls back (``fleet_vec.fast_path_reason``) because static
routing cannot be proven from a stream prefix.

Contract (enforced by ``tests/test_stream_equiv.py``):

  * **Bit identity** — running an engine over ``stream.chunks()`` and over
    ``stream.materialize()`` produces byte-identical results (sha256 over the
    per-request sample arrays, exact counters). The merged order inside a
    chunk is the engines' own order (global stable argsort over per-function
    concatenation), chunks cover half-open ``[t0, t1)`` windows, so equal
    timestamps never straddle a chunk boundary and tie-breaks cannot drift.
  * **Chunk-size invariance** — all randomness is drawn from generators
    seeded per ``(seed, tag, block)`` (or per ``(seed, tag, fn, block)`` for
    the CSV reader), where a *block* is a fixed ``block_min``-minute window.
    A chunk is a grouping of whole blocks, so ``chunk_min`` changes how many
    arrivals are resident at once — never which arrivals exist. ``chunk_min``
    and ``stream`` are therefore non-semantic spec knobs
    (``NON_SEMANTIC_TRACE_KWARGS``): the executor's store key ignores them.

Generators registered here (all accept ``stream=True`` to return the stream
itself, default ``False`` materializes — same values either way):

  ``azure_csv``   hardened chunked reader for the Azure Functions per-minute
                  count schema: gzip auto-detection, malformed rows raise
                  with line numbers, per-window spill files keep ingestion
                  out-of-core (two sequential passes, never the whole trace).
  ``diurnal``     day/night sinusoidal rate modulation with per-function
                  phase jitter (time-of-day load waves).
  ``bursts``      correlated bursts: deploy storms / retry stampedes that
                  multiply every function of one image for a short window,
                  with decaying retry echoes.
  ``tenant_mix``  multi-tenant fleet: per-tenant function/image partitions
                  and Zipf-skewed tenant load shares — pair with a bounded
                  ``shared_cache_bytes`` to model per-tenant cache quotas
                  (each tenant's quota is its image-universe footprint).
  ``rollout``     image-version rollouts: functions migrate to a new image
                  version mid-trace (per-function canary jitter), modeled as
                  distinct revision rows so a rollout invalidates the shared
                  image exactly like a fresh deployment.
"""
from __future__ import annotations

import csv
import gzip
import math
import os
import shutil
import tempfile
import weakref
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.traces import (TRACE_GENERATORS, Trace, assign_images,
                               sample_rates, zipf_weights)

#: One RNG-block per day of trace time by default: big enough that per-block
#: vectorized draws stay cheap, small enough that a chunk (>= 1 block) keeps
#: peak arrival residency far below production trace sizes.
DEFAULT_BLOCK_MIN = 1440.0
DEFAULT_CHUNK_MIN = 1440.0

#: Trace-component kwargs that change HOW a spec executes but provably not
#: WHAT it computes (the bit-identity + chunk-invariance contract above).
#: The sweep store's content hash and seed derivation strip these, so a
#: resumed sweep re-uses results computed under a different chunking.
NON_SEMANTIC_TRACE_KWARGS = frozenset({"stream", "chunk_min"})

# Per-generator RNG stream tags: decouple the (seed, tag, block) block
# streams so two generators given the same seed never share draws.
_TAG_CSV = 1
_TAG_DIURNAL = 2
_TAG_BURSTS = 3
_TAG_TENANT = 4
_TAG_ROLLOUT = 5


def block_rng(seed: int, tag: int, *key: int) -> np.random.Generator:
    """Deterministic generator for one RNG block: seeded by the full
    ``(seed, tag, *key)`` tuple via ``SeedSequence``, so draws depend only on
    the block identity — never on which chunk grouping requested them."""
    if seed < 0:
        raise ValueError(f"stream seeds must be >= 0, got {seed}")
    return np.random.default_rng([int(seed), int(tag)] + [int(k) for k in key])


@dataclass
class TraceChunk:
    """One merged arrival window: times (minutes, sorted; ties in trace-list
    order — the engines' own merge order) and the function index per arrival."""
    t_min: np.ndarray
    fn: np.ndarray
    start_min: float
    end_min: float

    def __len__(self) -> int:
        return len(self.t_min)


@dataclass
class StreamStats:
    """Residency accounting for one stream (updated by ``chunks()``):
    ``peak_resident_arrivals`` is the high-water mark of arrivals held in
    memory at once — the out-of-core guarantee CI asserts against the total."""
    n_arrivals: int = 0
    n_chunks: int = 0
    peak_resident_arrivals: int = 0


class TraceStream:
    """Base class: a re-iterable chunked arrival source.

    Subclasses provide ``meta_traces()`` (per-function rate/image metadata,
    zero-length arrival arrays — bounded by fleet size, not trace length) and
    ``chunks()`` (a FRESH iterator per call; engines consume one stream
    several times, once per method). ``materialize()`` builds the equivalent
    ``List[Trace]`` — the in-memory half of the differential contract; only
    call it at test scale.
    """

    def __init__(self, *, n_functions: int, horizon_min: float,
                 block_min: float = DEFAULT_BLOCK_MIN,
                 chunk_min: float = DEFAULT_CHUNK_MIN):
        if n_functions < 1:
            raise ValueError(f"n_functions must be >= 1, got {n_functions}")
        if horizon_min <= 0:
            raise ValueError(f"horizon_min must be > 0, got {horizon_min}")
        if block_min <= 0:
            raise ValueError(f"block_min must be > 0, got {block_min}")
        if chunk_min <= 0:
            raise ValueError(f"chunk_min must be > 0, got {chunk_min}")
        self.n_functions = int(n_functions)
        self.horizon_min = float(horizon_min)
        self.block_min = float(block_min)
        self.chunk_blocks = max(1, math.ceil(chunk_min / block_min))
        self.n_blocks = max(1, math.ceil(self.horizon_min / self.block_min))
        self.stats = StreamStats()

    # -- subclass hooks -----------------------------------------------------
    def meta_traces(self) -> List[Trace]:
        raise NotImplementedError

    def _block_arrivals(self, block: int) -> List[Tuple[int, np.ndarray]]:
        """Per-function sorted arrival arrays for one block, in ascending
        function-index order, times in the half-open block window."""
        raise NotImplementedError

    # -- chunked iteration --------------------------------------------------
    def chunks(self) -> Iterator[TraceChunk]:
        """Yield merged chunks of ``chunk_blocks`` whole blocks each. Empty
        windows are skipped; every yielded chunk is non-empty and sorted."""
        n_seen = n_chunks = 0
        for b0 in range(0, self.n_blocks, self.chunk_blocks):
            b1 = min(b0 + self.chunk_blocks, self.n_blocks)
            parts_t: List[np.ndarray] = []
            parts_fn: List[np.ndarray] = []
            for b in range(b0, b1):
                for fn, t in self._block_arrivals(b):
                    parts_t.append(np.asarray(t, np.float64))
                    parts_fn.append(np.full(len(t), fn, np.int64))
            if not parts_t:
                continue
            t_all = np.concatenate(parts_t)
            fn_all = np.concatenate(parts_fn)
            # the engines' merge order: per-function concatenation + one
            # global stable argsort (ties break by trace order then position)
            order = np.argsort(t_all, kind="stable")
            n_seen += len(t_all)
            n_chunks += 1
            self.stats.peak_resident_arrivals = max(
                self.stats.peak_resident_arrivals, len(t_all))
            yield TraceChunk(t_all[order], fn_all[order],
                             start_min=b0 * self.block_min,
                             end_min=min(b1 * self.block_min,
                                         self.horizon_min))
        self.stats.n_arrivals = n_seen
        self.stats.n_chunks = n_chunks

    def materialize(self) -> List[Trace]:
        """The equivalent in-memory trace list (test scale only: holds every
        arrival at once). Bit-identical inputs to the chunked path."""
        meta = self.meta_traces()
        parts: Dict[int, List[np.ndarray]] = {m.fn_index: [] for m in meta}
        for b in range(self.n_blocks):
            for fn, t in self._block_arrivals(b):
                parts[fn].append(np.asarray(t, np.float64))
        return [Trace(m.fn_index, m.rate_per_min,
                      np.concatenate(parts[m.fn_index])
                      if parts[m.fn_index] else np.empty((0,), np.float64),
                      image_id=m.image_id)
                for m in meta]


def ensure_trace_list(traces) -> List[Trace]:
    """Accept either a trace list or a stream; return the list form."""
    return traces.materialize() if isinstance(traces, TraceStream) else traces


class ListTraceStream(TraceStream):
    """In-memory traces re-presented through the chunked interface — the
    differential-test adapter proving the engines' chunked consumption path
    is identical to their array path for ARBITRARY chunk boundaries (count
    slices may split equal-timestamp runs; the engine's merge rules make
    that safe, and the fuzz test pins it)."""

    def __init__(self, traces: Sequence[Trace], chunk_size: int = 4096):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self._traces = list(traces)
        all_t = (np.concatenate([np.asarray(t.arrivals_min, np.float64)
                                 for t in self._traces])
                 if self._traces else np.empty((0,)))
        all_fn = (np.concatenate([np.full(len(t.arrivals_min), t.fn_index,
                                          np.int64) for t in self._traces])
                  if self._traces else np.empty((0,), np.int64))
        order = np.argsort(all_t, kind="stable")
        self._all_t = all_t[order]
        self._all_fn = all_fn[order]
        self.chunk_size = int(chunk_size)
        horizon = float(self._all_t[-1]) if len(self._all_t) else 1.0
        super().__init__(n_functions=max(len(self._traces), 1),
                         horizon_min=max(horizon, 1e-9))

    def meta_traces(self) -> List[Trace]:
        return [Trace(t.fn_index, t.rate_per_min, np.empty((0,), np.float64),
                      image_id=t.image_id) for t in self._traces]

    def materialize(self) -> List[Trace]:
        return list(self._traces)

    def chunks(self) -> Iterator[TraceChunk]:
        n = len(self._all_t)
        n_seen = n_chunks = 0
        for lo in range(0, n, self.chunk_size):
            hi = min(lo + self.chunk_size, n)
            n_seen += hi - lo
            n_chunks += 1
            self.stats.peak_resident_arrivals = max(
                self.stats.peak_resident_arrivals, hi - lo)
            yield TraceChunk(self._all_t[lo:hi], self._all_fn[lo:hi],
                             start_min=float(self._all_t[lo]),
                             end_min=float(self._all_t[hi - 1]))
        self.stats.n_arrivals = n_seen
        self.stats.n_chunks = n_chunks


# ------------------------------------------------------------------------------
# Azure Functions CSV: hardened out-of-core reader
# ------------------------------------------------------------------------------

class CsvSchemaError(ValueError):
    """The CSV violates the Azure per-minute count schema; the message names
    the file, line and column so a bad row is a one-look fix."""


class AzureCsvStream(TraceStream):
    """Two-pass out-of-core reader for the Azure Functions trace schema
    (optionally leading id columns — ``HashOwner/HashApp/HashFunction`` — then
    one integer column per minute, named by minute number).

    Pass 1 (construction) streams the file row by row — gzip auto-detected
    from magic bytes — validating every cell (malformed rows raise
    :class:`CsvSchemaError` with the line number) and spilling nonzero
    ``(fn, minute, count)`` triples into one binary file per ``block_min``
    window, so peak memory is one ROW, never the trace. Pass 2
    (``chunks()``/``materialize()``) re-reads one window at a time and places
    each count uniformly inside its minute with a per-``(seed, fn, block)``
    generator — chunk-size invariant by construction.

    Functions sharing a ``HashApp`` share an image (dependency bundle);
    without id columns every row runs on image 0. ``rate_per_min`` is the
    in-horizon mean count per minute.
    """

    def __init__(self, path: str, n_functions: int, horizon_min: float,
                 seed: int = 0, block_min: float = DEFAULT_BLOCK_MIN,
                 chunk_min: float = DEFAULT_CHUNK_MIN):
        super().__init__(n_functions=n_functions, horizon_min=horizon_min,
                         block_min=block_min, chunk_min=chunk_min)
        if seed < 0:
            raise ValueError(f"stream seeds must be >= 0, got {seed}")
        self.path = path
        self.seed = int(seed)
        self.total_invocations = 0
        self._rates: List[float] = []
        self._images: List[int] = []
        self._spill_dir = tempfile.mkdtemp(prefix="repro-trace-spill-")
        self._cleanup = weakref.finalize(self, shutil.rmtree, self._spill_dir,
                                         True)
        try:
            self._ingest(max_rows=int(n_functions))
        except BaseException:
            self.close()
            raise
        # the file may hold fewer rows than the requested cap
        self.n_functions = len(self._rates)

    def close(self) -> None:
        """Drop the spill directory now (also runs at garbage collection)."""
        self._cleanup()

    def _open_text(self):
        with open(self.path, "rb") as probe:
            magic = probe.read(2)
        if magic == b"\x1f\x8b":
            return gzip.open(self.path, "rt", newline="")
        return open(self.path, newline="")

    def _ingest(self, max_rows: int) -> None:
        spill: Dict[int, object] = {}
        app_ids: Dict[str, int] = {}
        try:
            with self._open_text() as f:
                reader = csv.reader(f)
                try:
                    header = next(reader)
                except StopIteration:
                    raise CsvSchemaError(f"{self.path}: empty file (no header)")
                minute_cols = [i for i, h in enumerate(header)
                               if h.strip().isdigit()]
                if not minute_cols:
                    raise CsvSchemaError(
                        f"{self.path}: header has no per-minute count columns "
                        f"(integer-named), got {header[:8]!r}...")
                minutes = np.array([int(header[i]) for i in minute_cols],
                                   np.int64)
                if len(np.unique(minutes)) != len(minutes):
                    raise CsvSchemaError(
                        f"{self.path}: duplicate minute columns in header")
                minutes = minutes - minutes.min()   # minute origin -> 0
                in_h = minutes < self.horizon_min
                app_col = header.index("HashApp") if "HashApp" in header else None
                n_cols = len(header)
                for fi, row in enumerate(reader):
                    if fi >= max_rows:
                        break
                    line = reader.line_num
                    if len(row) != n_cols:
                        raise CsvSchemaError(
                            f"{self.path}, line {line}: expected {n_cols} "
                            f"columns, got {len(row)}")
                    counts = self._parse_counts(row, minute_cols, header, line)
                    counts = counts[in_h]
                    mins = minutes[in_h]
                    self.total_invocations += int(counts.sum())
                    self._rates.append(
                        float(counts.sum()) / max(float(in_h.sum()), 1.0))
                    if app_col is not None:
                        app = row[app_col]
                        self._images.append(
                            app_ids.setdefault(app, len(app_ids)))
                    else:
                        self._images.append(0)
                    nz = np.flatnonzero(counts)
                    if not len(nz):
                        continue
                    m_nz, c_nz = mins[nz], counts[nz]
                    ord_m = np.argsort(m_nz, kind="stable")
                    m_nz, c_nz = m_nz[ord_m], c_nz[ord_m]
                    blocks = (m_nz // self.block_min).astype(np.int64)
                    for b in np.unique(blocks):
                        sel = blocks == b
                        tri = np.column_stack([
                            np.full(int(sel.sum()), fi, np.int64),
                            m_nz[sel], c_nz[sel]])
                        fh = spill.get(int(b))
                        if fh is None:
                            fh = open(self._spill_path(int(b)), "wb")
                            spill[int(b)] = fh
                        fh.write(tri.tobytes())
        finally:
            for fh in spill.values():
                fh.close()

    def _parse_counts(self, row, minute_cols, header, line) -> np.ndarray:
        cells = [row[i].strip() for i in minute_cols]
        try:
            # the Azure schema writes absent minutes as empty cells
            counts = np.array([c if c else "0" for c in cells], np.int64)
        except ValueError:
            for i, c in zip(minute_cols, cells):
                if c:
                    try:
                        int(c)
                    except ValueError:
                        raise CsvSchemaError(
                            f"{self.path}, line {line}, column "
                            f"{header[i]!r}: invalid invocation count {c!r}")
            raise
        if (counts < 0).any():
            i = minute_cols[int(np.flatnonzero(counts < 0)[0])]
            raise CsvSchemaError(
                f"{self.path}, line {line}, column {header[i]!r}: negative "
                f"invocation count {row[i]!r}")
        return counts

    def _spill_path(self, block: int) -> str:
        return os.path.join(self._spill_dir, f"w{block:08d}.bin")

    def meta_traces(self) -> List[Trace]:
        return [Trace(i, r, np.empty((0,), np.float64), image_id=img)
                for i, (r, img) in enumerate(zip(self._rates, self._images))]

    def _block_arrivals(self, block: int) -> List[Tuple[int, np.ndarray]]:
        path = self._spill_path(block)
        if not os.path.exists(path):
            return []
        tri = np.fromfile(path, np.int64).reshape(-1, 3)
        fn, minute, count = tri[:, 0], tri[:, 1], tri[:, 2]
        # triples were appended row-major: fn ascending, minutes ascending
        starts = np.concatenate(([0], np.flatnonzero(np.diff(fn)) + 1,
                                 [len(fn)]))
        out = []
        for s, e in zip(starts[:-1], starts[1:]):
            f = int(fn[s])
            rng = block_rng(self.seed, _TAG_CSV, f, block)
            total = int(count[s:e].sum())
            t = (np.repeat(minute[s:e].astype(np.float64), count[s:e])
                 + rng.random(total))
            out.append((f, np.sort(t, kind="stable")))
        return out


@TRACE_GENERATORS.register("azure_csv")
def load_azure_csv(path: str, n_functions: int, horizon_min: float,
                   seed: int = 0, stream: bool = False,
                   block_min: float = DEFAULT_BLOCK_MIN,
                   chunk_min: float = DEFAULT_CHUNK_MIN):
    """Azure Functions per-minute count schema -> traces (see
    :class:`AzureCsvStream`). ``stream=True`` returns the chunked stream;
    the default materializes the identical trace list. ``n_functions`` caps
    the rows read."""
    st = AzureCsvStream(path, n_functions, horizon_min, seed=seed,
                        block_min=block_min, chunk_min=chunk_min)
    if stream:
        return st
    try:
        return st.materialize()
    finally:
        st.close()


# ------------------------------------------------------------------------------
# Adversarial generators: binned inhomogeneous-Poisson streams
# ------------------------------------------------------------------------------

class _BinnedPoissonStream(TraceStream):
    """Shared machinery for the synthetic adversarial generators: each block
    is sliced into ``resolution_min`` bins; a subclass supplies the per-row
    rate matrix for a block (rows are functions, or revisions for rollouts),
    and one per-``(seed, tag, block)`` generator draws Poisson counts plus
    uniform placement for the whole block in three vectorized calls."""

    def __init__(self, *, tag: int, seed: int, rows: int,
                 resolution_min: float, **kw):
        super().__init__(**kw)
        if resolution_min <= 0:
            raise ValueError(
                f"resolution_min must be > 0, got {resolution_min}")
        self._tag = int(tag)
        self.seed = int(seed)
        self._rows = int(rows)
        self.resolution_min = float(resolution_min)

    def _block_rates(self, block: int, starts: np.ndarray,
                     widths: np.ndarray) -> np.ndarray:
        """(rows, bins) arrival rate per minute inside each bin."""
        raise NotImplementedError

    def _row_fn(self, row: int) -> int:
        return row

    def _block_arrivals(self, block: int) -> List[Tuple[int, np.ndarray]]:
        lo = block * self.block_min
        hi = min(lo + self.block_min, self.horizon_min)
        edges = np.arange(lo, hi, self.resolution_min)
        widths = np.minimum(edges + self.resolution_min, hi) - edges
        lam = np.maximum(self._block_rates(block, edges, widths), 0.0)
        rng = block_rng(self.seed, self._tag, block)
        counts = rng.poisson(lam * widths)
        total = int(counts.sum())
        if not total:
            return []
        flat = counts.ravel()                      # row-major: bins per row
        u = rng.random(total)
        t = (np.repeat(np.broadcast_to(edges, counts.shape).ravel(), flat)
             + u * np.repeat(np.broadcast_to(widths, counts.shape).ravel(),
                             flat))
        row_tot = counts.sum(axis=1)
        bounds = np.concatenate(([0], np.cumsum(row_tot)))
        return [(self._row_fn(r),
                 np.sort(t[bounds[r]:bounds[r + 1]], kind="stable"))
                for r in np.flatnonzero(row_tot)]


def _base_rates(n: int, seed: int, rate_model: str, rate_skew: float,
                total_rate_per_min: float) -> np.ndarray:
    if rate_model == "azure":
        return sample_rates(n, seed)
    if rate_model == "zipf":
        return total_rate_per_min * zipf_weights(n, rate_skew)
    raise ValueError(f"unknown rate_model: {rate_model!r}")


class DiurnalTraceStream(_BinnedPoissonStream):
    """Day/night load waves: each function's rate is its base rate modulated
    by ``1 + amplitude * cos(2*pi*(t - peak)/period)`` with a per-function
    peak-time jitter, so the fleet breathes together but not in lockstep.
    Mean modulation over a period is 1 — base rates are preserved."""

    def __init__(self, n_functions: int, horizon_min: float, seed: int,
                 n_images: int, image_skew: float, rate_model: str,
                 rate_skew: float, total_rate_per_min: float,
                 amplitude: float, period_min: float, peak_min: float,
                 phase_jitter_min: float, resolution_min: float,
                 block_min: float, chunk_min: float):
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
        if period_min <= 0:
            raise ValueError(f"period_min must be > 0, got {period_min}")
        super().__init__(tag=_TAG_DIURNAL, seed=seed, rows=n_functions,
                         resolution_min=resolution_min,
                         n_functions=n_functions, horizon_min=horizon_min,
                         block_min=block_min, chunk_min=chunk_min)
        self.rates = _base_rates(n_functions, seed, rate_model, rate_skew,
                                 total_rate_per_min)
        self.images = assign_images(n_functions, n_images, image_skew, seed)
        self.amplitude = float(amplitude)
        self.period_min = float(period_min)
        setup = block_rng(seed, _TAG_DIURNAL, 0, 1)   # distinct from blocks
        self.peaks = peak_min + setup.uniform(
            -phase_jitter_min, phase_jitter_min, size=n_functions)

    def meta_traces(self) -> List[Trace]:
        return [Trace(i, float(r), np.empty((0,), np.float64),
                      image_id=int(img))
                for i, (r, img) in enumerate(zip(self.rates, self.images))]

    def _block_rates(self, block, starts, widths):
        mid = starts + widths / 2.0
        phase = 2.0 * np.pi * (mid[None, :] - self.peaks[:, None]) \
            / self.period_min
        return self.rates[:, None] * (1.0 + self.amplitude * np.cos(phase))


class BurstTraceStream(_BinnedPoissonStream):
    """Correlated bursts: each burst picks one image (Zipf-weighted, so hot
    images storm most) and multiplies the rate of EVERY function on it for
    ``burst_duration_min`` — a deploy storm — followed by decaying retry
    echoes at backoff offsets — a retry stampede. The burst schedule is drawn
    once from the seed (bounded state), so blocks stay independent."""

    def __init__(self, n_functions: int, horizon_min: float, seed: int,
                 n_images: int, image_skew: float, rate_model: str,
                 rate_skew: float, total_rate_per_min: float, n_bursts: int,
                 burst_duration_min: float, burst_multiplier: float,
                 retries: int, retry_backoff_min: float, retry_decay: float,
                 resolution_min: float, block_min: float, chunk_min: float):
        if n_bursts < 0:
            raise ValueError(f"n_bursts must be >= 0, got {n_bursts}")
        if burst_multiplier < 1.0:
            raise ValueError(
                f"burst_multiplier must be >= 1, got {burst_multiplier}")
        super().__init__(tag=_TAG_BURSTS, seed=seed, rows=n_functions,
                         resolution_min=resolution_min,
                         n_functions=n_functions, horizon_min=horizon_min,
                         block_min=block_min, chunk_min=chunk_min)
        self.rates = _base_rates(n_functions, seed, rate_model, rate_skew,
                                 total_rate_per_min)
        self.images = assign_images(n_functions, n_images, image_skew, seed)
        setup = block_rng(seed, _TAG_BURSTS, 0, 1)
        starts = np.sort(setup.uniform(0.0, horizon_min, size=n_bursts),
                         kind="stable")
        imgs = setup.choice(max(n_images, 1), size=n_bursts,
                            p=zipf_weights(max(n_images, 1), image_skew))
        # (start, end, image, extra-multiplier) windows incl. retry echoes
        self.windows: List[Tuple[float, float, int, float]] = []
        for s, img in zip(starts, imgs):
            boost = burst_multiplier - 1.0
            for j in range(retries + 1):
                off = s + j * retry_backoff_min
                self.windows.append(
                    (off, off + burst_duration_min, int(img),
                     boost * (retry_decay ** j)))

    def meta_traces(self) -> List[Trace]:
        return [Trace(i, float(r), np.empty((0,), np.float64),
                      image_id=int(img))
                for i, (r, img) in enumerate(zip(self.rates, self.images))]

    def _block_rates(self, block, starts, widths):
        lam = np.repeat(self.rates[:, None], len(starts), axis=1)
        lo, hi = starts[0], starts[-1] + widths[-1]
        for (s, e, img, boost) in self.windows:
            if e <= lo or s >= hi or boost <= 0.0:
                continue
            frac = np.clip(np.minimum(starts + widths, e)
                           - np.maximum(starts, s), 0.0, None) / widths
            rows = self.images == img
            lam[rows] += self.rates[rows, None] * boost * frac[None, :]
        return lam


class TenantMixTraceStream(_BinnedPoissonStream):
    """Multi-tenant mix: tenants own disjoint function and image partitions;
    tenant load shares are Zipf-skewed (tenant 0 is the noisy neighbor) and
    per-function rates are Zipf within each tenant. Pairing the partitioned
    image universes with a bounded ``shared_cache_bytes`` models per-tenant
    cache quotas: each tenant's quota is its own image footprint, and the
    noisy tenant's churn pressures everyone through the shared tier."""

    def __init__(self, n_tenants: int, fns_per_tenant: int,
                 images_per_tenant: int, horizon_min: float, seed: int,
                 tenant_rate_skew: float, rate_skew: float,
                 total_rate_per_min: float, noisy_multiplier: float,
                 resolution_min: float, block_min: float, chunk_min: float):
        if n_tenants < 1 or fns_per_tenant < 1 or images_per_tenant < 1:
            raise ValueError("n_tenants, fns_per_tenant and images_per_tenant "
                             "must all be >= 1")
        n_functions = n_tenants * fns_per_tenant
        super().__init__(tag=_TAG_TENANT, seed=seed, rows=n_functions,
                         resolution_min=resolution_min,
                         n_functions=n_functions, horizon_min=horizon_min,
                         block_min=block_min, chunk_min=chunk_min)
        shares = zipf_weights(n_tenants, tenant_rate_skew)
        shares = shares * np.where(np.arange(n_tenants) == 0,
                                   noisy_multiplier, 1.0)
        within = zipf_weights(fns_per_tenant, rate_skew)
        self.rates = (total_rate_per_min
                      * (shares[:, None] * within[None, :]).ravel())
        self.tenant_of_fn = np.repeat(np.arange(n_tenants, dtype=np.int64),
                                      fns_per_tenant)
        setup = block_rng(seed, _TAG_TENANT, 0, 1)
        imgs = []
        for ten in range(n_tenants):
            local = assign_images(fns_per_tenant, images_per_tenant,
                                  skew=1.2,
                                  seed=int(setup.integers(0, 2**31)))
            imgs.append(ten * images_per_tenant + local)
        self.images = np.concatenate(imgs)

    def meta_traces(self) -> List[Trace]:
        return [Trace(i, float(r), np.empty((0,), np.float64),
                      image_id=int(img))
                for i, (r, img) in enumerate(zip(self.rates, self.images))]

    def _block_rates(self, block, starts, widths):
        return np.repeat(self.rates[:, None], len(starts), axis=1)


class RolloutTraceStream(_BinnedPoissonStream):
    """Image-version rollouts: every function starts on version 0 of its
    image; at each rollout epoch it adopts the next version after a
    per-function canary jitter. A (function, version) pair is a distinct
    *revision* row with its own versioned image id, so the moment a function
    adopts v+1 its traffic cold-starts against an image nothing has built —
    the shared image is invalidated mid-trace exactly like a redeploy, while
    the stale version keeps occupying pool capacity until LRU reclaims it."""

    def __init__(self, n_functions: int, horizon_min: float, seed: int,
                 n_images: int, image_skew: float, rate_model: str,
                 rate_skew: float, total_rate_per_min: float,
                 n_rollouts: int, rollout_stagger_min: float,
                 resolution_min: float, block_min: float, chunk_min: float):
        if n_rollouts < 0:
            raise ValueError(f"n_rollouts must be >= 0, got {n_rollouts}")
        self.n_base_functions = int(n_functions)
        self.n_versions = int(n_rollouts) + 1
        super().__init__(tag=_TAG_ROLLOUT, seed=seed,
                         rows=n_functions * self.n_versions,
                         resolution_min=resolution_min,
                         n_functions=n_functions * self.n_versions,
                         horizon_min=horizon_min, block_min=block_min,
                         chunk_min=chunk_min)
        self.rates = _base_rates(n_functions, seed, rate_model, rate_skew,
                                 total_rate_per_min)
        self.base_images = assign_images(n_functions, n_images, image_skew,
                                         seed)
        self.n_images = int(n_images)
        setup = block_rng(seed, _TAG_ROLLOUT, 0, 1)
        # adoption[f, v]: when fn f starts running version v (v=0 at t=0);
        # epochs split the horizon evenly, canaries jitter per function
        epochs = horizon_min * (np.arange(1, self.n_versions)
                                / self.n_versions)
        jitter = setup.uniform(0.0, rollout_stagger_min,
                               size=(n_functions, max(n_rollouts, 1)))
        adoption = np.zeros((n_functions, self.n_versions))
        if n_rollouts:
            adoption[:, 1:] = np.minimum(epochs[None, :]
                                         + jitter[:, :n_rollouts],
                                         horizon_min)
        self.adoption = adoption

    def _rev(self, fn: int, version: int) -> int:
        return fn + version * self.n_base_functions

    def meta_traces(self) -> List[Trace]:
        out = []
        for v in range(self.n_versions):
            for f in range(self.n_base_functions):
                out.append(Trace(self._rev(f, v), float(self.rates[f]),
                                 np.empty((0,), np.float64),
                                 image_id=int(self.base_images[f])
                                 + v * self.n_images))
        out.sort(key=lambda t: t.fn_index)
        return out

    def _block_rates(self, block, starts, widths):
        n, v = self.n_base_functions, self.n_versions
        lam = np.zeros((n * v, len(starts)))
        ends = np.concatenate([self.adoption[:, 1:],
                               np.full((n, 1), self.horizon_min)], axis=1)
        for ver in range(v):
            a0 = self.adoption[:, ver][:, None]      # active window per fn
            a1 = ends[:, ver][:, None]
            frac = np.clip(np.minimum(starts[None, :] + widths[None, :], a1)
                           - np.maximum(starts[None, :], a0),
                           0.0, None) / widths[None, :]
            lam[ver * n:(ver + 1) * n] = self.rates[:, None] * frac
        return lam


# ------------------------------------------------------------------------------
# Registry entries
# ------------------------------------------------------------------------------

def _emit(st: TraceStream, stream: bool):
    return st if stream else st.materialize()


@TRACE_GENERATORS.register("diurnal")
def generate_diurnal_traces(
        n_functions: int, horizon_min: float = 7 * 24 * 60, seed: int = 0,
        n_images: int = 4, image_skew: float = 1.2,
        rate_model: str = "zipf", rate_skew: float = 1.1,
        total_rate_per_min: float = 2.0, amplitude: float = 0.8,
        period_min: float = 1440.0, peak_min: float = 14 * 60.0,
        phase_jitter_min: float = 120.0, resolution_min: float = 15.0,
        stream: bool = False, block_min: float = DEFAULT_BLOCK_MIN,
        chunk_min: float = DEFAULT_CHUNK_MIN):
    """Diurnal day/night cycles (see :class:`DiurnalTraceStream`)."""
    return _emit(DiurnalTraceStream(
        n_functions, horizon_min, seed, n_images, image_skew, rate_model,
        rate_skew, total_rate_per_min, amplitude, period_min, peak_min,
        phase_jitter_min, resolution_min, block_min, chunk_min), stream)


@TRACE_GENERATORS.register("bursts")
def generate_burst_traces(
        n_functions: int, horizon_min: float = 2 * 24 * 60, seed: int = 0,
        n_images: int = 4, image_skew: float = 1.2,
        rate_model: str = "zipf", rate_skew: float = 1.1,
        total_rate_per_min: float = 2.0, n_bursts: int = 8,
        burst_duration_min: float = 10.0, burst_multiplier: float = 30.0,
        retries: int = 2, retry_backoff_min: float = 5.0,
        retry_decay: float = 0.5, resolution_min: float = 5.0,
        stream: bool = False, block_min: float = DEFAULT_BLOCK_MIN,
        chunk_min: float = DEFAULT_CHUNK_MIN):
    """Correlated deploy storms / retry stampedes
    (see :class:`BurstTraceStream`)."""
    return _emit(BurstTraceStream(
        n_functions, horizon_min, seed, n_images, image_skew, rate_model,
        rate_skew, total_rate_per_min, n_bursts, burst_duration_min,
        burst_multiplier, retries, retry_backoff_min, retry_decay,
        resolution_min, block_min, chunk_min), stream)


@TRACE_GENERATORS.register("tenant_mix")
def generate_tenant_traces(
        n_tenants: int = 4, fns_per_tenant: int = 16,
        images_per_tenant: int = 2, horizon_min: float = 2 * 24 * 60,
        seed: int = 0, tenant_rate_skew: float = 1.0,
        rate_skew: float = 1.1, total_rate_per_min: float = 2.0,
        noisy_multiplier: float = 3.0, resolution_min: float = 15.0,
        stream: bool = False, block_min: float = DEFAULT_BLOCK_MIN,
        chunk_min: float = DEFAULT_CHUNK_MIN):
    """Multi-tenant mix with per-tenant image partitions
    (see :class:`TenantMixTraceStream`)."""
    return _emit(TenantMixTraceStream(
        n_tenants, fns_per_tenant, images_per_tenant, horizon_min, seed,
        tenant_rate_skew, rate_skew, total_rate_per_min, noisy_multiplier,
        resolution_min, block_min, chunk_min), stream)


@TRACE_GENERATORS.register("rollout")
def generate_rollout_traces(
        n_functions: int, horizon_min: float = 2 * 24 * 60, seed: int = 0,
        n_images: int = 2, image_skew: float = 1.2,
        rate_model: str = "zipf", rate_skew: float = 1.1,
        total_rate_per_min: float = 2.0, n_rollouts: int = 2,
        rollout_stagger_min: float = 120.0, resolution_min: float = 15.0,
        stream: bool = False, block_min: float = DEFAULT_BLOCK_MIN,
        chunk_min: float = DEFAULT_CHUNK_MIN):
    """Mid-trace image-version rollouts (see :class:`RolloutTraceStream`)."""
    return _emit(RolloutTraceStream(
        n_functions, horizon_min, seed, n_images, image_skew, rate_model,
        rate_skew, total_rate_per_min, n_rollouts, rollout_stagger_min,
        resolution_min, block_min, chunk_min), stream)
