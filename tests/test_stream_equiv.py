"""Streamed-execution differential suite: running the event engine over
``TraceStream.chunks()`` must be BIT-identical to running it over the same
trace fully materialized — same sha256 over the per-request sample arrays,
same counters, same projections (docs/TRACES.md, "The streaming contract").
Covers:

  * every checked-in fleet scenario spec, wrapped in ``ListTraceStream`` at
    several chunk sizes (including degenerate 1-arrival and whole-trace
    chunks);
  * the four adversarial generators plus the Azure CSV reader executed
    natively (``stream=true`` vs ``stream=false`` through the scenario
    layer), with ``chunk_min`` varied at fixed ``block_min``;
  * a seeded randomized chunk-size fuzz sweep (reduced iterations under
    ``REPRO_SMOKE=1``);
  * the vectorized engine's stream fallback (``fast_path_reason``) and the
    oracle's chunk-wise accumulation (``hindsight_floor``);
  * the scenario/store plumbing: ``stream``/``chunk_min`` are non-semantic
    for ``spec_key``/``point_seed``, and stream+disruption is rejected.
"""
import glob
import os

import numpy as np
import pytest

from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import fast_path_reason, simulate_fleet_vec
from repro.core.oracle import hindsight_floor
from repro.core.scenario import RunOverrides, Scenario, run
from repro.core.simulator import CostModel
from repro.core.trace_stream import ListTraceStream
from repro.core.traces import TRACE_GENERATORS, generate_fleet_traces
from repro.experiments.executor import point_seed
from repro.experiments.store import spec_key

from tests.test_fleet_equiv import _TIER1_TRIMS, _sha, assert_equiv

SCENARIOS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "scenarios")
CM = CostModel.paper_table2()

#: Reduced fuzz budget under the CI smoke job; tier-1 runs the full sweep.
N_FUZZ = 10 if os.environ.get("REPRO_SMOKE") == "1" else 32

#: Specs whose trace generator takes the ``stream`` kwarg, i.e. can execute
#: natively chunked end-to-end through the scenario layer.
STREAMABLE_GENERATORS = ("azure_csv", "diurnal", "bursts", "tenant_mix",
                         "rollout")


def _fleet_spec_paths():
    out = []
    for path in sorted(glob.glob(os.path.join(SCENARIOS_DIR, "*.json"))):
        scn = Scenario.from_file(path)
        if scn.engine in ("fleet", "fleet_vec"):
            out.append(os.path.splitext(os.path.basename(path))[0])
    return out


def _spec(name):
    return Scenario.from_file(os.path.join(SCENARIOS_DIR, f"{name}.json"))


def _smoke_scaled(name):
    scn = _spec(name).smoke_scaled()
    return scn.with_overrides(dict(_TIER1_TRIMS.get(name, {})))


# ---------------------------------------------------------------------------------
# Every checked-in fleet spec: materialized vs ListTraceStream-wrapped
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("name", _fleet_spec_paths())
def test_checked_in_specs_stream_bit_identical(name):
    """The adapter half of the contract: wrapping any in-memory trace list in
    count-sliced chunks (which may split equal-timestamp runs across chunk
    boundaries!) must not change a single output byte — through the full
    scenario layer, so each spec's own page model / placement / prewarm is
    exercised."""
    overrides = {"engine": "fleet"}
    if _spec(name).traces.name in STREAMABLE_GENERATORS:
        overrides["traces.kwargs.stream"] = False
    if _spec(name).disruption is not None:
        # stream + disruption is rejected by design (see
        # test_stream_with_disruption_rejected); drop the component so the
        # chunking invariance of the rest of the spec is still covered
        overrides["disruption"] = None
    scn = _smoke_scaled(name).with_overrides(overrides)
    traces = TRACE_GENERATORS.build(scn.traces.name, **scn.traces.kwargs)
    if hasattr(traces, "materialize"):
        traces = traces.materialize()
    ref = run(scn, overrides=RunOverrides(traces=traces))
    n = sum(len(t.arrivals_min) for t in traces)
    for chunk_size in (1, 7, 1024, max(n, 1)):
        st = ListTraceStream(traces, chunk_size=chunk_size)
        got = run(scn, overrides=RunOverrides(traces=st))
        for method in scn.methods:
            assert_equiv(ref.raw[method], got.raw[method],
                         label=f"{name}/{method}/chunk={chunk_size}")


# ---------------------------------------------------------------------------------
# Native streams through the scenario layer: stream=true vs stream=false
# ---------------------------------------------------------------------------------

def _streamable_spec_names():
    return [n for n in _fleet_spec_paths()
            if _spec(n).traces.name in STREAMABLE_GENERATORS]


@pytest.mark.parametrize("name", _streamable_spec_names())
def test_native_stream_specs_end_to_end(name):
    """The generator half of the contract, through the full scenario layer:
    the checked-in spec executed chunked vs materialized, all methods."""
    scn = _smoke_scaled(name)
    mem = run(scn.with_overrides({"traces.kwargs.stream": False}))
    st = run(scn.with_overrides({"traces.kwargs.stream": True}))
    assert set(mem.raw) == set(st.raw)
    for method in mem.raw:
        assert_equiv(mem.raw[method], st.raw[method],
                     label=f"{name}/{method}/native-stream")
    assert mem.summary == st.summary


@pytest.mark.parametrize("name", _streamable_spec_names())
def test_chunk_min_invariant_end_to_end(name):
    """chunk_min is non-semantic: regrouping blocks into different chunk
    sizes must not change a byte (block_min stays fixed — it IS the RNG
    key)."""
    scn = _smoke_scaled(name)
    base = run(scn.with_overrides({"traces.kwargs.stream": True}))
    block = scn.traces.kwargs.get("block_min", 1440.0)
    for chunk_min in (block, 4 * block, 1e9):
        got = run(scn.with_overrides({"traces.kwargs.stream": True,
                                      "traces.kwargs.chunk_min": chunk_min}))
        for method in base.raw:
            assert_equiv(base.raw[method], got.raw[method],
                         label=f"{name}/{method}/chunk_min={chunk_min}")


# ---------------------------------------------------------------------------------
# Randomized chunk-size fuzz
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("case", range(N_FUZZ))
def test_fuzz_chunk_sizes(case):
    rng = np.random.default_rng(7000 + case)
    traces = generate_fleet_traces(
        n_functions=int(rng.integers(2, 14)),
        horizon_min=float(rng.integers(100, 800)),
        seed=int(rng.integers(0, 1 << 16)),
        n_images=int(rng.integers(1, 4)),
        rate_model="zipf",
        total_rate_per_min=float(rng.uniform(0.5, 4.0)),
    )
    method = ("warmswap", "baseline", "prebaking")[case % 3]
    kwargs = dict(n_workers=int(rng.integers(1, 5)),
                  keep_alive_min=float(rng.integers(1, 30)))
    ref = _simulate_fleet_impl(traces, method, CM, FleetConfig(**kwargs))
    chunk_size = int(rng.integers(1, 500))
    st = ListTraceStream(traces, chunk_size=chunk_size)
    got = _simulate_fleet_impl(st, method, CM, FleetConfig(**kwargs))
    assert_equiv(ref, got, label=f"fuzz[{case}]/chunk={chunk_size}")


# ---------------------------------------------------------------------------------
# Vectorized engine: streams always fall back, bit-identically
# ---------------------------------------------------------------------------------

def test_fleet_vec_falls_back_on_streams():
    traces = generate_fleet_traces(n_functions=6, horizon_min=300.0, seed=3)
    st = ListTraceStream(traces, chunk_size=64)
    reason = fast_path_reason(st, "warmswap", CM)
    assert reason is not None and "stream" in reason
    vec = simulate_fleet_vec(st, "warmswap", CM, FleetConfig(n_workers=2))
    ref = _simulate_fleet_impl(traces, "warmswap", CM,
                               FleetConfig(n_workers=2))
    assert_equiv(ref, vec, label="vec-stream-fallback")


# ---------------------------------------------------------------------------------
# Oracle: chunk-wise accumulation matches the in-memory floor
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("gen", ("diurnal", "bursts"))
def test_hindsight_floor_streams(gen):
    # block_min is the RNG key, so it must match on both sides; only
    # stream/chunk_min may differ
    kw = dict(n_functions=16, horizon_min=480.0, seed=9, block_min=60.0)
    mem = hindsight_floor(TRACE_GENERATORS.build(gen, stream=False, **kw),
                          "warmswap", CM)
    st = hindsight_floor(
        TRACE_GENERATORS.build(gen, stream=True, chunk_min=60.0, **kw),
        "warmswap", CM)
    assert _sha(mem.latency_samples_s) == _sha(st.latency_samples_s)
    assert (mem.n_invocations, mem.n_cold, mem.n_warm) == \
        (st.n_invocations, st.n_cold, st.n_warm)


# ---------------------------------------------------------------------------------
# Scenario / store plumbing
# ---------------------------------------------------------------------------------

def test_stream_with_disruption_rejected():
    scn = _smoke_scaled("adversarial_diurnal").with_overrides({
        "traces.kwargs.stream": True,
        "disruption": {"name": "churn", "kwargs": {}},
    })
    with pytest.raises(ValueError, match="disruption"):
        run(scn)


def test_stream_and_chunk_min_are_non_semantic_for_the_store():
    spec = _spec("adversarial_bursts").to_dict()
    streamed = run(Scenario.from_dict(spec).smoke_scaled().with_overrides(
        {"traces.kwargs.stream": True}))
    assert streamed.raw  # the spec itself runs streamed
    variants = [dict(spec) for _ in range(3)]
    variants[1] = Scenario.from_dict(spec).with_overrides(
        {"traces.kwargs.stream": True}).to_dict()
    variants[2] = Scenario.from_dict(spec).with_overrides(
        {"traces.kwargs.stream": True,
         "traces.kwargs.chunk_min": 360.0}).to_dict()
    keys = {spec_key(v) for v in variants}
    seeds = {point_seed(v) for v in variants}
    assert len(keys) == 1, "stream/chunk_min must not change spec_key"
    assert len(seeds) == 1, "stream/chunk_min must not change point_seed"
    # block_min IS semantic (it keys the per-block RNG)
    semantic = Scenario.from_dict(spec).with_overrides(
        {"traces.kwargs.block_min": 60.0}).to_dict()
    assert spec_key(semantic) not in keys
