"""Declarative scenario API: one serializable spec, one ``run()`` entry point.

The paper's argument is comparative — WarmSwap vs Prebaking vs Baseline under
identical skewed fleets — so the experiment surface here is *data*, not
call-site code. A :class:`Scenario` names every moving part of a simulation
by **string key into a component registry** (trace source, cost model,
page-cost model, keep-alive/pre-warm policy, placement strategy) plus plain
JSON-typed knobs (fleet shape, caps, cache bounds), round-trips losslessly
to/from JSON, and runs through a single :func:`run` returning a unified,
schema-versioned :class:`Result`.

Registries a scenario draws from (all ``repro.core.registry.Registry``
instances; unknown keys fail with did-you-mean suggestions):

  ===================  ======================================  =============
  spec field           registry                                built-in keys
  ===================  ======================================  =============
  ``traces``           ``traces.TRACE_GENERATORS``             azure, fleet,
                                                               azure_csv
  ``cost``             ``simulator.COST_MODELS``               paper_table2,
                                                               scalar
  ``page_cost``        ``costmodel.PAGE_COST_MODELS``          default,
                                                               degenerate
  ``prewarm``          ``keepalive.PREWARM_POLICIES``          none,
                                                               histogram,
                                                               spes, bytes
  ``placement``        ``serving.scheduler.PLACEMENTS``        affinity,
                                                               least_loaded,
                                                               round_robin
  ``disruption``       ``disruption.DISRUPTIONS``              churn, preempt,
                                                               storm
  ===================  ======================================  =============

The legacy imperative surface is preserved as thin wrappers: both
``simulator.simulate()`` and ``fleet.simulate_fleet()`` route through
:func:`run` (via :class:`RunOverrides`, which carries already-resolved
components), so the degenerate-equivalence contract — including the 88 %
memory-saving headline and the 2.2–3.2× dependency-loading band — holds
through the declarative path by construction (asserted in
``tests/test_scenario.py``).

CLI: ``python -m repro.experiments run scenario.json`` /
``... sweep scenario.json --axis n_workers=1,4,16``; shipped specs live in
``benchmarks/scenarios/``. Schema reference: ``docs/API.md``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.costmodel import PAGE_COST_MODELS, PageCostModel
from repro.core.disruption import DISRUPTIONS
from repro.core.keepalive import PREWARM_POLICIES, KeepAlivePolicy
from repro.core.registry import did_you_mean as _did_you_mean
from repro.core.simulator import (COST_MODELS, CostModel,
                                  memory_saving_fraction, quartile_latencies)
from repro.core.trace_stream import NON_SEMANTIC_TRACE_KWARGS, TraceStream
from repro.core.traces import TRACE_GENERATORS, Trace

#: Version of the :class:`Scenario` JSON schema this build reads and writes.
SCHEMA_VERSION = 1
#: Version of the :class:`Result` dict schema this build emits.
RESULT_SCHEMA_VERSION = 1

#: The paper's three start methods — the only valid ``Scenario.methods``.
METHODS = ("warmswap", "prebaking", "baseline")
#: Valid ``Scenario.engine`` values. ``fleet_vec`` is the vectorized batch
#: engine (``core/fleet_vec.py``) — bit-identical results to ``fleet``, with
#: an exact event-engine fallback outside its fast-path domain.
ENGINES = ("single", "fleet", "fleet_vec")


@dataclass
class ComponentSpec:
    """One pluggable component: a registry key plus per-component kwargs.

    In JSON a component is either a bare string (``"histogram"``) or an
    object (``{"name": "histogram", "kwargs": {"percentile": 95}}``).
    ``kwargs`` values must be JSON types; they are passed verbatim to the
    registered factory.
    """
    name: str
    kwargs: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def coerce(cls, value: Any, field_name: str = "component") -> "ComponentSpec":
        """A :class:`ComponentSpec` from a spec string / dict / instance."""
        if isinstance(value, ComponentSpec):
            return value
        if isinstance(value, str):
            return cls(name=value)
        if isinstance(value, Mapping):
            unknown = set(value) - {"name", "kwargs"}
            if unknown:
                raise ValueError(
                    f"unknown key(s) {sorted(unknown)} in {field_name} spec "
                    f"(a component is a string or "
                    f"{{'name': ..., 'kwargs': {{...}}}})")
            if "name" not in value:
                raise ValueError(f"{field_name} spec needs a 'name'")
            return cls(name=value["name"], kwargs=dict(value.get("kwargs") or {}))
        raise TypeError(f"{field_name} spec must be a string or dict, "
                        f"got {type(value).__name__}")

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "kwargs": dict(self.kwargs)}


def _default_methods() -> List[str]:
    return list(METHODS)


@dataclass
class Scenario:
    """A complete, serializable description of one simulation experiment.

    Times are minutes, sizes bytes (the repo-wide simulation units,
    docs/SIMULATION.md). Every component field is a :class:`ComponentSpec`
    (in JSON: a string key or ``{"name", "kwargs"}``); plain fields are
    JSON scalars. ``smoke_overrides`` maps dotted paths into this spec to
    replacement values, applied by ``run(..., smoke=True)`` and the CLI's
    ``--smoke`` so one checked-in spec serves both CI and full-scale runs.
    """
    name: str = "scenario"
    description: str = ""
    schema_version: int = SCHEMA_VERSION
    engine: str = "fleet"                    # 'fleet' | 'fleet_vec' | 'single'
    methods: List[str] = field(default_factory=_default_methods)
    traces: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("azure", {"n_functions": 10}))
    cost: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("paper_table2"))
    page_cost: Optional[ComponentSpec] = None
    prewarm: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("none"))
    placement: ComponentSpec = field(
        default_factory=lambda: ComponentSpec("affinity"))
    n_workers: int = 1
    max_instances_per_fn: Optional[int] = None
    worker_capacity_bytes: Optional[int] = None
    shared_cache_bytes: Optional[int] = None
    disruption: Optional[ComponentSpec] = None   # churn | preempt | storm
    keep_alive_min: float = 15.0
    shared_images: int = 1                   # single-engine memory model
    smoke_overrides: Dict[str, Any] = field(default_factory=dict)

    # ------------------------------------------------------------- validation
    def __post_init__(self) -> None:
        for f in ("traces", "cost", "prewarm", "placement"):
            setattr(self, f, ComponentSpec.coerce(getattr(self, f), f))
        if self.page_cost is not None:
            self.page_cost = ComponentSpec.coerce(self.page_cost, "page_cost")
        if self.disruption is not None:
            self.disruption = ComponentSpec.coerce(self.disruption,
                                                   "disruption")
        self.methods = list(self.methods)
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine: {self.engine!r} (choose from "
                             f"{list(ENGINES)})"
                             + _did_you_mean(self.engine, ENGINES))
        for m in self.methods:
            if m not in METHODS:
                raise ValueError(f"unknown method: {m!r} (choose from "
                                 f"{list(METHODS)})" + _did_you_mean(m, METHODS))
        if not self.methods:
            raise ValueError("scenario needs at least one method")
        if self.engine == "single":
            # the single-worker engine has no fleet shape: accepting these at
            # non-default values would silently simulate something else
            ignored = [name for name, is_default in (
                ("n_workers", self.n_workers == 1),
                ("max_instances_per_fn", self.max_instances_per_fn is None),
                ("worker_capacity_bytes", self.worker_capacity_bytes is None),
                ("shared_cache_bytes", self.shared_cache_bytes is None),
                ("disruption", self.disruption is None),
                ("placement", self.placement == ComponentSpec("affinity")),
                ("prewarm", self.prewarm == ComponentSpec("none")),
            ) if not is_default]
            if ignored:
                raise ValueError(
                    f"engine='single' has no fleet shape; field(s) {ignored} "
                    f"would be silently ignored — remove them or use "
                    f"engine='fleet'")
        elif self.shared_images != 1:
            # ...and the fleet engine derives image counts from the traces
            raise ValueError(
                "shared_images parameterizes the single-engine memory model "
                "and is ignored by engine='fleet' (image sharing comes from "
                "the trace generator's n_images there) — remove it or use "
                "engine='single'")
        # strict loading: unknown component keys fail at construction, with
        # did-you-mean (placement's registry lives behind the repro.serving
        # import and is checked by validate_components() / run() instead)
        TRACE_GENERATORS.resolve(self.traces.name)
        COST_MODELS.resolve(self.cost.name)
        if self.page_cost is not None:
            PAGE_COST_MODELS.resolve(self.page_cost.name)
        if self.disruption is not None:
            DISRUPTIONS.resolve(self.disruption.name)
        PREWARM_POLICIES.resolve(self.prewarm.name)

    def validate_components(self) -> None:
        """Resolve every component key against its registry (raises
        :class:`~repro.core.registry.UnknownComponentError` with did-you-mean
        on failure). Construction already checks all but ``placement``, whose
        registry needs the ``repro.serving`` import; the CLI's ``validate``
        command and :func:`run` both call this."""
        from repro.serving.scheduler import PLACEMENTS
        PLACEMENTS.resolve(self.placement.name)

    # ---------------------------------------------------------- serialization
    def to_dict(self) -> Dict[str, Any]:
        """A plain JSON-typed dict; ``from_dict`` of it is identity."""
        d: Dict[str, Any] = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, ComponentSpec):
                v = v.to_dict()
            elif isinstance(v, (list, tuple)):
                v = list(v)
            elif isinstance(v, dict):
                v = dict(v)
            d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Scenario":
        """Build and validate a scenario from a JSON-shaped dict.

        Rejects unknown top-level keys (with did-you-mean suggestions) and
        specs written by a *newer* schema than this build understands.
        """
        if not isinstance(d, Mapping):
            raise TypeError(f"scenario spec must be a dict, "
                            f"got {type(d).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        for key in d:
            if key not in known:
                raise ValueError(f"unknown scenario field: {key!r}"
                                 + _did_you_mean(key, known))
        version = d.get("schema_version", SCHEMA_VERSION)
        if not isinstance(version, int) or version < 1:
            raise ValueError(f"schema_version must be a positive integer, "
                             f"got {version!r}")
        if version > SCHEMA_VERSION:
            raise ValueError(
                f"scenario schema_version {version} is newer than this build "
                f"supports (<= {SCHEMA_VERSION}); update the repo or re-export "
                f"the spec")
        return cls(**dict(d))

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "Scenario":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_file(cls, path: str) -> "Scenario":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -------------------------------------------------------------- overrides
    def with_overrides(self, overrides: Mapping[str, Any]) -> "Scenario":
        """A new scenario with dotted-path overrides applied to the spec dict
        (e.g. ``{"traces.kwargs.horizon_min": 1440, "n_workers": 4}``) and
        re-validated. The base scenario is untouched."""
        d = self.to_dict()
        for path, value in overrides.items():
            _set_path(d, path, value)
        return Scenario.from_dict(d)

    def smoke_scaled(self) -> "Scenario":
        """This scenario with its own ``smoke_overrides`` applied (identity
        when none are declared)."""
        if not self.smoke_overrides:
            return self
        return self.with_overrides(self.smoke_overrides)


def _set_path(d: Dict[str, Any], path: str, value: Any) -> None:
    """Set ``d[a][b][c] = value`` for ``path`` ``'a.b.c'``, creating
    intermediate dicts as needed."""
    parts = path.split(".")
    node = d
    for p in parts[:-1]:
        nxt = node.get(p)
        if not isinstance(nxt, dict):
            nxt = {}
            node[p] = nxt
        node = nxt
    node[parts[-1]] = value


def sweep(base: Scenario, axes: Mapping[str, Sequence[Any]]) -> List[Scenario]:
    """Expand grid ``axes`` over ``base`` into one scenario per grid cell.

    Axis keys are dotted paths into the spec dict (``"n_workers"``,
    ``"traces.kwargs.n_images"``, ``"placement.name"``); values are the
    points along that axis. The grid is the cartesian product in the axes'
    given order, and each expanded scenario's name records its coordinates
    (``base[n_workers=4,placement.name=affinity]``).

    Returns:
        One validated :class:`Scenario` per cell; ``base`` is untouched.
    """
    if not axes:
        return [base]
    keys = list(axes)
    out = []
    for values in itertools.product(*(axes[k] for k in keys)):
        coords = dict(zip(keys, values))
        label = ",".join(f"{k}={v}" for k, v in coords.items())
        scn = base.with_overrides(coords)
        scn.name = f"{base.name}[{label}]"
        out.append(scn)
    return out


# -------------------------------------------------------------------------------
# The unified result schema
# -------------------------------------------------------------------------------

@dataclass
class MethodResult:
    """One method's outcomes in engine-independent shape (latencies in
    seconds, memory in bytes, residency in instance-minutes). Fields the
    single-worker engine cannot produce (pool/cache/pre-warm counters) hold
    their zero defaults there."""
    method: str
    n_invocations: int
    n_cold: int
    n_warm: int
    total_latency_s: float
    avg_latency_s: float
    latency_percentiles_s: Dict[str, float]
    quartile_latency_s: Dict[str, float]
    memory_bytes: int
    n_queued: int = 0
    queue_delay_s: float = 0.0
    pool_misses: int = 0
    evictions: int = 0
    prewarm_spawns: int = 0
    prewarm_hits: int = 0
    prewarm_dropped: int = 0
    max_concurrent_instances: int = 1
    instance_resident_min: float = 0.0
    cache_hits: Dict[str, int] = field(
        default_factory=lambda: {"local": 0, "remote": 0, "miss": 0})
    pages_transferred: int = 0
    shared_cache_peak_bytes: int = 0
    shared_cache_evictions: int = 0
    placement_warm_hits: int = 0
    placement_pool_hits: int = 0
    requeued: int = 0
    worker_failures: int = 0
    worker_recoveries: int = 0
    cache_flushes: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclass
class Result:
    """One :func:`run`'s outputs: the spec echo, per-method unified results,
    and cross-method summary numbers. ``raw`` keeps the engine-native
    ``SimResult`` / ``FleetResult`` objects (latency sample arrays included)
    and ``traces`` the resolved arrival traces, for callers that need them
    (e.g. per-quartile percentile breakdowns); neither is serialized.

    ``methods`` is computed lazily from ``raw`` on first access: the unified
    projection pays a percentile pass over every latency sample, which the
    legacy ``simulate()``/``simulate_fleet()`` wrappers (which only read
    ``raw``) should not be charged for."""
    scenario: Dict[str, Any]
    engine: str
    summary: Dict[str, float]
    result_schema_version: int = RESULT_SCHEMA_VERSION
    raw: Dict[str, Any] = field(default_factory=dict, repr=False)
    traces: List[Trace] = field(default_factory=list, repr=False)
    _methods: Optional[Dict[str, MethodResult]] = field(default=None,
                                                        repr=False)

    @property
    def methods(self) -> Dict[str, MethodResult]:
        if self._methods is None:
            self._methods = {m: _method_result(r, self.traces)
                             for m, r in self.raw.items()}
        return self._methods

    def to_dict(self) -> Dict[str, Any]:
        return {
            "result_schema_version": self.result_schema_version,
            "scenario": self.scenario,
            "engine": self.engine,
            "methods": {m: r.to_dict() for m, r in self.methods.items()},
            "summary": dict(self.summary),
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)


#: Keys every serialized per-method result must carry (subset of
#: :class:`MethodResult`; checked by :func:`validate_result`).
_REQUIRED_METHOD_KEYS = ("method", "n_invocations", "n_cold", "n_warm",
                         "total_latency_s", "avg_latency_s",
                         "latency_percentiles_s", "memory_bytes")


def validate_result(d: Mapping[str, Any]) -> Mapping[str, Any]:
    """Validate a serialized :class:`Result` dict (CI's scenario smoke job
    runs every checked-in spec through this). Raises ``ValueError`` on a
    missing key, a future result schema, an unknown method, or a non-finite/
    negative latency; returns ``d`` unchanged when valid."""
    for key in ("result_schema_version", "scenario", "engine", "methods",
                "summary"):
        if key not in d:
            raise ValueError(f"result is missing {key!r}")
    version = d["result_schema_version"]
    if not isinstance(version, int) or version > RESULT_SCHEMA_VERSION:
        raise ValueError(f"unsupported result_schema_version {version!r} "
                         f"(<= {RESULT_SCHEMA_VERSION})")
    if not d["methods"]:
        raise ValueError("result has no methods")
    for m, mr in d["methods"].items():
        if m not in METHODS:
            raise ValueError(f"unknown method in result: {m!r}")
        for key in _REQUIRED_METHOD_KEYS:
            if key not in mr:
                raise ValueError(f"method {m!r} result is missing {key!r}")
        lats = [mr["total_latency_s"], mr["avg_latency_s"],
                mr.get("queue_delay_s", 0.0),
                *mr["latency_percentiles_s"].values()]
        for v in lats:
            if not math.isfinite(v) or v < 0:
                raise ValueError(f"method {m!r} has a non-finite or negative "
                                 f"latency: {v!r}")
    return d


# -------------------------------------------------------------------------------
# The one entry point
# -------------------------------------------------------------------------------

@dataclass
class RunOverrides:
    """Already-resolved components that bypass registry construction.

    This is how the legacy wrappers (``simulate()`` / ``simulate_fleet()``)
    route through :func:`run` with the live objects their callers handed
    them — including non-serializable ones (policy instances, a fully
    configured ``FleetConfig``). Any field left ``None`` is built from the
    scenario spec as usual.
    """
    traces: Optional[Union[List[Trace], TraceStream]] = None
    cost: Optional[CostModel] = None
    page_cost: Optional[PageCostModel] = None
    keep_alive: Optional[KeepAlivePolicy] = None   # single engine only
    fleet: Optional["FleetConfig"] = None          # fleet engine only


def _method_result(r, traces: List[Trace]) -> MethodResult:
    """Project a ``SimResult`` or ``FleetResult`` onto the unified schema."""
    is_fleet = hasattr(r, "pool_misses")
    return MethodResult(
        method=r.method,
        n_invocations=r.n_invocations,
        n_cold=r.n_cold,
        n_warm=r.n_warm,
        total_latency_s=float(r.total_latency_s),
        avg_latency_s=float(r.avg_latency_s),
        latency_percentiles_s=r.latency_percentiles(),
        quartile_latency_s=quartile_latencies(traces, r),
        memory_bytes=int(r.memory_bytes),
        n_queued=r.n_queued,
        queue_delay_s=float(r.queue_delay_s),
        pool_misses=r.pool_misses if is_fleet else 0,
        evictions=r.evictions if is_fleet else 0,
        prewarm_spawns=r.prewarm_spawns if is_fleet else 0,
        prewarm_hits=r.prewarm_hits if is_fleet else 0,
        prewarm_dropped=r.prewarm_dropped if is_fleet else 0,
        max_concurrent_instances=(r.max_concurrent_instances
                                  if is_fleet else 1),
        instance_resident_min=(float(r.instance_resident_min)
                               if is_fleet else 0.0),
        cache_hits=({"local": r.cache_local_hits,
                     "remote": r.cache_remote_hits,
                     "miss": r.cache_misses} if is_fleet
                    else {"local": 0, "remote": 0, "miss": 0}),
        pages_transferred=r.pages_transferred if is_fleet else 0,
        shared_cache_peak_bytes=(r.shared_cache_peak_bytes
                                 if is_fleet else 0),
        shared_cache_evictions=(r.shared_cache_evictions
                                if is_fleet else 0),
        placement_warm_hits=r.placement_warm_hits if is_fleet else 0,
        placement_pool_hits=r.placement_pool_hits if is_fleet else 0,
        requeued=r.requeued if is_fleet else 0,
        worker_failures=r.worker_failures if is_fleet else 0,
        worker_recoveries=r.worker_recoveries if is_fleet else 0,
        cache_flushes=r.cache_flushes if is_fleet else 0,
    )


def run(scenario: Scenario, *, smoke: bool = False,
        overrides: Optional[RunOverrides] = None,
        sanitize: Optional[bool] = None) -> Result:
    """Run one scenario end to end: resolve components from the registries,
    simulate every method, return the unified :class:`Result`.

    This is the single simulation entry point — the legacy ``simulate()`` /
    ``simulate_fleet()`` signatures are thin wrappers over it (they pass
    resolved components via ``overrides``), so declarative and imperative
    callers exercise the same engines.

    Args:
        scenario: the spec (typically ``Scenario.from_file(...)``).
        smoke: apply the spec's ``smoke_overrides`` first (CI scale).
        overrides: already-resolved components to use instead of building
            from the spec (see :class:`RunOverrides`).
        sanitize: run under the repro-san invariant sanitizer
            (``repro.core.sanitize``): instrumented assertions at every
            drain step, a :class:`~repro.core.sanitize.SanitizeError` with
            a repro artifact on violation, bit-identical results otherwise.
            ``None`` (default) follows the ``REPRO_SANITIZE`` env knob.

    Returns:
        A :class:`Result`; ``result.raw[method]`` holds the engine-native
        per-method result objects.
    """
    # deferred: fleet imports this module's wrappers' home modules —
    # importing it at module load would be circular
    from repro.core.fleet import FleetConfig, _simulate_fleet_impl
    from repro.core.sanitize import FleetSanitizer, sanitize_enabled
    from repro.core.simulator import _simulate_impl

    scn = scenario.smoke_scaled() if smoke else scenario
    ov = overrides if overrides is not None else RunOverrides()
    san_on = sanitize_enabled() if sanitize is None else bool(sanitize)
    scn_dict = scn.to_dict() if san_on else None

    traces = (ov.traces if ov.traces is not None
              else TRACE_GENERATORS.build(scn.traces.name, **scn.traces.kwargs))
    if isinstance(traces, TraceStream):
        # chunked execution: the fleet event engine consumes the stream
        # natively (bit-identical to the materialized run — docs/TRACES.md);
        # fleet_vec falls back to it via fast_path_reason. The single engine
        # has no chunked path, so it materializes.
        if scn.engine == "single":
            traces = traces.materialize()
        elif scn.disruption is not None:
            raise ValueError(
                "disruption schedules are built against the trace horizon, "
                "which a stream only knows after its last chunk; set "
                "traces.kwargs.stream=false to combine disruption with "
                "this workload")
    cost = (ov.cost if ov.cost is not None
            else COST_MODELS.build(scn.cost.name, **scn.cost.kwargs))
    page = ov.page_cost
    if page is None and scn.page_cost is not None:
        page = PAGE_COST_MODELS.build(scn.page_cost.name, cost=cost,
                                      **scn.page_cost.kwargs)

    raw: Dict[str, Any] = {}
    if scn.engine == "single":
        # no placement validation here: the single engine has none, and
        # construction already rejected a non-default placement spec — so a
        # simulation-only caller never pays the repro.serving import
        keep_alive = (ov.keep_alive if ov.keep_alive is not None
                      else KeepAlivePolicy(scn.keep_alive_min))
        for m in scn.methods:
            raw[m] = _simulate_impl(traces, m, cost, keep_alive,
                                    scn.shared_images, page)
            if san_on:
                FleetSanitizer("single", m,
                               scenario=scn_dict).check_single(raw[m])
    else:
        # deferred: repro.serving pulls in the model/engine stack
        from repro.serving.scheduler import PLACEMENTS
        scn.validate_components()
        fleet_cfg = ov.fleet
        if fleet_cfg is None:
            placement = (scn.placement.name if not scn.placement.kwargs
                         else PLACEMENTS.build(scn.placement.name,
                                               **scn.placement.kwargs))
            prewarm = (scn.prewarm.name if not scn.prewarm.kwargs
                       else PREWARM_POLICIES.build(scn.prewarm.name,
                                                   **scn.prewarm.kwargs))
            disruption = None
            if scn.disruption is not None:
                # schedule factories take the runtime-injected fleet shape:
                # the worker count and the trace horizon (last arrival)
                horizon = max((float(t.arrivals_min[-1]) for t in traces
                               if len(t.arrivals_min)), default=0.0)
                disruption = DISRUPTIONS.build(
                    scn.disruption.name, n_workers=scn.n_workers,
                    horizon_min=horizon, **scn.disruption.kwargs)
            fleet_cfg = FleetConfig(
                n_workers=scn.n_workers,
                placement=placement,
                max_instances_per_fn=scn.max_instances_per_fn,
                worker_capacity_bytes=scn.worker_capacity_bytes,
                prewarm=prewarm,
                keep_alive_min=scn.keep_alive_min,
                page_cost=page,
                shared_cache_bytes=scn.shared_cache_bytes,
                disruption=disruption,
            )
        if scn.engine == "fleet_vec":
            from repro.core.fleet_vec import simulate_fleet_vec
            impl = simulate_fleet_vec
        else:
            impl = _simulate_fleet_impl
        for m in scn.methods:
            if san_on:
                raw[m] = impl(traces, m, cost, fleet_cfg,
                              sanitizer=FleetSanitizer(scn.engine, m,
                                                       scenario=scn_dict))
            else:
                raw[m] = impl(traces, m, cost, fleet_cfg)

    summary: Dict[str, float] = {}
    if "warmswap" in raw and "prebaking" in raw:
        summary["memory_saving_vs_prebaking"] = memory_saving_fraction(
            raw["warmswap"], raw["prebaking"])
    if page is not None:
        # the paper's dependency-loading comparison (2.2-3.2x band at the
        # ~230 MB paper-scale image) priced by the scenario's own page model
        summary["dependency_loading_speedup"] = (
            page.dependency_loading_speedup())
    return Result(scenario=scn.to_dict(), engine=scn.engine,
                  summary=summary, raw=raw,
                  traces=(traces.meta_traces()
                          if isinstance(traces, TraceStream) else traces))
