"""Deterministic synthetic token pipeline with host sharding and prefetch.

Training data for the end-to-end drivers: a seeded Zipf-ish token stream that is
  * **deterministic per (seed, step, host)** — restart/elastic-rescale resume produces
    bit-identical batches (the fault-tolerance contract: a restarted run replays the
    same data order), and
  * **host-sharded** — each host generates only its slice of the global batch
    (process_index/process_count), so no cross-host data motion at scale, and
  * **prefetched** — a background thread keeps ``prefetch_depth`` batches ready so
    host-side generation overlaps device compute.

Batches follow the model API: {'tokens': (B_local, S) int32} plus stub frontend
embeddings for [audio]/[vlm] archs.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ArchConfig


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    prefetch_depth: int = 2
    zipf_a: float = 1.2           # skewed token distribution (more LM-like than uniform)


def _batch_for_step(cfg: ArchConfig, data: DataConfig, step: int,
                    host_index: int, host_count: int) -> Dict[str, np.ndarray]:
    local_batch = data.global_batch // host_count
    rng = np.random.default_rng(
        np.random.SeedSequence([data.seed, step, host_index]))
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0
    seq = data.seq_len - n_front
    # Zipf draw clipped to vocab (rejection-free: modulo fold)
    raw = rng.zipf(data.zipf_a, size=(local_batch, seq)).astype(np.int64)
    tokens = (raw % cfg.vocab_size).astype(np.int32)
    batch: Dict[str, np.ndarray] = {"tokens": tokens}
    if cfg.frontend == "audio_frames":
        batch["frames"] = rng.standard_normal(
            (local_batch, cfg.n_enc_positions, cfg.d_model)).astype(np.float32) * 0.02
    elif cfg.frontend == "vision_patches":
        batch["patches"] = rng.standard_normal(
            (local_batch, n_front, cfg.d_model)).astype(np.float32) * 0.02
    return batch


def make_batch_specs(cfg: ArchConfig, data: DataConfig) -> Dict[str, tuple]:
    """Abstract shapes of one GLOBAL batch (for dry-run input_specs)."""
    n_front = cfg.n_frontend_tokens if cfg.frontend == "vision_patches" else 0
    specs = {"tokens": ((data.global_batch, data.seq_len - n_front), np.int32)}
    if cfg.frontend == "audio_frames":
        specs["frames"] = ((data.global_batch, cfg.n_enc_positions, cfg.d_model),
                           np.float32)
    elif cfg.frontend == "vision_patches":
        specs["patches"] = ((data.global_batch, n_front, cfg.d_model), np.float32)
    return specs


class SyntheticTokenPipeline:
    """Iterator over deterministic batches with background prefetch."""

    def __init__(self, cfg: ArchConfig, data: DataConfig, *, start_step: int = 0,
                 host_index: int = 0, host_count: int = 1):
        assert data.global_batch % host_count == 0
        self.cfg = cfg
        self.data = data
        self.host_index = host_index
        self.host_count = host_count
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=data.prefetch_depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = _batch_for_step(self.cfg, self.data, step,
                                    self.host_index, self.host_count)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def peek_step(self) -> int:
        return self._step

    def close(self) -> None:
        self._stop.set()

    @staticmethod
    def batch_at(cfg: ArchConfig, data: DataConfig, step: int,
                 host_index: int = 0, host_count: int = 1) -> Dict[str, np.ndarray]:
        """Random access (replay/verification path)."""
        return _batch_for_step(cfg, data, step, host_index, host_count)
