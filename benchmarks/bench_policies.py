"""Paper Table 2: cold/warm starts across the four restore prototypes
(bulk restore, lazy restore, w/o page server, w/o lazy migration) for the three
dependency-heavy serving functions."""
from __future__ import annotations

from typing import Dict

from benchmarks.common import build_fleet, emit, median, save_json

FUNCTIONS = ["lr_serving", "cnn_serving", "rnn_serving"]
ITERS = 3


def run() -> Dict:
    from repro.core import RestorePolicy
    from repro.core import workloads as wl
    mgr, reg, orch = build_fleet()
    rows: Dict = {}
    for policy in [RestorePolicy.BULK, RestorePolicy.LAZY,
                   RestorePolicy.NO_PAGESERVER, RestorePolicy.NO_LAZY]:
        rows[policy.value] = {}
        for fn in FUNCTIONS:
            cold, warm = [], []
            stats = None
            for _ in range(ITERS):
                inst, t = orch.cold_start_warmswap(fn, policy=policy)
                cold.append(t.total)
                req = wl.WORKLOADS[fn].request_builder()
                warm.append(min(inst.invoke(req)[1] for _ in range(3)))
                stats = getattr(inst, "migration_stats", None)
            rows[policy.value][fn] = {
                "cold_s": median(cold),
                "warm_s": median(warm),
                "pages": getattr(stats, "pages_transferred", None),
                "requests": getattr(stats, "requests", None),
                "fault_wait_s": getattr(stats, "fault_wait_s", None),
            }
            emit(f"policy/{policy.value}/{fn}", median(cold) * 1e6,
                 f"warm={median(warm)*1e6:.0f}us pages="
                 f"{rows[policy.value][fn]['pages']}")
    save_json("bench_policies", rows)
    return rows


if __name__ == "__main__":
    run()
