"""Unified model definition covering all 10 assigned architectures.

One code path, driven entirely by :class:`ArchConfig`:

  * decoder-only LMs (dense / MoE) with any repeating attention pattern
    (full, sliding-window, local+global alternating);
  * attention-free SSM stacks (Mamba-1);
  * hybrid recurrent/attention stacks (RG-LRU, Griffin pattern with remainder layers);
  * encoder-decoder (whisper) with cross-attention and a stubbed audio frontend;
  * VLM (stubbed vision frontend: precomputed patch embeddings prepended).

Layers are applied with **scan-over-pattern-units**: parameters for one repeating
pattern unit are stacked along a leading ``n_units`` axis and the unit body is scanned,
so the lowered HLO is depth-independent (critical for compiling 46–64-layer models for
512 devices). Remainder layers (e.g. recurrentgemma's 26 = 8x3 + 2) are applied
unstacked after the scan.

Fidelity notes (see DESIGN.md): gemma2's post-block norms are folded into the pre-norm
(shape/FLOP-neutral); whisper uses sinusoidal positions on both sides.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig, GLOBAL_ATTN, LOCAL_ATTN, RECURRENT, SSM
from repro.models.layers import (
    embed_tokens,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    sinusoidal_position_at,
    sinusoidal_positions,
    unembed,
)

LayerParams = Dict[str, Any]


# ---------------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, ltype: str, dtype, *, cross: bool = False) -> LayerParams:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if ltype == SSM:
        return {"ln1": init_rmsnorm(d, dtype), "ssm": ssm_mod.init_ssm(ks[0], cfg, dtype)}
    p: LayerParams = {"ln1": init_rmsnorm(d, dtype)}
    if ltype == RECURRENT:
        p["rec"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if cross:
        p["lnx"] = init_rmsnorm(d, dtype)
        p["xattn"] = attn.init_attention(ks[1], cfg, dtype, cross=True)
    p["ln2"] = init_rmsnorm(d, dtype)
    if cfg.n_experts > 0:
        p["moe"] = moe_mod.init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg, dtype)
    return p


def _init_unit(key, cfg: ArchConfig, dtype, *, cross: bool = False) -> Tuple[LayerParams, ...]:
    ks = jax.random.split(key, len(cfg.attn_pattern))
    return tuple(
        _init_layer(ks[i], cfg, t, dtype, cross=cross)
        for i, t in enumerate(cfg.attn_pattern)
    )


def init_params(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    n_units = cfg.n_pattern_units
    unit_keys = jax.random.split(keys[1], n_units)
    params["unit"] = jax.vmap(
        lambda k: _init_unit(k, cfg, dtype, cross=cfg.is_encoder_decoder)
    )(unit_keys)
    rem_keys = jax.random.split(keys[2], max(cfg.n_remainder_layers, 1))
    params["rem"] = tuple(
        _init_layer(rem_keys[i], cfg, cfg.attn_pattern[i % len(cfg.attn_pattern)], dtype,
                    cross=cfg.is_encoder_decoder)
        for i in range(cfg.n_remainder_layers)
    )
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same width; encoder layers are non-causal global attention
        enc_keys = jax.random.split(keys[3], cfg.n_enc_layers)
        params["enc"] = jax.vmap(
            lambda k: _init_layer(k, enc_cfg, GLOBAL_ATTN, dtype)
        )(enc_keys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------------
# Layer application — full-sequence (train / prefill)
# ---------------------------------------------------------------------------------

def _apply_mlp_part(p: LayerParams, x: jax.Array, cfg: ArchConfig,
                    *, decode: bool = False):
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.n_experts > 0:
        out, aux = moe_mod.moe_ffn(p["moe"], h, cfg, no_drop=decode)
    else:
        out, aux = mlp(p["mlp"], h, cfg.mlp), jnp.float32(0.0)
    return x + out, aux


def _apply_layer_seq(
    p: LayerParams,
    x: jax.Array,
    cfg: ArchConfig,
    ltype: str,
    positions: jax.Array,
    *,
    causal: bool,
    make_state: bool,
    state_len: Optional[int] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    q_chunk: int = 512,
    rec_chunk: int = 256,
):
    """Returns (x, aux_loss, state_or_None)."""
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    state = None
    if ltype == SSM:
        out, state = ssm_mod.ssm_prefill(p["ssm"], h, cfg, make_state=make_state,
                                         chunk=rec_chunk)
        return x + out, jnp.float32(0.0), state
    if ltype == RECURRENT:
        out, state = rglru_mod.rglru_prefill(p["rec"], h, cfg, make_state=make_state,
                                             chunk=rec_chunk)
        x = x + out
    else:
        out, cache = attn.attention_prefill(
            p["attn"], h, cfg, ltype, positions,
            causal=causal, make_cache=make_state, state_len=state_len, q_chunk=q_chunk)
        x = x + out
        state = cache
    if cross_kv is not None:
        hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], hx, cross_kv[0], cross_kv[1], cfg)
    x, aux = _apply_mlp_part(p, x, cfg)
    return x, aux, state


# ---------------------------------------------------------------------------------
# Layer application — single-token decode
# ---------------------------------------------------------------------------------

def _apply_layer_decode(
    p: LayerParams,
    x: jax.Array,             # (B, 1, D)
    st,
    pos,
    cfg: ArchConfig,
    ltype: str,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if ltype == SSM:
        out, st = ssm_mod.ssm_decode(p["ssm"], h, st, cfg)
        return x + out, st
    if ltype == RECURRENT:
        out, st = rglru_mod.rglru_decode(p["rec"], h, st, cfg)
        x = x + out
    else:
        out, st = attn.attention_decode(p["attn"], h, st, pos, cfg, ltype)
        x = x + out
    if cross_kv is not None:
        hx = rmsnorm(p["lnx"], x, cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], hx, cross_kv[0], cross_kv[1], cfg)
    x, _ = _apply_mlp_part(p, x, cfg, decode=True)
    return x, st


# ---------------------------------------------------------------------------------
# Empty decode state (for dry-run input_specs and fresh decoding)
# ---------------------------------------------------------------------------------

def _empty_layer_state(cfg: ArchConfig, ltype: str, batch: int, seq_len: int, dtype):
    if ltype == SSM:
        return ssm_mod.empty_ssm_state(cfg, batch, dtype)
    if ltype == RECURRENT:
        return rglru_mod.empty_rglru_state(cfg, batch, dtype)
    return attn.empty_cache(cfg, ltype, batch, seq_len, dtype)


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    n_units = cfg.n_pattern_units
    unit = tuple(
        jax.tree.map(lambda a: jnp.broadcast_to(a, (n_units,) + a.shape).copy()
                     if n_units > 0 else a,
                     _empty_layer_state(cfg, t, batch, seq_len, dtype))
        for t in cfg.attn_pattern
    )
    rem = tuple(
        _empty_layer_state(cfg, cfg.attn_pattern[i % len(cfg.attn_pattern)], batch,
                           seq_len, dtype)
        for i in range(cfg.n_remainder_layers)
    )
    state: Dict[str, Any] = {"unit": unit, "rem": rem,
                             "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.is_encoder_decoder:
        hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        state["cross"] = {
            "k": jnp.zeros((n_units, batch, cfg.n_enc_positions, hk, hd), dtype),
            "v": jnp.zeros((n_units, batch, cfg.n_enc_positions, hk, hd), dtype),
        }
    return state


# ---------------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------------

def encode(params, frames: jax.Array, cfg: ArchConfig, *, remat: bool = False):
    """frames: (B, S_enc, D) precomputed stub embeddings -> encoder output."""
    S = frames.shape[1]
    # stub embeddings arrive fp32; run the stack in the param compute dtype
    frames = frames.astype(params["enc_norm"]["scale"].dtype)
    x = frames + sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, unit_p):
        y, _, _ = _apply_layer_seq(unit_p, carry, cfg, GLOBAL_ATTN, positions,
                                   causal=False, make_state=False)
        return y, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------------
# Full forward (train / prefill)
# ---------------------------------------------------------------------------------

def forward(
    params,
    tokens: jax.Array,                      # (B, S_tok) int32
    cfg: ArchConfig,
    *,
    frontend_embeds: Optional[jax.Array] = None,  # (B, S_front, D) for audio/vlm
    make_state: bool = False,
    state_len: Optional[int] = None,        # decode-state capacity (prompt + budget)
    remat: str = "none",                    # none | unit | dots
    q_chunk: int = 512,
    rec_chunk: int = 256,
    logits_slice: Optional[int] = None,     # keep only the last N positions' logits
    return_features: bool = False,          # skip unembed (loss computes it chunked)
):
    """Returns (logits fp32 (B, S, Vp) — or features (B, S, D) if
    ``return_features`` — , aux_loss, state_or_None)."""
    x = embed_tokens(params["embed"], tokens, cfg)
    cross_kv_seq = None
    if cfg.is_encoder_decoder:
        assert frontend_embeds is not None, "whisper needs stub frame embeddings"
        enc_out = encode(params, frontend_embeds, cfg, remat=(remat != "none"))
        S = tokens.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    elif frontend_embeds is not None:       # VLM: prepend patch embeddings
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    S_total = x.shape[1]
    positions = jnp.arange(S_total, dtype=jnp.int32)

    def unit_body(carry, unit_p):
        y, aux_acc = carry
        states = []
        for i, ltype in enumerate(cfg.attn_pattern):
            ck = None
            if cfg.is_encoder_decoder:
                k = attn.project_cross_kv(unit_p[i]["xattn"], enc_out, cfg)
                ck = k
                states_cross = k
            y, aux, st = _apply_layer_seq(
                unit_p[i], y, cfg, ltype, positions,
                causal=True, make_state=make_state, state_len=state_len, cross_kv=ck,
                q_chunk=q_chunk, rec_chunk=rec_chunk)
            states.append(st)
        ys = tuple(states) if make_state else None
        if cfg.is_encoder_decoder and make_state:
            ys = (ys, states_cross)
        return (y, aux_acc + aux), ys

    body = unit_body
    if remat == "unit":
        body = jax.checkpoint(unit_body, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            unit_body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    (x, aux_loss), unit_states = jax.lax.scan(body, (x, jnp.float32(0.0)), params["unit"])

    rem_states = []
    for i in range(cfg.n_remainder_layers):
        ltype = cfg.attn_pattern[i % len(cfg.attn_pattern)]
        x, aux, st = _apply_layer_seq(params["rem"][i], x, cfg, ltype, positions,
                                      causal=True, make_state=make_state,
                                      state_len=state_len,
                                      q_chunk=q_chunk, rec_chunk=rec_chunk)
        aux_loss = aux_loss + aux
        rem_states.append(st)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    logits = x if return_features else unembed(params["embed"], x, cfg)

    state = None
    if make_state:
        cross = None
        if cfg.is_encoder_decoder:
            unit_states, cross_kv = unit_states
            cross = {"k": cross_kv[0], "v": cross_kv[1]}
        state = {"unit": unit_states, "rem": tuple(rem_states),
                 "pos": jnp.full((tokens.shape[0],), S_total, jnp.int32)}
        if cross is not None:
            state["cross"] = cross
    return logits, aux_loss, state


# ---------------------------------------------------------------------------------
# Single-token decode step
# ---------------------------------------------------------------------------------

def decode_step(
    params,
    state,
    token: jax.Array,       # (B, 1) int32
    cfg: ArchConfig,
):
    """One autoregressive step. Returns (logits fp32 (B, Vp), new_state)."""
    pos = state["pos"]                                   # (B,) per-slot positions
    x = embed_tokens(params["embed"], token, cfg)
    if cfg.is_encoder_decoder:
        sin = sinusoidal_position_at(pos, cfg.d_model).astype(x.dtype)  # (B, D)|(D,)
        x = x + (sin[:, None] if sin.ndim == 2 else sin[None, None])

    def unit_body(x_carry, xs):
        if cfg.is_encoder_decoder:
            unit_p, unit_st, ck, cv = xs
        else:
            unit_p, unit_st = xs
        y = x_carry
        new_states = []
        for i, ltype in enumerate(cfg.attn_pattern):
            cross = (ck, cv) if cfg.is_encoder_decoder else None
            y, st = _apply_layer_decode(unit_p[i], y, unit_st[i], pos, cfg, ltype,
                                        cross_kv=cross)
            new_states.append(st)
        return y, tuple(new_states)

    if cfg.is_encoder_decoder:
        xs = (params["unit"], state["unit"], state["cross"]["k"], state["cross"]["v"])
    else:
        xs = (params["unit"], state["unit"])
    x, new_unit_states = jax.lax.scan(unit_body, x, xs)

    new_rem = []
    for i in range(cfg.n_remainder_layers):
        ltype = cfg.attn_pattern[i % len(cfg.attn_pattern)]
        x, st = _apply_layer_decode(params["rem"][i], x, state["rem"][i], pos, cfg, ltype)
        new_rem.append(st)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)[:, 0]          # (B, Vp)
    new_state = dict(state)
    new_state["unit"] = new_unit_states
    new_state["rem"] = tuple(new_rem)
    new_state["pos"] = pos + 1
    return logits, new_state
