"""Mamba-1 selective-state-space block (falcon-mamba-7b).

The block subsumes both temporal mixing and the MLP (no separate FFN in Mamba archs).
Prefill/training uses the chunked diagonal recurrence (O(B·chunk·d_inner·N) live
memory); decode is a single fused state update. The recurrent state per layer is
``h (B, d_inner, N)`` + a small causal-conv tail — the "no unbounded KV cache"
property that qualifies this arch for long_500k.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import _he
from repro.models.recurrence import (
    causal_conv1d,
    causal_conv1d_step,
    chunked_diag_recurrence,
)


class SSMState(NamedTuple):
    h: jax.Array           # (B, d_inner, N) fp32
    conv: jax.Array        # (B, d_conv-1, d_inner)


def init_ssm(key, cfg: ArchConfig, dtype) -> dict:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias so softplus(dt) spans [1e-3, 1e-1]
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,)) * (math.log(0.1) - math.log(1e-3))
                 + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": _he(ks[1], (d, 2 * di), d, dtype),
        "conv_w": _he(ks[2], (di, cfg.d_conv), cfg.d_conv, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _he(ks[3], (di, r + 2 * n), di, dtype),
        "dt_proj": _he(ks[4], (r, di), r, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _he(ks[5], (di, d), di, dtype),
    }


def _ssm_inputs(params: dict, x: jax.Array, cfg: ArchConfig):
    """Shared projections. x: (B, S, D) -> (x_conv_in, z, helpers)."""
    di = cfg.d_inner
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)          # (B, S, di) each
    return x_in, z


def _selective_terms(params: dict, x_conv: jax.Array, cfg: ArchConfig):
    """x_conv: (B, S, di) post conv+silu -> a, b, C for the diagonal recurrence."""
    n, r = cfg.ssm_state, cfg.resolved_dt_rank
    proj = x_conv @ params["x_proj"]             # (B, S, r+2n)
    dt_r, b_ssm, c_ssm = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_r @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"])  # (B,S,di)
    A = -jnp.exp(params["A_log"])                # (di, n)
    a = jnp.exp(dt[..., None] * A)               # (B, S, di, n)
    b = (dt * x_conv.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[:, :, None, :]  # (B, S, di, n)
    return a, b, c_ssm


def ssm_prefill(
    params: dict,
    x: jax.Array,                # (B, S, D)
    cfg: ArchConfig,
    *,
    make_state: bool = False,
    chunk: int = 256,
) -> Tuple[jax.Array, SSMState | None]:
    """Chunk-fused selective scan (perf iteration C, EXPERIMENTS.md §Perf).

    The (B, S, d_inner, N) recurrence inputs a/b are never materialized at full
    sequence length: each outer-scan step slices one (B, chunk, d_inner) piece of
    x_conv, expands a/b for that chunk only, runs the within-chunk associative scan,
    contracts against C_t immediately, and emits y (B, chunk, d_inner). The
    full-length (B,S,di,N) tensors (4·di·N bytes/token) are thereby replaced by
    (B,S,di)-sized streams — an N-fold (16x) HBM-traffic reduction at equal FLOPs.
    On TPU the same contraction runs inside the diag_recurrence Pallas kernel
    (kernels/diag_recurrence), collapsing even the per-chunk expansion into VMEM."""
    import os
    B, S, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    x_in, z = _ssm_inputs(params, x, cfg)
    x_conv = jax.nn.silu(causal_conv1d(x_in, params["conv_w"], params["conv_b"]))

    if os.environ.get("REPRO_PERF_BASELINE", "") == "1":
        # pre-iteration-C path: a/b materialized at full sequence length
        a, b, c_ssm = _selective_terms(params, x_conv, cfg)
        h0 = jnp.zeros((B, di, n), jnp.float32)
        h_all, h_final = chunked_diag_recurrence(a, b, h0, chunk=chunk)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_ssm.astype(jnp.float32))
        y = (y + params["D"] * x_conv.astype(jnp.float32)).astype(x.dtype)
        out = (y * jax.nn.silu(z)) @ params["out_proj"]
        state = None
        if make_state:
            tail = x_in[:, -(cfg.d_conv - 1):]
            pad2 = cfg.d_conv - 1 - tail.shape[1]
            if pad2 > 0:
                tail = jnp.pad(tail, ((0, 0), (pad2, 0), (0, 0)))
            state = SSMState(h=h_final, conv=tail)
        return out, state

    C = min(chunk, S)
    pad = (-S) % C
    xc = jnp.pad(x_conv, ((0, 0), (0, pad), (0, 0))) if pad else x_conv
    n_chunks = xc.shape[1] // C
    xc_chunks = jnp.moveaxis(xc.reshape(B, n_chunks, C, di), 1, 0)  # (nc,B,C,di)

    def body(h, xck):
        a, b, c_ssm = _selective_terms(params, xck, cfg)            # chunk-local
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a2 * a1, a2 * b1 + b2
        aa, bb = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = aa * h[:, None] + bb                                # (B,C,di,n)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, c_ssm.astype(jnp.float32))
        return h_all[:, -1], y

    h0 = jnp.zeros((B, di, n), jnp.float32)
    h_final, y_chunks = jax.lax.scan(body, h0, xc_chunks)
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, n_chunks * C, di)[:, :S]
    y = (y + params["D"] * x_conv.astype(jnp.float32)).astype(x.dtype)
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    state = None
    if make_state:
        tail = x_in[:, -(cfg.d_conv - 1):]
        pad2 = cfg.d_conv - 1 - tail.shape[1]
        if pad2 > 0:
            tail = jnp.pad(tail, ((0, 0), (pad2, 0), (0, 0)))
        state = SSMState(h=h_final, conv=tail)
    return out, state


def ssm_decode(
    params: dict,
    x: jax.Array,                # (B, 1, D)
    state: SSMState,
    cfg: ArchConfig,
) -> Tuple[jax.Array, SSMState]:
    x_in, z = _ssm_inputs(params, x, cfg)
    conv_out, conv_state = causal_conv1d_step(x_in, state.conv, params["conv_w"], params["conv_b"])
    x_conv = jax.nn.silu(conv_out)               # (B, 1, di)
    a, b, c_ssm = _selective_terms(params, x_conv, cfg)
    h = a[:, 0] * state.h + b[:, 0]              # (B, di, n)
    y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))
    y = (y + params["D"] * x_conv[:, 0].astype(jnp.float32)).astype(x.dtype)[:, None]
    out = (y * jax.nn.silu(z)) @ params["out_proj"]
    return out, SSMState(h=h, conv=conv_state)


def empty_ssm_state(cfg: ArchConfig, batch: int, dtype) -> SSMState:
    return SSMState(
        h=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    )
