"""WarmSwap core: live dependency sharing for serverless model serving.

Public API:
  * pages       — pytree <-> page-store encoding (the memory-page layer)
  * image       — LiveDependencyImage / build_image (the shareable unit)
  * pool        — DependencyManager (provider-side pool, RAM+disk tiers, LRU)
  * migration   — PageServer + MigrationClient, 4 restore policies (Table 2)
  * registry    — FunctionRegistry (endpoints = image ref + private handler)
  * coldstart   — ColdStartOrchestrator with per-phase timers (Figs. 3/6)
  * keepalive   — E_cs(λ) arrival math (§2.2) + pluggable pre-warm policies
  * traces      — Azure-statistics / Zipf fleet trace generation (§4.5)
  * simulator   — single-worker, queue-accurate simulation (Fig. 7)
  * events      — typed discrete-event core (heap + tie-break contract)
  * fleet       — multi-worker discrete-event fleet simulation: concurrency,
                  queueing, placement, capacity, latency percentiles
  * scenario    — declarative Scenario spec + the one run() entry point +
                  sweep() grid expansion (docs/API.md)
  * workloads   — FunctionBench-analogue suite (Table 1)

Pluggable components are addressed by string key via Registry instances
(PREWARM_POLICIES, TRACE_GENERATORS, COST_MODELS, PAGE_COST_MODELS,
serving.scheduler.PLACEMENTS, workloads.WORKLOADS); a @register("name")
decorator adds new ones without touching the engines.
"""
from repro.core.coldstart import ColdStartConfig, ColdStartOrchestrator, PhaseTimes
from repro.core.costmodel import PAGE_COST_MODELS, PageCostModel
from repro.core.events import Event, EventKind, EventQueue
from repro.core.fleet import FleetConfig, FleetResult, simulate_fleet
from repro.core.image import ImageMetadata, LiveDependencyImage, build_image
from repro.core.keepalive import (PREWARM_POLICIES, BytesAwareKeepAlive,
                                  HistogramKeepAlive, KeepAlivePolicy,
                                  PrewarmPolicy, SpesPrewarm,
                                  expected_cold_starts)
from repro.core.migration import LinkModel, MigrationClient, PageServer, RestorePolicy
from repro.core.pages import PageTable, materialize, paginate
from repro.core.pool import CapacityLedger, ClusterImageCache, DependencyManager
from repro.core.registry import FunctionRegistry, Registry, UnknownComponentError
from repro.core.scenario import (ComponentSpec, MethodResult, Result,
                                 RunOverrides, Scenario, run, sweep,
                                 validate_result)
from repro.core.simulator import (COST_MODELS, CostModel,
                                  memory_saving_fraction, simulate)
from repro.core.traces import (TRACE_GENERATORS, generate_fleet_traces,
                               generate_traces)

__all__ = [
    "ColdStartConfig", "ColdStartOrchestrator", "PhaseTimes",
    "Event", "EventKind", "EventQueue",
    "FleetConfig", "FleetResult", "simulate_fleet",
    "ImageMetadata", "LiveDependencyImage", "build_image",
    "KeepAlivePolicy", "expected_cold_starts",
    "PrewarmPolicy", "HistogramKeepAlive", "SpesPrewarm", "BytesAwareKeepAlive",
    "LinkModel", "MigrationClient", "PageServer", "RestorePolicy",
    "PageTable", "materialize", "paginate",
    "CapacityLedger", "ClusterImageCache", "DependencyManager",
    "FunctionRegistry", "Registry", "UnknownComponentError",
    "ComponentSpec", "MethodResult", "Result", "RunOverrides", "Scenario",
    "run", "sweep", "validate_result",
    "CostModel", "PageCostModel", "memory_saving_fraction", "simulate",
    "generate_traces", "generate_fleet_traces",
    "COST_MODELS", "PAGE_COST_MODELS", "PREWARM_POLICIES", "TRACE_GENERATORS",
]
