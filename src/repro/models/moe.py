"""Top-k routed mixture-of-experts with per-row sort-based dispatch.

Dispatch is O(S·k) memory per row (no (S, E, C) one-hot): assignments are sorted by
expert id, ranked within each expert, capacity-dropped, and gathered into
(B, E, C, D) buffers for the batched per-expert GEMMs. Everything is expressed with
a leading batch dimension (batched sorts/scatters, no vmap), so under the production
mesh the batch stays data-parallel-sharded and routing never all-gathers tokens.

Expert parallelism (perf iteration B, EXPERIMENTS.md §Perf): the expert dimension
shards over `model` whenever it divides the mesh axis — natively (moonshot, 64e) or
via ``expert_pad_to`` (granite: 40 -> 48 padded experts; the 8 pad experts receive no
tokens from the router, costing ~17 % idle expert-GEMM slots but replacing the
(B,E,C,D) partial-sum all-reduce of TP-in-expert, which reduces over *capacity slots*
(~top_k x tokens), with the small (B,S,D) combine all-reduce). Explicit sharding
constraints pin the dispatch buffers to the expert axis.

``no_drop=True`` (decode) sizes capacity so no token is ever dropped, keeping decode
deterministic w.r.t. the prefill that built the cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import _he

_U = P.UNCONSTRAINED


def _maybe_constrain(x: jax.Array, spec: P, expert_dim: int) -> jax.Array:
    """Pin the expert axis to 'model' when a mesh is active and divides it
    (no-op in plain single-device tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or getattr(mesh, "empty", True) or \
                "model" not in getattr(mesh, "axis_names", ()):
            return x
        if expert_dim % dict(mesh.shape)["model"] != 0:
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    e = cfg.n_experts_padded          # pad experts so EP shards evenly (iteration B)
    ks = jax.random.split(key, 4)
    p = {"router": _he(ks[0], (d, cfg.n_experts), d, jnp.float32)}  # REAL experts only
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = _he(ks[1], (e, d, f), d, dtype)
        p["w_in"] = _he(ks[2], (e, d, f), d, dtype)
    else:
        p["w_in"] = _he(ks[2], (e, d, f), d, dtype)
    p["w_out"] = _he(ks[3], (e, f, d), f, dtype)
    return p


def expert_capacity(cfg: ArchConfig, n_tokens: int, *, no_drop: bool = False) -> int:
    if no_drop:
        return n_tokens  # worst case: every token routes to the same expert
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(min(cap, n_tokens), min(cfg.top_k, n_tokens))


def moe_ffn(params: dict, x: jax.Array, cfg: ArchConfig, *,
            no_drop: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    import os as _os
    B, S, D = x.shape
    E_real, K = cfg.n_experts, cfg.top_k
    E = cfg.n_experts_padded
    C = expert_capacity(cfg, S, no_drop=no_drop)
    xe_spec = P(_U, "model", _U, _U)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    gates = jax.nn.softmax(logits, axis=-1)                         # (B, S, E_real)
    top_w, top_i = jax.lax.top_k(gates, K)                          # (B, S, K)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style): E * mean_b sum_e(f_e * p_e)
    me = jnp.mean(gates, axis=1)                                    # (B, E_real)
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    ce = jnp.zeros((B, E_real), jnp.float32).at[
        bidx, top_i.reshape(B, -1)].add(1.0) / (S * K)
    aux = E_real * jnp.mean(jnp.sum(me * ce, axis=-1)) * cfg.router_aux_coef

    # ---- batched sort-based dispatch ----------------------------------------------
    e_flat = top_i.reshape(B, S * K)                                # (B, S*K)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (B, S * K))
    w_flat = top_w.reshape(B, S * K).astype(x.dtype)
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    t_sorted = jnp.take_along_axis(t_flat, order, axis=-1)
    w_sorted = jnp.take_along_axis(w_flat, order, axis=-1)
    counts = jnp.zeros((B, E_real), jnp.int32).at[bidx, e_flat].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts                   # (B, E_real)
    ranks = jnp.arange(S * K, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(starts, e_sorted, axis=-1)
    keep = ranks < C                                                # capacity drop
    slot = jnp.where(keep, e_sorted * C + ranks, E * C)             # OOB sentinel

    slot_tok = jnp.full((B, E * C), S, jnp.int32).at[bidx, slot].set(
        t_sorted, mode="drop")
    slot_w = jnp.zeros((B, E * C), x.dtype).at[bidx, slot].set(w_sorted, mode="drop")

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)
    xe = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)    # (B, E*C, D)
    xe = xe.reshape(B, E, C, D)
    if _os.environ.get("REPRO_PERF_BASELINE", "") != "1":
        xe = _maybe_constrain(xe, xe_spec, E)  # EP: the dispatch all-to-all lives here

    # ---- batched per-expert FFN (local under EP) -----------------------------------
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        h = act(jnp.einsum("becd,edf->becf", xe, params["w_gate"])) * \
            jnp.einsum("becd,edf->becf", xe, params["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xe, params["w_in"]),
                        approximate=True)
    ye = jnp.einsum("becf,efd->becd", h, params["w_out"])           # (B, E, C, D)
    if _os.environ.get("REPRO_PERF_BASELINE", "") != "1":
        ye = _maybe_constrain(ye, xe_spec, E)

    # ---- weighted combine back to token order (small (B,S,D) reduction) ------------
    out = jnp.zeros((B, S + 1, D), x.dtype)
    out = out.at[bidx, slot_tok].add(
        ye.reshape(B, E * C, D) * slot_w[..., None])
    return out[:, :S], aux
