"""Parallel, resumable sweep executor over the declarative scenario layer.

``sweep()`` (core/scenario.py) turns a base spec + axes into a grid of
resolved scenarios; this module *runs* that grid at production scale:

  * **parallel** — grid points run across a ``multiprocessing`` pool
    (spawn context: no inherited RNG/JAX state, workers import the repo
    fresh). Each point is a pure function of its resolved spec — every seed
    lives in the spec — so scheduling cannot affect results, and a serial
    and a parallel run of the same grid are **bit-identical** through the
    store (asserted in tests/test_executor.py);
  * **streaming + resumable** — each validated result is appended to an
    append-only JSONL :class:`~repro.experiments.store.ResultStore` keyed by
    the content hash of the fully resolved spec, fsynced per point. An
    interrupted sweep rerun with ``resume=True`` skips every key already in
    the store (a torn final line from a kill is dropped and recomputed);
  * **deterministic per-point seeds** — with ``derive_seeds=True`` each grid
    point's ``traces.kwargs.seed`` is pinned to a stable hash of the rest of
    its spec, so every point draws independent arrivals without any
    cross-point RNG coupling, reproducibly.

CLI::

    python -m repro.experiments sweep spec.json --axis n_workers=1,4,16 \\
        --parallel 4 --store results/sweep.jsonl --resume
    python -m repro.experiments report results/sweep.jsonl
"""
from __future__ import annotations

import hashlib
import json
import multiprocessing
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.scenario import Scenario, run, sweep, validate_result
from repro.experiments.store import (ResultStore, StoreError, canonical_json,
                                     normalize_spec, spec_key)


@dataclass
class SweepPoint:
    """One resolved grid cell: the runnable spec dict and its store key."""
    index: int                 # position in the expanded grid
    spec: Dict[str, Any]       # fully resolved (overrides + smoke + seed)
    key: str                   # content hash of ``spec`` (the store key)

    @property
    def name(self) -> str:
        return self.spec.get("name", f"point{self.index}")


@dataclass
class SweepReport:
    """What :func:`run_sweep` did: results in grid order + resume stats."""
    points: List[SweepPoint]
    results: List[Dict[str, Any]]      # serialized Result per point, in order
    n_run: int = 0                     # points actually simulated this call
    n_skipped: int = 0                 # points satisfied from the store
    store_path: Optional[str] = None
    parallel: int = 1
    extras: Dict[str, Any] = field(default_factory=dict)


def point_seed(spec: Mapping[str, Any]) -> int:
    """Deterministic per-point seed: a stable 31-bit hash of the spec with
    any existing ``traces.kwargs.seed`` removed (so the derived seed is a
    function of *what* the point simulates, not of a previous seed).
    Non-semantic trace kwargs (``stream``, ``chunk_min``) are dropped too
    (:func:`repro.experiments.store.normalize_spec`): streamed and in-memory
    runs of one spec must draw the same derived seed."""
    d = normalize_spec(spec)
    d.get("traces", {}).get("kwargs", {}).pop("seed", None)
    digest = hashlib.sha256(canonical_json(d).encode()).digest()
    return int.from_bytes(digest[:4], "big") & 0x7FFFFFFF


def resolve_points(base: Scenario, axes: Mapping[str, Sequence[Any]], *,
                   smoke: bool = False,
                   derive_seeds: bool = False) -> List[SweepPoint]:
    """Expand ``axes`` over ``base`` and fully resolve each cell: smoke
    overrides applied, seeds optionally derived, content hash computed.

    The returned specs are what workers run and what the store is keyed by —
    ``run()`` is called on them with no further transformation."""
    points = []
    for i, scn in enumerate(sweep(base, axes)):
        if smoke:
            scn = scn.smoke_scaled()
        if derive_seeds:
            scn = scn.with_overrides(
                {"traces.kwargs.seed": point_seed(scn.to_dict())})
        spec = scn.to_dict()
        points.append(SweepPoint(index=i, spec=spec, key=spec_key(spec)))
    return points


def run_point(spec: Mapping[str, Any]) -> Dict[str, Any]:
    """Run one fully resolved spec dict; returns the validated serialized
    result. Module-level so ``multiprocessing`` workers can import it."""
    result = run(Scenario.from_dict(spec))
    d = result.to_dict()
    validate_result(d)
    return d


def run_sweep(
    base: Scenario,
    axes: Mapping[str, Sequence[Any]],
    *,
    smoke: bool = False,
    parallel: int = 1,
    store_path: Optional[str] = None,
    resume: bool = False,
    derive_seeds: bool = False,
    mp_context: str = "spawn",
    progress=None,
) -> SweepReport:
    """Run a sweep grid, optionally in parallel, optionally through a store.

    Args:
        base: the base scenario; ``axes`` are dotted-path grid axes
            (see :func:`repro.core.scenario.sweep`).
        smoke: apply each spec's ``smoke_overrides`` (CI scale).
        parallel: worker processes; ``<= 1`` runs in-process. Results are
            appended in grid order either way, so serial and parallel runs
            of the same grid produce byte-identical stores.
        store_path: JSONL results store; ``None`` keeps results in memory
            only. Appends are fsynced per point (kill-safe).
        resume: skip points whose key is already stored. Without it, an
            existing non-empty store is refused rather than silently mixed
            into.
        derive_seeds: pin each point's ``traces.kwargs.seed`` to
            :func:`point_seed` of its spec.
        mp_context: multiprocessing start method (default ``spawn``).
        progress: optional callable ``(done, total, point, skipped)`` for
            per-point reporting.

    Returns:
        A :class:`SweepReport`; ``results`` holds every point's serialized
        result in grid order (stored points included when resuming).
    """
    if resume and not store_path:
        raise StoreError("resume=True needs a store_path "
                         "(--resume needs --store): there is nothing to "
                         "resume from without a results store")
    points = resolve_points(base, axes, smoke=smoke,
                            derive_seeds=derive_seeds)
    store = ResultStore(store_path) if store_path else None
    completed: Dict[str, Dict[str, Any]] = {}
    if store is not None and store.exists():
        if resume:
            completed = store.completed_keys()
        elif store.records():
            raise StoreError(
                f"{store_path} already holds results; pass resume=True "
                f"(--resume) to skip completed points, or use a fresh path")

    todo = [p for p in points if p.key not in completed]
    results_by_key: Dict[str, Dict[str, Any]] = {
        k: r["result"] for k, r in completed.items()}
    report = SweepReport(points=points, results=[],
                         n_skipped=len(points) - len(todo),
                         store_path=store_path, parallel=max(parallel, 1))

    def finish(point: SweepPoint, result: Dict[str, Any]) -> None:
        results_by_key[point.key] = result
        if store is not None:
            store.append(point.key, result, name=point.name)
        report.n_run += 1
        if progress is not None:
            progress(report.n_run + report.n_skipped, len(points), point,
                     False)

    if progress is not None:
        done = 0
        for p in points:
            if p.key in completed:
                done += 1
                progress(done, len(points), p, True)
    if todo:
        if parallel > 1:
            ctx = multiprocessing.get_context(mp_context)
            with ctx.Pool(processes=min(parallel, len(todo))) as pool:
                # ordered imap: results stream back (and append to the
                # store) in grid order, making serial == parallel stores
                # byte-identical
                for point, result in zip(
                        todo, pool.imap(run_point,
                                        [p.spec for p in todo])):
                    finish(point, result)
        else:
            for point in todo:
                finish(point, run_point(point.spec))

    report.results = [results_by_key[p.key] for p in points]
    return report


def summarize_store(store_path: str) -> Dict[str, Any]:
    """Project a results store back onto the unified result schema: every
    record's result validated, plus a compact per-point summary table —
    the CLI ``report`` command's payload."""
    store = ResultStore(store_path)
    records = store.records()
    table = []
    for rec in records:
        result = rec["result"]
        validate_result(result)
        row: Dict[str, Any] = {
            "key": rec["key"],
            "name": rec.get("name") or result["scenario"].get("name", ""),
            "engine": result["engine"],
            "summary": dict(result["summary"]),
        }
        for m, mr in result["methods"].items():
            row[m] = {"avg_latency_s": mr["avg_latency_s"],
                      "p99_s": mr["latency_percentiles_s"]["p99"],
                      "n_cold": mr["n_cold"],
                      "memory_bytes": mr["memory_bytes"]}
        table.append(row)
    return {
        "store_path": store_path,
        "n_points": len(records),
        "torn_tail_dropped": store.torn_tail,
        "points": table,
        "results": [rec["result"] for rec in records],
    }
