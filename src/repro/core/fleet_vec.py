"""Vectorized batch fleet engine, bit-identical to the event engine.

``engine="fleet_vec"`` is a batch reformulation of the discrete-event drain
in :mod:`repro.core.fleet`: arrivals are decomposed into independent
``(worker, function)`` streams, each solved on flat numpy arrays, with an
optional ``jax.lax.scan`` path (``REPRO_FLEET_VEC_SCAN=1``) for ``cap=1``
groups. The contract is **bit identity**, not approximation: per-request
latency/wait sample arrays, every counter, and every FP accumulation are
reproduced exactly (sha256-equal sample buffers — the differential suite in
``tests/test_fleet_equiv.py`` enforces it across placement x caps x page
model x prewarm configs).

Why decomposition is sound (the static-routing theorem)
-------------------------------------------------------
Inside the fast-path domain (below), every invocation of a function routes
to a statically known worker, so per-function streams never interact:

* single worker: trivially static;
* ``affinity`` + warmswap/prebaking: the provider setup phase
  (:func:`repro.core.fleet._seed_home_residents`, shared with the event
  engine) makes exactly one worker hold the function's resident key. The
  placement chain then keeps all activity there by induction: warm
  instances only ever exist on the home worker, and the residency signal
  (boolean ``holds`` or, under the page model, a *strictly* cheaper local
  transfer) picks the home for every cold start;
* ``round_robin`` + baseline: the rotation is a pure function of the
  arrival index, and baseline holds nothing, so no ledger state feeds back.

Everything outside the domain — non-trivial pre-warm policies (spawn events
read fleet-wide load), bounded cluster caches (evictions are global),
load-coupled placements, degenerate page models (cost ties fall through to
the load signal), setup phases that overflow worker pool capacity — falls
back to :func:`repro.core.fleet._simulate_fleet_impl` verbatim, so the
engine is *always* exact; the fast path is a JIT-style bailout design.
:func:`fast_path_reason` reports why a config fell back (``None`` = fast).

Within one group the solver alternates two regimes:

* **vectorized warm runs** — while every arrival is warm-served, the engine
  serves the idle instance with minimum ``(busy_until, creation pos)``;
  since each service pushes a *monotonically increasing* value
  ``t + warm_s/60``, the service heap drains FIFO and the served
  ``busy_until`` sequence is exactly the sorted merge of the current
  instance states with the shifted arrival stream. One ``np.sort`` +
  two comparisons validate an arbitrarily long run (windowed, geometrically
  grown); survivors' identities resolve by walking pop chains backward;
* **scalar steps** — cold starts, queue joins, FIFO dispatches and
  keep-alive prunes replay the event engine's exact arithmetic one arrival
  at a time (identical FP expression shapes: ``(start - req_t) * 60.0``,
  ``start + svc_s / 60.0``, ``busy_until + keep_alive``).

Full window semantics and the equivalence contract live in
docs/SIMULATION.md ("Vectorized engine").
"""
from __future__ import annotations

import os
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.fleet import (FleetConfig, FleetResult, _make_policy,
                              _seed_home_residents, _simulate_fleet_impl,
                              _Worker)
from repro.core.keepalive import PrewarmPolicy
from repro.core.pool import ClusterImageCache
from repro.core.sanitize import FleetSanitizer, sanitize_enabled
from repro.core.simulator import CostModel, method_cold_latency_s
from repro.core.trace_stream import TraceStream
from repro.core.traces import Trace

#: Diagnostics for the optional jax.lax.scan path: how many groups the last
#: ``simulate_fleet_vec`` call solved via scan (tests assert it engaged).
SCAN_STATS = {"groups": 0}


def _scan_enabled() -> bool:
    return os.environ.get("REPRO_FLEET_VEC_SCAN", "") == "1"


# --------------------------------------------------------------------- setup
def _build_setup(traces: List[Trace], method: str, cost: CostModel,
                 fleet: FleetConfig):
    """Replicate the event engine's provider setup phase on the *real*
    ledger/cluster objects (so capacities, peaks and eviction counters are
    authoritative), via the shared :func:`_seed_home_residents` helper."""
    workers = [_Worker(i, fleet.worker_capacity_bytes)
               for i in range(fleet.n_workers)]
    fn_image = {t.fn_index: t.image_id for t in traces}
    images = sorted({t.image_id for t in traces})
    page = fleet.page_cost

    def _cluster_evict(key: str) -> None:
        for w in workers:
            w.ledger.evict(key)
    cluster = (ClusterImageCache(fleet.shared_cache_bytes,
                                 on_evict=_cluster_evict)
               if page is not None else None)

    def resident_bytes_of(key: str) -> int:
        return cost.snapshot_bytes if key.startswith("snap:") else cost.image_bytes

    def admit(w: _Worker, key: str) -> None:
        nbytes = resident_bytes_of(key)
        for victim in w.ledger.admit(key, nbytes, now=0.0):
            if cluster is not None:
                cluster.worker_evicted(w.idx, victim)
        if cluster is not None:
            cluster.admit(key, nbytes, w.idx, now=0.0)
            cluster.touch(key, 0.0)

    _seed_home_residents(method, workers, fn_image, images, admit)
    return workers, fn_image, images, cluster


def _setup_capacity_binds(workers: List[_Worker], method: str,
                          fn_image: Dict[int, int], images: List[int],
                          cluster) -> bool:
    """True when the bounded worker pools could not hold the full provider
    setup — residency would then evolve at cold starts (revives, evictions)
    and the static-routing theorem no longer applies."""
    if any(w.ledger.evictions for w in workers):
        return True
    if cluster is not None and (cluster.evictions or cluster.rejected):
        return True
    rank = {img: i for i, img in enumerate(images)}
    n = len(workers)
    for fn, img in fn_image.items():
        key = f"img:{img}" if method == "warmswap" else f"snap:{fn}"
        if method != "baseline" and not workers[rank[img] % n].ledger.holds(key):
            return True
    return False


# --------------------------------------------------------------- domain guard
def fast_path_reason(traces: Union[List[Trace], TraceStream], method: str,
                     cost: CostModel,
                     fleet: Optional[FleetConfig] = None) -> Optional[str]:
    """Why this config needs the event-engine fallback; ``None`` = the
    vectorized fast path is provably bit-identical. Raises the same
    validation errors as the event engine (bad worker counts, shared cache
    without a page model, unknown placement/policy keys). A
    :class:`~repro.core.trace_stream.TraceStream` always falls back: the
    event engine consumes its chunks natively."""
    fleet = fleet if fleet is not None else FleetConfig()
    if fleet.n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {fleet.n_workers}")
    if fleet.shared_cache_bytes is not None and fleet.page_cost is None:
        raise ValueError("shared_cache_bytes bounds the page-model cluster "
                         "tier; set FleetConfig.page_cost to enable it")
    if isinstance(fleet.placement, str):
        from repro.serving.scheduler import PLACEMENTS
        PLACEMENTS.build(fleet.placement)   # unknown-key parity with the engine
    if isinstance(traces, TraceStream):
        # The static-routing theorem needs the full function->image map and
        # the provider setup phase up front; a stream only reveals arrivals
        # chunk by chunk, so routing cannot be statically known from a
        # stream prefix. The event engine consumes chunks natively.
        return ("streamed traces: routing cannot be statically known from "
                "a stream prefix")
    if fleet.disruption is not None and fleet.disruption.events:
        if fleet.disruption.n_workers != fleet.n_workers:
            raise ValueError(
                f"disruption schedule was built for "
                f"{fleet.disruption.n_workers} worker(s) but the fleet has "
                f"{fleet.n_workers}; rebuild it with the fleet's shape")
        return ("fleet disruption schedule: worker churn and eviction "
                "storms couple all request streams")
    policy = _make_policy(fleet)
    if type(policy) is not PrewarmPolicy:
        return "non-trivial pre-warm policy: spawn placement reads fleet load"
    if fleet.shared_cache_bytes is not None:
        return "bounded cluster-shared cache: evictions couple all workers"
    page = fleet.page_cost
    if fleet.n_workers > 1:
        if not isinstance(fleet.placement, str):
            return "custom placement callable: routing not statically known"
        if fleet.placement == "affinity" and method in ("warmswap", "prebaking"):
            if page is not None:
                nbytes = (cost.image_bytes if method == "warmswap"
                          else cost.snapshot_bytes)
                local = page.transfer_blocking_s("local", image_bytes=nbytes)
                if not (local < page.transfer_blocking_s("remote",
                                                         image_bytes=nbytes)
                        and local < page.transfer_blocking_s("miss",
                                                             image_bytes=nbytes)):
                    return ("page model does not strictly favor the home "
                            "worker: placement ties break on fleet load")
        elif fleet.placement == "round_robin" and method == "baseline":
            pass                            # rotation is arrival-index-static
        else:
            return (f"placement {fleet.placement!r} with method {method!r} "
                    f"routes by fleet-wide load")
    if fleet.worker_capacity_bytes is not None and method != "baseline":
        workers, fn_image, images, cluster = _build_setup(traces, method,
                                                          cost, fleet)
        if _setup_capacity_binds(workers, method, fn_image, images, cluster):
            return ("worker pool capacity binds during provider setup: "
                    "residency evolves at cold starts")
    return None


# ------------------------------------------------------------------ jax scan
_SCAN_FN: List[Optional[Callable]] = []


def _get_scan_fn() -> Optional[Callable]:
    """Build (once) the jitted cap=1 group recursion, or ``None`` when jax
    is unavailable — the caller silently falls back to the numpy solver."""
    if _SCAN_FN:
        return _SCAN_FN[0]
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        @jax.jit
        def body(tp, warm_s, cold_s, wm, cold60, ka):
            def step(state, t):
                alive, free, exp = state
                alive2 = jnp.logical_and(alive, exp >= t)
                queued = jnp.logical_and(alive2, free > t)
                start = jnp.where(queued, free, t)
                svc = jnp.where(alive2, warm_s, cold_s)
                svc60 = jnp.where(alive2, wm, cold60)
                wait = (start - t) * 60.0
                sample = wait + svc
                free2 = start + svc60
                exp2 = free2 + ka
                return ((jnp.bool_(True), free2, exp2),
                        (sample, wait, start, jnp.logical_not(alive2),
                         queued, exp2))
            z = jnp.zeros((), tp.dtype)
            _, ys = jax.lax.scan(step, (jnp.bool_(False), z, z), tp)
            return ys

        def call(tp, *consts):
            # the session conftest disables global x64; the engine contract
            # is float64, so flip it on locally for trace + execution
            with enable_x64():
                return body(tp, *consts)
        # Process-wide jit cache: compile the scan body once per process.
        _SCAN_FN.append(call)     # repro-lint: allow[module-mutable]
    except Exception:
        _SCAN_FN.append(None)     # repro-lint: allow[module-mutable]
    return _SCAN_FN[0]


def _solve_group_scan(t_g, gl, warm_s, cold_s, wm, cold60, ka,
                      samples, waits):
    """cap=1 group as one ``jax.lax.scan``: the whole stream is the Lindley
    recursion on a single rotating instance (queued requests chain through
    the carried ``free`` time in FIFO order). Returns the same
    ``(n_cold, n_warm_imm, n_disp, recs)`` tuple as the scalar/vector
    solver, or ``None`` when jax is unavailable."""
    fn = _get_scan_fn()
    if fn is None:
        return None
    L = len(t_g)
    pad = 1 << max(6, int(L - 1).bit_length())   # bucket sizes: few recompiles
    tp = np.full(pad, np.inf)
    tp[:L] = t_g
    sample, wait, start, cold, queued, exp2 = (
        np.asarray(a)[:L] for a in fn(tp, warm_s, cold_s, wm, cold60, ka))
    g = np.asarray(gl, np.int64)
    samples[g] = sample
    waits[g] = wait
    n_cold = int(cold.sum())
    n_disp = int(queued.sum())
    recs = []
    cpos = np.flatnonzero(cold)
    last = np.r_[cpos[1:] - 1, L - 1]            # tenure = cold .. next cold-1
    for c0, e in zip(cpos.tolist(), last.tolist()):
        recs.append((float(exp2[e]), float(start[e]),
                     0 if queued[e] else 2, gl[e],
                     float(t_g[c0]), gl[c0]))
    # Diagnostics counter, reset per simulate_fleet_vec call; never feeds
    # results.  # repro-lint: allow[module-mutable]
    SCAN_STATS["groups"] += 1
    return n_cold, L - n_cold - n_disp, n_disp, recs


# --------------------------------------------------------------- group solver
def _solve_group(t_g: np.ndarray, g_idx: np.ndarray, cap: Optional[int],
                 warm_s: float, cold_s: float, ka: float,
                 samples: np.ndarray, waits: np.ndarray, use_scan: bool):
    """Solve one independent ``(worker, fn)`` stream.

    Returns ``(n_cold, n_warm_imm, n_disp, recs)`` where ``recs`` holds one
    tuple per instance lifetime:
    ``(final_expires, sk_time, sk_kind, sk_idx, created_t, created_idx)``
    (``sk_*`` keys the instance's last service — the event engine's expiry
    push order — so residency can be re-accumulated in exact retire order).
    """
    wm = warm_s / 60.0
    cold60 = cold_s / 60.0
    if use_scan and cap == 1:
        out = _solve_group_scan(t_g, g_idx.tolist(), warm_s, cold_s, wm,
                                cold60, ka, samples, waits)
        if out is not None:
            return out
    L = len(t_g)
    tl = t_g.tolist()
    gl = g_idx.tolist()
    # live instances, creation order (list position is the engine's
    # tie-break pos): [busy_until, created, expires, sk_t, sk_k, sk_i, cidx]
    B: List[list] = []
    recs: List[tuple] = []
    pending: deque = deque()                      # FIFO queue: (req_t, req_idx)
    n_cold = n_warm = n_disp = 0
    i = 0
    streak = 0          # consecutive immediate-warm serves; long streaks hand
                        # off to the vectorized run (short ones stay scalar —
                        # the numpy window overhead would dominate them)

    def flush(inst: list) -> None:
        recs.append((inst[2], inst[3], inst[4], inst[5], inst[1], inst[6]))

    while i < L:
        t_i = tl[i]
        if streak >= 24 and not pending and B:
            bu0 = B[0][0]
            for inst in B:
                if inst[0] < bu0:
                    bu0 = inst[0]
            if bu0 <= t_i and bu0 + ka >= t_i:
                # ---- vectorized warm run: serving the min-(busy_until, pos)
                # instance pushes monotone values t+wm, so the service heap
                # drains FIFO: the m-th served busy_until is the m-th order
                # statistic of {current states} u {t[i..i+m-1] + wm}. Later
                # pushes can never undercut earlier pops, so sorting the
                # whole window is safe; validate in geometrically grown
                # windows until the first non-warm arrival breaks the run.
                k = len(B)
                border = sorted(range(k), key=lambda j: (B[j][0], j))
                b_vals = np.array([B[j][0] for j in border])
                win, R = 256, -1
                while R < 0:
                    c = min(win, L - i)
                    cand = np.concatenate([b_vals, t_g[i:i + c - 1] + wm]) \
                        if c > 1 else b_vals
                    P = np.sort(cand, kind="stable")[:c]
                    a = t_g[i:i + c]
                    bad = np.flatnonzero(~((P <= a) & (P + ka >= a)))
                    if bad.size:
                        R = int(bad[0])
                    elif c == L - i:
                        R = c
                    else:
                        win *= 8
                if R > 0:
                    g = g_idx[i:i + R]
                    samples[g] = warm_s
                    waits[g] = 0.0
                    n_warm += R
                    # survivors: last k candidates; walk pop chains back to
                    # the original instance each final state belongs to
                    cand = np.concatenate([b_vals, t_g[i:i + R] + wm])
                    A = np.argsort(cand, kind="stable").tolist()
                    for c in A[R:]:
                        final = c
                        while c >= k:
                            c = A[c - k]
                        if final >= k:            # else: never served in run
                            j = final - k
                            inst = B[border[c]]
                            tm = tl[i + j]
                            inst[0] = tm + wm
                            inst[2] = inst[0] + ka
                            inst[3], inst[4], inst[5] = tm, 2, gl[i + j]
                    i += R
                    streak = 0
                    continue
        # -------- scalar step: exact event-engine replay for one arrival
        # 1. INSTANCE_FREE events at or before t dispatch the FIFO queue
        #    (while requests wait, no instance ever idles, so these strictly
        #    precede any prune)
        if pending:
            while pending:
                jm = 0
                for j in range(1, len(B)):
                    if B[j][0] < B[jm][0]:
                        jm = j
                inst = B[jm]
                ev_t = inst[0]
                if ev_t > t_i:
                    break
                req_t, ridx = pending.popleft()
                wait_s = (ev_t - req_t) * 60.0
                samples[ridx] = wait_s + warm_s
                waits[ridx] = wait_s
                inst[0] = ev_t + wm
                inst[2] = inst[0] + ka
                inst[3], inst[4], inst[5] = ev_t, 0, ridx
                n_disp += 1
        # 2+3. one fused scan: the min-(busy_until, pos) instance also has
        # the min keep-alive expiry (expires == busy_until + ka throughout),
        # so pruning is needed iff ITS expiry passed strictly before t (an
        # expiry AT t ranks after the arrival and stays alive); otherwise it
        # is directly the engine's idle pick (strict-min busy_until in
        # creation order) when free
        best = -1
        if B:
            best = 0
            for j in range(1, len(B)):
                if B[j][0] < B[best][0]:
                    best = j
            if B[best][2] < t_i:
                for inst in B:
                    if inst[2] < t_i:
                        flush(inst)
                B = [inst for inst in B if inst[2] >= t_i]
                best = -1
                for j, inst in enumerate(B):
                    if best < 0 or inst[0] < B[best][0]:
                        best = j
            if best >= 0 and B[best][0] > t_i:
                best = -1                        # everyone busy
        gi = gl[i]
        if best >= 0:
            inst = B[best]
            inst[0] = t_i + wm
            inst[2] = inst[0] + ka
            inst[3], inst[4], inst[5] = t_i, 2, gi
            samples[gi] = warm_s
            waits[gi] = 0.0
            n_warm += 1
            streak += 1
        elif B and cap is not None and len(B) >= cap:
            pending.append((t_i, gi))
            streak = 0
        else:
            bu = t_i + cold60
            samples[gi] = cold_s                 # == 0.0 wait + cold_s
            waits[gi] = 0.0
            B.append([bu, t_i, bu + ka, t_i, 2, gi, gi])
            n_cold += 1
            streak = 0
        i += 1
    # drain the queue past the last arrival (the event heap drains fully),
    # then account every surviving instance's final lifetime
    while pending:
        jm = 0
        for j in range(1, len(B)):
            if B[j][0] < B[jm][0]:
                jm = j
        inst = B[jm]
        ev_t = inst[0]
        req_t, ridx = pending.popleft()
        wait_s = (ev_t - req_t) * 60.0
        samples[ridx] = wait_s + warm_s
        waits[ridx] = wait_s
        inst[0] = ev_t + wm
        inst[2] = inst[0] + ka
        inst[3], inst[4], inst[5] = ev_t, 0, ridx
        n_disp += 1
    for inst in B:
        flush(inst)
    return n_cold, n_warm, n_disp, recs


# -------------------------------------------------------------------- engine
def _simulate_fleet_vec_impl(traces: List[Trace], method: str,
                             cost: CostModel, fleet: FleetConfig,
                             use_scan: bool,
                             sanitizer: Optional["FleetSanitizer"] = None
                             ) -> FleetResult:
    san = sanitizer
    if san is None and sanitize_enabled():
        san = FleetSanitizer("fleet_vec", method)
    workers, fn_image, images, cluster = _build_setup(traces, method, cost,
                                                      fleet)
    page = fleet.page_cost
    policy = _make_policy(fleet)
    idle_bytes = {"warmswap": cost.metadata_bytes,
                  "prebaking": cost.snapshot_bytes,
                  "baseline": cost.image_bytes}[method]
    ka = policy.keep_alive_min(0, image_bytes=idle_bytes)
    warm_s = cost.warm_s
    cap = fleet.max_instances_per_fn
    n_workers = fleet.n_workers
    # cold latency is constant across the fast-path domain: residency never
    # changes after setup, so warmswap/prebaking always cold-start from the
    # local tier and baseline always rebuilds from source
    if page is None:
        cold_s = method_cold_latency_s(cost, method)
    elif method == "baseline":
        cold_s = page.cold_latency_s("baseline")
    elif method == "warmswap":
        cold_s = page.cold_latency_s("warmswap", tier="local")
    else:
        cold_s = page.cold_latency_s("prebaking", tier="local",
                                     image_bytes=cost.snapshot_bytes)

    res = FleetResult(method=method, n_invocations=0, n_cold=0, n_warm=0,
                      total_latency_s=0.0, memory_bytes=0,
                      n_workers=n_workers)
    fleet_bytes = 0
    for w in workers:
        fleet_bytes += w.ledger.used_bytes()
        if method == "warmswap":
            fleet_bytes += len(w.metadata_fns) * cost.metadata_bytes
    res.memory_bytes = fleet_bytes           # static after setup (in-domain)

    # merged arrival stream: same construction as the event engine
    all_t = np.concatenate([t.arrivals_min for t in traces]) if traces else \
        np.empty((0,))
    all_fn = np.concatenate([np.full(len(t.arrivals_min), t.fn_index, np.int64)
                             for t in traces]) if traces else np.empty((0,), np.int64)
    order = np.argsort(all_t, kind="stable")
    all_t, all_fn = all_t[order], all_fn[order]
    n_req = len(all_t)
    horizon = float(all_t[-1]) if n_req else 0.0
    res.horizon_min = horizon
    samples = np.full(n_req, np.nan)
    waits = np.full(n_req, np.nan)

    # (worker, fn) group decomposition in merged-arrival order
    rank = {img: r for r, img in enumerate(images)}
    rr = n_workers > 1 and isinstance(fleet.placement, str) \
        and fleet.placement == "round_robin"
    if n_req:
        if rr:
            gkey = all_fn * n_workers + (np.arange(n_req, dtype=np.int64)
                                         % n_workers)
        else:
            gkey = all_fn
        order2 = np.argsort(gkey, kind="stable")
        gs = gkey[order2]
        segs = np.split(order2, np.flatnonzero(np.diff(gs)) + 1)
    else:
        segs = []

    n_cold_c = n_warm_c = n_disp_c = 0
    worker_recs: List[List[tuple]] = [[] for _ in workers]
    fn_recs: Dict[int, List[tuple]] = {}
    served = [0] * n_workers
    for seg in segs:
        fn = int(all_fn[seg[0]])
        if n_workers == 1:
            wk = 0
        elif rr:
            wk = int(gkey[seg[0]]) % n_workers
        else:
            wk = rank[fn_image[fn]] % n_workers
        nc, nw, nd, recs = _solve_group(all_t[seg], seg, cap, warm_s, cold_s,
                                        ka, samples, waits, use_scan)
        n_cold_c += nc
        n_warm_c += nw + nd
        n_disp_c += nd
        served[wk] += len(seg)
        worker_recs[wk].extend(recs)
        fn_recs.setdefault(fn, []).extend(recs)

    if n_req and np.isnan(samples).any():
        raise RuntimeError("fleet engine dropped requests: unfilled latency "
                           "samples after the event loop drained")
    res.latency_samples_s = samples
    res.queue_wait_s = waits
    res.sample_fn = all_fn
    res.n_invocations = n_req
    res.n_cold = n_cold_c
    res.n_warm = n_warm_c
    res.total_latency_s = float(samples.sum())
    res.n_queued = int((waits > 0).sum())
    res.queue_delay_s = float(waits.sum())
    # placement counters reconstruct exactly: every immediately-warm arrival
    # is a warm hit; every other arrival (cold or queued) found the resident
    # key in the chosen worker's pool for warmswap/prebaking (setup seeded
    # it; in-domain it never leaves), and never for baseline
    res.placement_warm_hits = n_warm_c - n_disp_c
    res.placement_pool_hits = 0 if method == "baseline" else \
        n_cold_c + n_disp_c
    if page is not None:
        if method == "baseline":
            res.pages_transferred = n_cold_c * page.image_pages()
        else:
            res.cache_local_hits = n_cold_c
    # peak concurrent instances of any single function: at each cold start
    # (in merged order), alive = instances created so far minus those whose
    # keep-alive expired strictly before it (an expiry AT the arrival time
    # ranks after the arrival and still counts)
    max_conc = 1
    for recs in fn_recs.values():
        m = len(recs)
        cidx = np.array([r[5] for r in recs], np.int64)
        o = np.argsort(cidx, kind="stable")
        created_t = np.array([r[4] for r in recs])[o]
        expires = np.sort(np.array([r[0] for r in recs]), kind="stable")
        alive = np.arange(1, m + 1) - np.searchsorted(expires, created_t,
                                                      side="left")
        mc = int(alive.max())
        if mc > max_conc:
            max_conc = mc
    res.max_concurrent_instances = max_conc
    fns = np.array(sorted({t.fn_index for t in traces}), np.int64)
    slots = np.searchsorted(fns, all_fn)
    lat_sums = np.bincount(slots, weights=samples, minlength=len(fns)) \
        if n_req else np.zeros(len(fns))
    inv_counts = np.bincount(slots, minlength=len(fns)) \
        if n_req else np.zeros(len(fns), np.int64)
    res.per_fn_latency = {int(f): float(s) for f, s in zip(fns, lat_sums)}
    res.per_fn_invocations = {int(f): int(c) for f, c in zip(fns, inv_counts)}
    res.evictions = sum(w.ledger.evictions for w in workers)
    # residency re-accumulates in the engine's retire order — keep-alive
    # expiry heap order, i.e. (expires, last-service seq) per worker — so
    # the FP sum is bit-identical, not just algebraically equal
    for w, recs in zip(workers, worker_recs):
        recs.sort()
        for r in recs:
            w.instance_min += max(0.0, min(r[0], horizon) - r[4])
        w.n_served = served[w.idx]
    res.instance_resident_min = sum(w.instance_min for w in workers)
    if cluster is not None:
        res.shared_cache_peak_bytes = cluster.peak_bytes
        res.shared_cache_evictions = cluster.evictions
    res.per_worker = [{
        "worker": w.idx,
        "served": w.n_served,
        "pool_bytes": w.ledger.used_bytes(),
        "resident": sorted(w.ledger.entries.keys()),
        "metadata_fns": len(w.metadata_fns),
        "evictions": w.ledger.evictions,
        "instance_min": w.instance_min,
    } for w in workers]
    if san is not None:
        san.check_samples(samples, waits)
        san.check_books(workers, cluster)
        san.check_counters(res)
    return res


def simulate_fleet_vec(traces: Union[List[Trace], TraceStream], method: str,
                       cost: CostModel,
                       fleet: Optional[FleetConfig] = None,
                       scan: Optional[bool] = None,
                       sanitizer: Optional["FleetSanitizer"] = None
                       ) -> FleetResult:
    """Drop-in replacement for :func:`repro.core.fleet.simulate_fleet` with
    identical results (bit-for-bit). Configs outside the vectorizable domain
    (see :func:`fast_path_reason`) run the event engine verbatim. ``scan``
    forces the ``jax.lax.scan`` path on/off (default: the
    ``REPRO_FLEET_VEC_SCAN=1`` env knob; cap=1 groups only). ``sanitizer``
    threads a :class:`repro.core.sanitize.FleetSanitizer` through whichever
    engine runs (built automatically under ``REPRO_SANITIZE=1``)."""
    fleet = fleet if fleet is not None else FleetConfig()
    SCAN_STATS["groups"] = 0      # repro-lint: allow[module-mutable]
    if fast_path_reason(traces, method, cost, fleet) is not None:
        return _simulate_fleet_impl(traces, method, cost, fleet,
                                    sanitizer=sanitizer)
    use_scan = _scan_enabled() if scan is None else scan
    return _simulate_fleet_vec_impl(traces, method, cost, fleet, use_scan,
                                    sanitizer=sanitizer)
