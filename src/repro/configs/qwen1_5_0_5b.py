"""qwen1.5-0.5b [dense] — QKV bias, full attention.

24L d_model=1024 16H (GQA kv=16, i.e. MHA) d_ff=2816 vocab=151936, head_dim=64.
[hf:Qwen/Qwen1.5-0.5B; hf].
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151_936,
    head_dim=64,
    attn_pattern=(GLOBAL_ATTN,),
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
