"""Pallas TPU kernels for the perf-critical compute/data-movement hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), <name>/ops.py (jitted public wrapper; interpret-mode on CPU), and
<name>/ref.py (pure-jnp oracle used by the allclose test sweeps).

  * flash_attention — blockwise online-softmax prefill attention
                      (causal / SWA / softcap / GQA)
  * decode_attention — single-token flash decode over long (ring) KV caches
  * diag_recurrence — chunked diagonal linear recurrence (Mamba-1 / RG-LRU scan)
  * page_gather     — paged weight-restore gather (WarmSwap pool hot path,
                      scalar-prefetch DMA pattern)
"""
from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.diag_recurrence import diag_recurrence, diag_recurrence_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.page_gather import page_gather, page_gather_ref

__all__ = [
    "flash_attention", "attention_ref",
    "decode_attention", "decode_attention_ref",
    "diag_recurrence", "diag_recurrence_ref",
    "page_gather", "page_gather_ref",
]
