"""Live dependency images: pre-initialized, shareable base-model bring-up state.

A :class:`LiveDependencyImage` is the WarmSwap unit of sharing (paper §3.2): the
provider builds it ONCE per (architecture, dtype) — not per function — by running the
function-independent prefix of startup:

    init/load weights -> (optionally pre-shard) -> paginate into the host-RAM pool
    -> pre-build executables for the serving step shapes (the XLA-compile analogue of
       the paper's pre-imported middleware)

and every endpoint that uses that base model restores from it. The split between
``ImageMetadata`` (small; transferred during the *communication* phase) and the page
store (large; streamed by the page server) mirrors CRIU's process-metadata /
memory-pages split — Table 3 measures exactly this asymmetry.

Images can be dumped to a **disk tier** (``dump_to_disk`` / ``from_disk``): the paper
keeps checkpoint images on disk to regenerate live images without re-running the
initialization (§3.2), which is also this framework's recovery path after eviction or
node failure.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.pages import DEFAULT_PAGE_SIZE, PageTable, materialize, paginate


@dataclass
class ImageMetadata:
    image_id: str
    arch_name: str
    dtype: str
    page_table: PageTable
    treedef_repr: str                  # structural fingerprint (restore sanity check)
    compile_keys: tuple = ()           # (step, shape-signature) executables warmed
    created_at: float = 0.0
    content_hash: str = ""

    def nbytes(self) -> int:
        """The paper's 'process metadata size' (Table 3)."""
        return self.page_table.metadata_bytes() + len(self.treedef_repr) + 256


class LiveDependencyImage:
    """An in-memory dependency image: page store + metadata + warmed executables."""

    def __init__(self, metadata: ImageMetadata, store: np.ndarray, treedef,
                 executables: Optional[Dict[str, Any]] = None):
        self.metadata = metadata
        self.store = store                     # (n_pages, page_size) uint8, host RAM
        self.treedef = treedef
        self.executables = executables or {}   # compile-cache: key -> compiled fn
        self.refcount = 0
        # Live-manager LRU clock.  # repro-lint: allow[wall-clock]
        self.last_used = time.monotonic()

    # -- sizes -------------------------------------------------------------------
    @property
    def image_bytes(self) -> int:
        """Page-store size in bytes (what the pool's CapacityLedger accounts)."""
        return int(self.store.nbytes)

    @property
    def n_pages(self) -> int:
        """Pages in the store — the unit the page-granular cost model
        (``core/costmodel.py``) prices migration in."""
        return int(self.metadata.page_table.n_pages)

    @property
    def metadata_bytes(self) -> int:
        """Serialized-metadata size in bytes (the 'communication' payload)."""
        return self.metadata.nbytes()

    # -- materialization ----------------------------------------------------------
    def params(self) -> Any:
        return materialize(self.store, self.metadata.page_table, self.treedef)

    # -- disk tier (checkpoint images, paper §3.2) ---------------------------------
    def dump_to_disk(self, directory: str) -> str:
        os.makedirs(directory, exist_ok=True)
        path = os.path.join(directory, f"{self.metadata.image_id}.npz")
        tmp = path + ".tmp"
        np.savez(tmp if not tmp.endswith(".npz") else tmp[:-4],
                 store=self.store)
        os.replace(tmp if tmp.endswith(".npz") else tmp + ".npz", path)
        meta = {
            "image_id": self.metadata.image_id,
            "arch_name": self.metadata.arch_name,
            "dtype": self.metadata.dtype,
            "page_table": self.metadata.page_table.to_json(),
            "treedef_repr": self.metadata.treedef_repr,
            "created_at": self.metadata.created_at,
            "content_hash": self.metadata.content_hash,
        }
        with open(os.path.join(directory, f"{self.metadata.image_id}.json"), "w") as f:
            json.dump(meta, f)
        return path

    @classmethod
    def from_disk(cls, directory: str, image_id: str, treedef) -> "LiveDependencyImage":
        with open(os.path.join(directory, f"{image_id}.json")) as f:
            meta = json.load(f)
        store = np.load(os.path.join(directory, f"{image_id}.npz"))["store"]
        md = ImageMetadata(
            image_id=meta["image_id"], arch_name=meta["arch_name"], dtype=meta["dtype"],
            page_table=PageTable.from_json(meta["page_table"]),
            treedef_repr=meta["treedef_repr"], created_at=meta["created_at"],
            content_hash=meta["content_hash"])
        return cls(md, store, treedef)


def build_image(
    image_id: str,
    arch_name: str,
    params_builder: Callable[[], Any],
    *,
    page_size: int = DEFAULT_PAGE_SIZE,
    dtype: str = "bfloat16",
    executables: Optional[Dict[str, Any]] = None,
) -> LiveDependencyImage:
    """Run the shareable bring-up prefix and dump it as a live image.

    ``params_builder`` is the dependency-initialization work being amortized:
    weight init or checkpoint deserialization. It runs exactly once per image,
    no matter how many functions later share the image.
    """
    params = params_builder()
    store, table, treedef = paginate(params, page_size=page_size)
    h = hashlib.sha256()
    h.update(store[: min(len(store), 4)].tobytes())  # cheap content fingerprint
    h.update(str(table.n_pages).encode())
    md = ImageMetadata(
        image_id=image_id, arch_name=arch_name, dtype=dtype, page_table=table,
        # Provenance timestamp on the live image, not a simulated quantity.
        treedef_repr=str(treedef), created_at=time.time(),  # repro-lint: allow[wall-clock]
        content_hash=h.hexdigest()[:16],
        compile_keys=tuple(sorted((executables or {}).keys())),
    )
    return LiveDependencyImage(md, store, treedef, executables)
