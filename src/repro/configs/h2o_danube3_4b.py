"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, head_dim=120.
[arXiv:2401.16818; unverified]. SWA window 4096 on all layers (mistral-style).
"""
from repro.models.config import ArchConfig, LOCAL_ATTN

CONFIG = ArchConfig(
    name="h2o-danube3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32_000,
    head_dim=120,
    attn_pattern=(LOCAL_ATTN,),
    window=4096,
    mlp="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)
