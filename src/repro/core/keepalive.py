"""Keep-alive / cold-start arrival math (paper §2.2, Fig. 1).

With Poisson invocations at rate λ (per minute) and keep-alive T minutes:

    P(no invocation within T)  =  e^(−λT)                       (paper Eq. 1)
    E[cold starts in D min]    =  D · λ · e^(−λT)                (paper Eq. 2)

maximized at λ* = 1/T. Function-specific tuning pays off only when
w·E_cs(λ) > c (Eq. 3) — the long tail fails this test, which is WarmSwap's
raison d'être.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


def p_no_invocation(lam: float, keep_alive_min: float) -> float:
    return math.exp(-lam * keep_alive_min)


def expected_cold_starts(lam, keep_alive_min: float, horizon_min: float):
    """Vectorized Eq. 2."""
    lam = np.asarray(lam, dtype=np.float64)
    return horizon_min * lam * np.exp(-lam * keep_alive_min)


def argmax_rate(keep_alive_min: float) -> float:
    """The invocation rate with the most expected cold starts: λ* = 1/T."""
    return 1.0 / keep_alive_min


def worth_function_specific_tuning(lam: float, keep_alive_min: float,
                                   horizon_min: float, benefit_per_cs: float,
                                   cost: float) -> bool:
    """Paper Eq. 3: w·E_cs(λ) > c."""
    return benefit_per_cs * float(expected_cold_starts(lam, keep_alive_min,
                                                       horizon_min)) > cost


@dataclass(frozen=True)
class KeepAlivePolicy:
    keep_alive_min: float = 15.0     # paper's default (§4.5); AWS/Azure use 5–30

    def expires_at(self, last_use_min: float) -> float:
        return last_use_min + self.keep_alive_min
