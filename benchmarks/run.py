"""Benchmark driver — one benchmark per paper table/figure + assignment artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only coldstart,...]

Emits ``name,us_per_call,derived`` CSV rows (stdout) and JSON artifacts under
results/.  Mapping to the paper:

    bench_coldstart  ->  Figs. 3, 5, 6 (cold/warm, phase breakdown)
    bench_policies   ->  prewarm x placement tournament vs the hindsight
                         oracle (Pareto front + per-cell oracle gap), the
                         per-spec oracle-dominance audit, and — full scale
                         only — Table 2 (bulk / lazy / no-pageserver /
                         no-lazy)
    bench_metadata   ->  Table 3 (metadata vs image size)
    bench_sharing    ->  Fig. 7 + 88% memory headline (Azure-trace simulation)
    bench_fleet      ->  multi-worker fleet sweep (workers x capacity x skew x
                         sharing), placement + pre-warm policy comparison,
                         queue-accurate P50/P95/P99 per rate quartile
                         (NaN/negative latencies fail the run)
    bench_kernels    ->  kernel-path microbenches + VMEM accounting
    bench_roofline   ->  assignment §Roofline table (from dry-run artifacts)

``--smoke`` shrinks the simulation suites (sharing, fleet, policies) to CI
size (the scale switch is ``benchmarks.common.set_smoke`` — one definition
for the driver and CI) and writes ``results/BENCH_smoke.json``: the
canonical perf baseline (per-bench wall clock + headline metrics, including
the oracle-dominance gap minima) that CI's ``bench`` job uploads and
band-checks (``tools/ci/check_bench.py``). The measurement suites
(coldstart, kernels, ...) always do real work; ``policies`` drops its live
Table-2 stack under ``--smoke``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import save_json, set_smoke

BENCHES = ["coldstart", "policies", "metadata", "sharing", "fleet", "kernels",
           "roofline"]

#: Version of the ``BENCH_smoke.json`` artifact layout.
BENCH_SCHEMA_VERSION = 1


def _headline(outs: dict) -> dict:
    """The paper-band metrics CI guards, pulled from the bench outputs that
    produced them (absent benches simply contribute nothing)."""
    head: dict = {}
    fleet = outs.get("fleet") or {}
    if "degenerate" in fleet:
        head["memory_saving_vs_prebaking"] = \
            fleet["degenerate"]["memory_saving_vs_prebaking"]
    if "page_model" in fleet:
        head["dependency_loading_speedup"] = \
            fleet["page_model"]["dependency_loading_speedup_paper_scale"]
    if "azure_scale" in fleet:
        head["azure_scale_n_invocations"] = \
            fleet["azure_scale"]["n_invocations"]
        head["azure_scale_wall_clock_s"] = \
            fleet["azure_scale"]["wall_clock_s"]
    if "azure_scale_xl" in fleet:
        head["azure_scale_xl_n_invocations"] = \
            fleet["azure_scale_xl"]["n_invocations"]
        head["azure_scale_xl_wall_clock_s"] = \
            fleet["azure_scale_xl"]["wall_clock_s"]
    if "stream_ingest" in fleet:
        # out-of-core ingestion headline: invocation count is deterministic
        # (trend-gated exactly); wall clock is trend-gated with slack
        head["stream_ingest_n_invocations"] = \
            fleet["stream_ingest"]["n_invocations"]
        head["stream_ingest_wall_clock_s"] = \
            fleet["stream_ingest"]["wall_clock_s"]
    if "sanitize_overhead" in fleet:
        # repro-san cost headline (check_bench fails above 3x)
        head["sanitize_overhead_ratio"] = \
            fleet["sanitize_overhead"]["ratio"]
    sharing = outs.get("sharing") or {}
    if "paper_costs" in sharing:
        head["sharing_memory_saving_vs_prebaking"] = \
            sharing["paper_costs"]["memory_saving_vs_prebaking"]
    policies = outs.get("policies") or {}
    if "oracle_gap" in policies:
        # the dominance headline: minimum oracle gap over every tournament
        # cell and audited spec x method (check_bench fails on < 0 or NaN)
        gap = policies["oracle_gap"]
        head["oracle_gap"] = {
            "min_total_gap_s": gap["min_total_gap_s"],
            "min_p99_gap_s": gap["min_p99_gap_s"],
            "n_cells": gap["n_cells"],
        }
    return head


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs for the simulation suites "
                         "(sharing, fleet); pair with --only")
    args = ap.parse_args()
    set_smoke(args.smoke)
    todo = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    failures = 0
    cells: dict = {}
    outs: dict = {}
    for name in todo:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            outs[name] = mod.run()
            wall = time.perf_counter() - t0
            cells[name] = {"ok": True, "wall_clock_s": wall}
            print(f"# {name}: ok ({wall:.1f}s)", file=sys.stderr)
        except Exception:
            failures += 1
            cells[name] = {"ok": False,
                           "wall_clock_s": time.perf_counter() - t0}
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)
    if args.smoke:
        path = save_json("BENCH_smoke", {
            "bench_schema_version": BENCH_SCHEMA_VERSION,
            "smoke": True,
            "cells": cells,
            "headline": _headline(outs),
        })
        print(f"# wrote {path}", file=sys.stderr)
    sys.exit(int(failures > 0))


if __name__ == "__main__":
    main()
