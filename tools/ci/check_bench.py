#!/usr/bin/env python
"""Perf-baseline band check over the canonical ``BENCH_smoke.json`` artifact
(written by ``python -m benchmarks.run --smoke``). CI's ``bench`` job fails
when a headline metric leaves the paper's bands or the production-scale
replay regresses:

  * memory saving vs Prebaking: 88 % +- 5 points (paper §4.5 headline);
  * dependency-loading speedup: inside the paper's 2.2-3.2x band;
  * azure_scale: >= 1M invocations simulated end-to-end in < 60 s;
  * azure_scale_xl: >= 10M invocations through the vectorized engine
    (``engine='fleet_vec'``) in < 60 s;
  * oracle dominance: the minimum oracle gap over every tournament cell
    and audited scenario x method (``bench_policies``) must be finite and
    >= 0 — a negative gap means an online policy beat the hindsight floor,
    i.e. the floor (or an engine) is wrong (docs/SIMULATION.md, "Oracle
    and disruption semantics");
  * sanitizer overhead: the repro-san invariant sanitizer
    (docs/ANALYSIS.md, "Runtime sanitizer") must keep a sanitized smoke
    run within 3x the unsanitized wall clock — it has to stay cheap
    enough to leave on in CI.

Runs locally too:

    python tools/ci/check_bench.py [results/BENCH_smoke.json]
"""
import json
import math
import sys

SAVING_BAND = (0.83, 0.93)       # 88 % +- 5 points
SPEEDUP_BAND = (2.2, 3.2)        # paper Table 2 / Fig. 5 band
SCALE_FLOOR = 1_000_000          # azure_scale invocation floor
SCALE_BUDGET_S = 60.0            # azure_scale wall-clock budget (CI hardware)
SCALE_XL_FLOOR = 10_000_000      # azure_scale_xl invocation floor (fleet_vec)
SCALE_XL_BUDGET_S = 60.0         # azure_scale_xl wall-clock budget
SANITIZE_RATIO_MAX = 3.0         # sanitized / plain wall-clock budget


def main(path="results/BENCH_smoke.json"):
    data = json.load(open(path))
    assert data.get("bench_schema_version") == 1, \
        f"unknown bench schema in {path}"
    failed_cells = [n for n, c in data["cells"].items() if not c.get("ok")]
    assert not failed_cells, f"bench cells failed: {failed_cells}"
    head = data["headline"]

    saving = head["memory_saving_vs_prebaking"]
    assert SAVING_BAND[0] <= saving <= SAVING_BAND[1], \
        f"memory saving {saving:.3f} outside {SAVING_BAND} (paper: 0.88)"
    sharing_saving = head.get("sharing_memory_saving_vs_prebaking", saving)
    assert SAVING_BAND[0] <= sharing_saving <= SAVING_BAND[1], \
        f"sharing-bench saving {sharing_saving:.3f} outside {SAVING_BAND}"
    speedup = head["dependency_loading_speedup"]
    assert SPEEDUP_BAND[0] <= speedup <= SPEEDUP_BAND[1], \
        f"dependency-loading speedup {speedup:.2f}x outside {SPEEDUP_BAND}"

    n_inv = head["azure_scale_n_invocations"]
    wall = head["azure_scale_wall_clock_s"]
    assert n_inv >= SCALE_FLOOR, \
        f"azure_scale simulated only {n_inv} invocations (< {SCALE_FLOOR})"
    assert wall < SCALE_BUDGET_S, \
        f"azure_scale took {wall:.1f}s (budget {SCALE_BUDGET_S}s) — " \
        f"fleet-engine hot path regressed"

    n_inv_xl = head["azure_scale_xl_n_invocations"]
    wall_xl = head["azure_scale_xl_wall_clock_s"]
    assert n_inv_xl >= SCALE_XL_FLOOR, \
        f"azure_scale_xl simulated only {n_inv_xl} invocations " \
        f"(< {SCALE_XL_FLOOR})"
    assert wall_xl < SCALE_XL_BUDGET_S, \
        f"azure_scale_xl took {wall_xl:.1f}s (budget {SCALE_XL_BUDGET_S}s) — " \
        f"vectorized engine (fleet_vec) hot path regressed"

    san_ratio = head["sanitize_overhead_ratio"]
    assert isinstance(san_ratio, (int, float)) and math.isfinite(san_ratio) \
        and san_ratio > 0, \
        f"sanitize_overhead_ratio is not a positive finite number: {san_ratio!r}"
    assert san_ratio <= SANITIZE_RATIO_MAX, \
        f"sanitized run took {san_ratio:.2f}x the plain wall clock " \
        f"(budget {SANITIZE_RATIO_MAX}x) — the repro-san sanitizer got too " \
        f"expensive to leave on"

    gap = head["oracle_gap"]
    for key in ("min_total_gap_s", "min_p99_gap_s"):
        v = gap[key]
        assert isinstance(v, (int, float)) and math.isfinite(v), \
            f"oracle_gap.{key} is not a finite number: {v!r}"
        assert v >= 0, \
            f"oracle_gap.{key} = {v} < 0: an online policy undercut the " \
            f"hindsight floor — the oracle-dominance invariant is broken"
    assert gap.get("n_cells", 0) >= 1, \
        f"oracle_gap audited no cells: {gap!r}"

    print(f"ok: saving {saving:.1%} (band {SAVING_BAND}), "
          f"dep speedup {speedup:.2f}x (band {SPEEDUP_BAND}), "
          f"azure_scale {n_inv:,} invocations in {wall:.1f}s "
          f"(< {SCALE_BUDGET_S:.0f}s), "
          f"azure_scale_xl {n_inv_xl:,} invocations in {wall_xl:.1f}s "
          f"(< {SCALE_XL_BUDGET_S:.0f}s), "
          f"oracle dominance holds over {gap['n_cells']} cell(s) "
          f"(min gap {gap['min_total_gap_s']:.3f}s), "
          f"sanitizer overhead {san_ratio:.2f}x (< {SANITIZE_RATIO_MAX:.0f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
