"""Shared-state hygiene checker: the PR-1 and PR-4 bug classes, at the AST.

* ``mutable-default`` — ``def f(x=[])`` / ``={}`` / ``=set()``: the default
  is created once and shared by every call (and, in this repo, by every
  simulation in a sweep — the PR-1 shared-mutable-default class);
* ``module-mutable`` — a module-level list/dict/set literal mutated from
  inside a function (or rebound via ``global``): cross-run state that
  survives between scenarios in one process;
* ``loop-closure`` — a closure defined inside a loop that reads the loop
  variable freely: Python binds late, so every closure sees the *last*
  iteration's value once the loop has advanced (the PR-4 shape — the
  ``pick_worker``/``spawn_prewarm`` closures silently reading a stale heap
  key). Closures consumed immediately by ``sorted``/``min``/``max``/
  ``map``/``filter`` (or called on the spot) are exempt;
* ``stale-capture`` — a closure reading a free variable that the enclosing
  function *rebinds after* the closure is defined: the closure sees the
  rebound value when it finally runs, which is exactly how the PR-4
  counters got silently zeroed.

Scope: ``config.SHARED_STATE_SCOPE``. Intentional module-level state (the
bench stack cache, the scan-path diagnostics dict) is sanctioned inline
with ``# repro-lint: allow[module-mutable]``.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis import config
from tools.analysis.base import SourceFile, qualname_index
from tools.analysis.findings import Finding

CHECKER = "shared-state"

_MUTATORS = {"append", "add", "update", "extend", "insert", "remove",
             "discard", "setdefault", "clear", "pop", "popitem"}
#: Calls that consume a closure argument before the loop advances.
_IMMEDIATE_CONSUMERS = {"sorted", "min", "max", "map", "filter", "sum",
                        "any", "all", "key"}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.SetComp, ast.DictComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("list", "dict", "set"))


def check(src: SourceFile) -> List[Finding]:
    if not config.in_scope(src.rel, config.SHARED_STATE_SCOPE):
        return []
    scopes = qualname_index(src.tree)
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(src.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    findings: List[Finding] = []

    def emit(rule: str, node: ast.AST, message: str, suggestion: str) -> None:
        f = src.finding(CHECKER, rule, node, message,
                        scope=scopes.get(node, ""), suggestion=suggestion)
        if f is not None:
            findings.append(f)

    # ------------------------------------------------------- mutable-default
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            args = node.args
            for default in list(args.defaults) + \
                    [d for d in args.kw_defaults if d is not None]:
                if _is_mutable_literal(default):
                    name = getattr(node, "name", "<lambda>")
                    emit("mutable-default", default,
                         f"mutable default argument in '{name}' — created "
                         f"once, shared by every call",
                         "default to None and create the container inside "
                         "the function")

    # -------------------------------------------------------- module-mutable
    module_mutables: Set[str] = set()
    for stmt in src.tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and \
                isinstance(stmt.targets[0], ast.Name) and \
                _is_mutable_literal(stmt.value):
            module_mutables.add(stmt.targets[0].id)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None and \
                isinstance(stmt.target, ast.Name) and \
                _is_mutable_literal(stmt.value):
            module_mutables.add(stmt.target.id)
    if module_mutables:
        for node in ast.walk(src.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            local_shadow = {a.arg for a in (node.args.args
                                            + node.args.kwonlyargs
                                            + node.args.posonlyargs)}
            for inner in ast.walk(node):
                hit: Optional[Tuple[ast.AST, str]] = None
                if isinstance(inner, ast.Global):
                    for name in inner.names:
                        if name in module_mutables:
                            hit = (inner, name)
                elif isinstance(inner, ast.Call) and \
                        isinstance(inner.func, ast.Attribute) and \
                        isinstance(inner.func.value, ast.Name) and \
                        inner.func.value.id in module_mutables and \
                        inner.func.value.id not in local_shadow and \
                        inner.func.attr in _MUTATORS:
                    hit = (inner, inner.func.value.id)
                elif isinstance(inner, (ast.Subscript,)) and \
                        isinstance(inner.ctx, (ast.Store, ast.Del)) and \
                        isinstance(inner.value, ast.Name) and \
                        inner.value.id in module_mutables and \
                        inner.value.id not in local_shadow:
                    hit = (inner, inner.value.id)
                if hit is not None:
                    n, name = hit
                    emit("module-mutable", n,
                         f"module-level mutable '{name}' mutated from "
                         f"function scope — state leaks across runs in one "
                         f"process",
                         "pass the container in explicitly, or sanction an "
                         "intentional process-wide cache with "
                         "'# repro-lint: allow[module-mutable]'")

    # ------------------------------------- loop-closure and stale-capture
    for func in ast.walk(src.tree):
        if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        findings.extend(_check_closures(src, func, scopes, parents))
    return findings


def _target_names(target: ast.AST) -> Set[str]:
    """Names an assignment target REBINDS: bare names and tuple/list/star
    elements — not the base of ``obj.attr = ...`` / ``obj[k] = ...``, which
    mutate the object without rebinding the name."""
    if isinstance(target, ast.Name):
        return {target.id}
    if isinstance(target, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for elt in target.elts:
            out |= _target_names(elt)
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value)
    return set()


def _loop_targets(loop: ast.AST) -> Set[str]:
    names: Set[str] = set()
    if isinstance(loop, (ast.For, ast.AsyncFor)):
        for n in ast.walk(loop.target):
            if isinstance(n, ast.Name):
                names.add(n.id)
    return names


def _closure_free_loads(closure: ast.AST) -> Set[str]:
    """Names the closure reads that it neither binds as params nor assigns
    locally (an approximation of its free variables)."""
    if isinstance(closure, ast.Lambda):
        body, args = [closure.body], closure.args
    else:
        body, args = closure.body, closure.args
    bound = {a.arg for a in (args.args + args.kwonlyargs + args.posonlyargs)}
    if args.vararg:
        bound.add(args.vararg.arg)
    if args.kwarg:
        bound.add(args.kwarg.arg)
    loads: Set[str] = set()
    assigned: Set[str] = set()
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Name):
                if isinstance(n.ctx, ast.Load):
                    loads.add(n.id)
                else:
                    assigned.add(n.id)
    return loads - bound - assigned


def _immediately_consumed(closure: ast.AST,
                          parents: Dict[ast.AST, ast.AST]) -> bool:
    """True when the closure is an argument of a consume-now call (sorted/
    min/max/...), a ``key=`` keyword, or is invoked on the spot."""
    p = parents.get(closure)
    if isinstance(p, ast.keyword) and p.arg == "key":
        return True
    if isinstance(p, ast.Call):
        if p.func is closure:            # (lambda: ...)() — IIFE
            return True
        fname = p.func.id if isinstance(p.func, ast.Name) else \
            p.func.attr if isinstance(p.func, ast.Attribute) else ""
        if fname in _IMMEDIATE_CONSUMERS:
            return True
    return False


def _check_closures(src: SourceFile, func: ast.AST, scopes, parents
                    ) -> List[Finding]:
    findings: List[Finding] = []

    # names rebound (plain Name assignment) in func's own body, with lines —
    # excludes nested function bodies, which have their own scopes
    rebinds: Dict[str, List[int]] = {}

    def collect_rebinds(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda, ast.ClassDef)):
                continue
            if isinstance(child, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                targets = (child.targets if isinstance(child, ast.Assign)
                           else [child.target])
                for t in targets:
                    for name in _target_names(t):
                        rebinds.setdefault(name, []).append(child.lineno)
            elif isinstance(child, (ast.For, ast.AsyncFor)):
                for name in _target_names(child.target):
                    rebinds.setdefault(name, []).append(child.lineno)
            collect_rebinds(child)

    collect_rebinds(func)

    def visit(node: ast.AST, loops: List[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                free = _closure_free_loads(child)
                consumed = _immediately_consumed(child, parents)
                name = getattr(child, "name", "<lambda>")
                in_loop_targets = {t for lp in loops
                                   for t in _loop_targets(lp)}
                if not consumed:
                    late = sorted(free & in_loop_targets)
                    if late:
                        f = src.finding(
                            CHECKER, "loop-closure", child,
                            f"closure '{name}' captures loop variable(s) "
                            f"{late} by reference — every closure sees the "
                            f"last iteration's value (late binding)",
                            scope=scopes.get(child, ""),
                            suggestion=f"bind the current value as a "
                                       f"default: lambda {late[0]}="
                                       f"{late[0]}: ...")
                        if f is not None:
                            findings.append(f)
                    else:
                        end = getattr(child, "end_lineno", child.lineno)
                        stale = sorted(
                            v for v in free
                            if any(ln > end for ln in rebinds.get(v, ())))
                        if stale:
                            f = src.finding(
                                CHECKER, "stale-capture", child,
                                f"closure '{name}' reads {stale} which the "
                                f"enclosing function rebinds later — the "
                                f"closure will see the rebound value, not "
                                f"the one at definition",
                                scope=scopes.get(child, ""),
                                suggestion="bind the value locally before "
                                           "the def (x = x) or pass it as a "
                                           "defaulted parameter")
                            if f is not None:
                                findings.append(f)
                # nested defs get their own pass via the outer loop in check()
                continue
            child_loops = loops
            if isinstance(child, (ast.For, ast.AsyncFor, ast.While)):
                child_loops = loops + [child]
                # the loop's iter/target are evaluated outside the body
                visit(child, child_loops)
                continue
            visit(child, child_loops)

    visit(func, [])
    return findings
