"""Multi-worker fleet simulation with concurrency (beyond paper Fig. 7).

``simulator.simulate()`` is the paper-faithful single-worker model: one instance
per function, an always-resident shared image, static memory accounting. This
module generalizes it into the regime the paper's fleet-level claims actually
live in:

  * **concurrency** — an arrival that finds every instance of its function busy
    spawns a *new* cold/warm instance instead of being serialized;
  * **queueing** — with ``max_instances_per_fn`` set, an at-cap arrival joins a
    per-worker FIFO queue and is dispatched by the instance-free event of the
    next completing instance; its latency = queue delay + warm cost, so tail
    latency under contention is queue-accurate (P99 > mean once requests wait);
  * **N worker nodes** — each with its own Dependency-Manager pool, modeled by
    the same :class:`~repro.core.pool.CapacityLedger` the real manager uses
    (capacity + LRU + refcounts), so images get evicted and revived under
    memory pressure exactly like the live pool;
  * **placement** — invocations are routed by
    :func:`repro.serving.scheduler.place_invocation`: warm-instance affinity,
    then image-affinity (the pool already holds the live image), then
    least-loaded *including queue depth*; round-robin and plain least-loaded
    are available as controls;
  * **pluggable pre-warm policies** (:mod:`repro.core.keepalive`) — fixed
    keep-alive (paper §4.5), histogram-adaptive keep-alive, SPES-style
    predictive pre-warming, and byte-minute-budgeted keep-alive, comparable
    under identical placement. Policies see completion events
    (``on_completion``) and the bytes an idle instance pins, not just
    arrival times;
  * **page-granular cold starts** (``FleetConfig.page_cost``,
    :mod:`repro.core.costmodel`) — cold latency = scalar base + blocking page
    transfer, priced by image pages, link bandwidth, the BULK fault/stream
    mix, and which tier serves the pages: the worker's own pool, a peer
    worker via the **cluster-shared image cache**
    (:class:`repro.core.pool.ClusterImageCache` — each image is fetched from
    source once, then shared fleet-wide), or the source store. Placement
    ranks workers by that transfer cost (``place_invocation(start_cost=...)``).
    The full contract lives in docs/SIMULATION.md.

The engine is a discrete-event simulation (``core/events.py``): one heap of
typed events (instance-free, pre-warm spawn, keep-alive expiry) merged against
the vectorized, pre-sorted arrival stream. Invariants the engine maintains:

  * ``busy_until`` is monotone per instance — a request never starts before
    the previous one on the same instance completed;
  * residency accounting clamps instance lifetimes to the trace horizon
    (the last arrival time), so ``instance_resident_min`` never counts
    keep-alive time the trace window cannot observe;
  * pre-warm spawns scheduled past the horizon are drained and accounted as
    ``prewarm_dropped`` rather than silently lost.

Degenerate case: ``n_workers=1``, unlimited capacity, ``max_instances_per_fn=1``
reproduces ``simulate()`` — including the ~88 % memory-saving headline at
sharing degree 10 (verified in tests/test_fleet.py).
"""
from __future__ import annotations

import copy
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.costmodel import PageCostModel
from repro.core.disruption import DisruptionSchedule
from repro.core.events import EventKind, EventQueue
from repro.core.keepalive import PREWARM_POLICIES, PrewarmPolicy
from repro.core.pool import CapacityLedger, ClusterImageCache
from repro.core.sanitize import FleetSanitizer, sanitize_enabled
from repro.core.simulator import (CostModel, latency_percentiles,
                                  method_cold_latency_s)
from repro.core.trace_stream import TraceStream
from repro.core.traces import Trace

# EventKind ranks as plain ints: the hot loop compares and pushes these
# without paying an enum construction or comparison per event
_FREE = int(EventKind.INSTANCE_FREE)
_SPAWN = int(EventKind.PREWARM_SPAWN)
_ARRIVAL = int(EventKind.ARRIVAL)
_EXPIRY = int(EventKind.KEEPALIVE_EXPIRY)
_FAIL = int(EventKind.WORKER_FAIL)
_RECOVER = int(EventKind.WORKER_RECOVER)
_FLUSH = int(EventKind.CACHE_FLUSH)


@dataclass
class FleetConfig:
    """Fleet-simulation knobs (times in minutes, sizes in bytes).

    ``page_cost`` switches the engine from scalar cold-start pricing to the
    page-granular model: cold latency becomes a function of image pages, link
    bandwidth, the BULK fault/stream mix, and where the pages come from — the
    worker's own pool (local), a peer worker via the cluster-shared image
    cache (remote), or the source store (miss). ``shared_cache_bytes`` bounds
    that cluster tier; it requires ``page_cost``.
    ``PageCostModel.degenerate(cost)`` (zero per-request latency, infinite
    bandwidth) reproduces the scalar engine's numbers exactly in the
    degenerate configuration — see docs/SIMULATION.md.
    """
    n_workers: int = 1
    placement: Union[str, Callable] = "affinity"
                                           # a serving/scheduler.PLACEMENTS key
                                           # ('affinity' | 'least_loaded' |
                                           # 'round_robin' | any registered
                                           # strategy) or a ready strategy
                                           # callable (workers, ctx) -> worker
    max_instances_per_fn: Optional[int] = None   # None = unbounded concurrency.
                                                 # The cap (and its FIFO queue) is
                                                 # per WORKER: with n_workers=1,
                                                 # cap=1 is simulate()'s serialized
                                                 # model; with several workers,
                                                 # placement may spawn on another
                                                 # worker instead of queueing
    worker_capacity_bytes: Optional[int] = None  # per-worker pool capacity
    prewarm: Union[str, PrewarmPolicy] = "none"  # policy name or ready instance
    keep_alive_min: float = 15.0                 # window for the 'none' policy
    page_cost: Optional[PageCostModel] = None    # page-granular cold pricing
    shared_cache_bytes: Optional[int] = None     # cluster-shared image tier
                                                 # capacity (distinct images);
                                                 # None = unbounded; needs
                                                 # page_cost
    disruption: Optional[DisruptionSchedule] = None
                                                 # worker churn / preemption /
                                                 # eviction-storm schedule
                                                 # (core/disruption.py); its
                                                 # n_workers must match


@dataclass(slots=True)
class _Instance:
    fn: int
    busy_until: float        # minutes; monotone — only ever advanced
    expires: float           # minutes (keep-alive expiry)
    created: float = 0.0
    prewarmed: bool = False
    gen: int = 0             # expiry generation: stale expiry events carry an
                             #   older gen and are dropped on arrival
    killed: bool = False     # worker died: pending free/expiry events for
                             #   this instance are stale and must be ignored
    cur_idx: int = -1        # request index currently (or last) served —
    cur_req_t: float = 0.0   #   and its original arrival time, so a worker
                             #   failure can requeue the in-flight request


class _Worker:
    __slots__ = ("idx", "ledger", "instances", "queues", "metadata_fns",
                 "n_served", "instance_min", "in_flight", "queued_now",
                 "failed")

    def __init__(self, idx: int, capacity_bytes: Optional[int]):
        self.idx = idx
        self.ledger = CapacityLedger(capacity_bytes)
        self.instances: Dict[int, List[_Instance]] = {}
        self.queues: Dict[int, Deque[Tuple[float, int]]] = {}  # fn -> (t, req idx)
        self.metadata_fns: set = set()
        self.failed = False          # down due to a disruption worker_fail
        self.n_served = 0
        self.instance_min = 0.0      # total warm-instance residency (minutes)
        self.in_flight = 0           # requests currently executing; maintained
                                     #   incrementally (begin_service +1,
                                     #   INSTANCE_FREE -1) so placement's load
                                     #   signal is O(1) per decision
        self.queued_now = 0          # requests waiting in self.queues

    def alive(self, fn: int) -> List[_Instance]:
        """Instances of ``fn``; expiry events (not reads) prune this list."""
        return self.instances.get(fn, [])

    def idle_instance(self, fn: int, t: float) -> Optional[_Instance]:
        """The idle instance of ``fn`` with the earliest previous completion,
        or ``None``. Valid at the current simulation time only (events up to
        ``t`` must have been processed)."""
        best = None
        for inst in self.instances.get(fn, ()):
            if inst.busy_until <= t and (best is None
                                         or inst.busy_until < best.busy_until):
                best = inst
        return best

    def load(self, t: float = 0.0) -> int:
        """In-flight requests on this worker. O(1): the engine maintains the
        count incrementally, which equals the number of busy instances at the
        current simulation time (completion events at or before now have
        already fired — the heap ranks ``INSTANCE_FREE`` ahead of arrivals)."""
        return self.in_flight

    def queue_depth(self) -> int:
        return self.queued_now


@dataclass
class FleetResult:
    """One ``simulate_fleet`` run's outputs. Units: latencies/waits in
    seconds, memory in bytes, residency in instance-minutes, migration
    volume in pages; per-field semantics in the inline comments."""
    method: str
    n_invocations: int
    n_cold: int
    n_warm: int
    total_latency_s: float
    memory_bytes: int                    # PEAK fleet-wide resident bytes
    per_fn_latency: Dict[int, float] = field(default_factory=dict)
    per_fn_invocations: Dict[int, int] = field(default_factory=dict)
    n_workers: int = 1
    pool_misses: int = 0                 # cold starts that paid an image revive
    evictions: int = 0
    prewarm_spawns: int = 0
    prewarm_hits: int = 0
    prewarm_dropped: int = 0             # spawn events past the trace horizon
    max_concurrent_instances: int = 1    # peak instances of any SINGLE function
                                         #   (>1 means arrivals overlapped)
    placement_warm_hits: int = 0         # routed to a worker with an idle warm inst
    placement_pool_hits: int = 0         # routed by image residency
    instance_resident_min: float = 0.0   # warm instance-minutes across the fleet,
                                         #   clamped to the trace horizon
    n_queued: int = 0                    # requests that waited for an instance
    queue_delay_s: float = 0.0           # total time requests spent queued
    horizon_min: float = 0.0             # last arrival time (residency clamp)
    cache_local_hits: int = 0            # page-model cold starts served from
                                         #   the worker's own pool (memcpy)
    cache_remote_hits: int = 0           # ... from a peer worker's pool (DCN)
    cache_misses: int = 0                # ... from the source store (fetched
                                         #   once into the shared tier)
    shared_cache_peak_bytes: int = 0     # distinct-image bytes in the cluster
                                         #   tier, high-water mark
    shared_cache_evictions: int = 0      # cluster-wide capacity evictions
    worker_failures: int = 0             # disruption worker_fail events applied
    worker_recoveries: int = 0           # disruption worker_recover events
    cache_flushes: int = 0               # disruption cache_flush storms applied
    requeued: int = 0                    # requests re-submitted by failures
                                         #   (in-flight + queued on the dead
                                         #   worker); under disruption,
                                         #   n_cold + n_warm counts SERVICE
                                         #   STARTS and can exceed
                                         #   n_invocations by up to this
    pages_transferred: int = 0           # pages moved over the NETWORK (remote
                                         #   + source links; local memcpy not
                                         #   counted) by page-model cold starts
    latency_samples_s: np.ndarray = field(
        default_factory=lambda: np.empty(0))   # per request, merged-arrival order
    queue_wait_s: np.ndarray = field(
        default_factory=lambda: np.empty(0))   # per request, merged-arrival order
    sample_fn: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int64))  # fn index per sample
    per_worker: List[Dict] = field(default_factory=list)

    @property
    def avg_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_invocations, 1)

    def latency_percentiles(self) -> Dict[str, float]:
        """P50/P95/P99 (+ mean/max) over the per-request latency samples."""
        return latency_percentiles(self.latency_samples_s)


def _make_policy(cfg: FleetConfig) -> PrewarmPolicy:
    if isinstance(cfg.prewarm, PrewarmPolicy):
        # copy: policies accumulate arrival history, and reusing the caller's
        # instance across runs would leak state between simulations
        return copy.deepcopy(cfg.prewarm)
    if cfg.prewarm == "none":
        return PrewarmPolicy(keep_alive_min=cfg.keep_alive_min)
    return PREWARM_POLICIES.build(cfg.prewarm)


def _seed_home_residents(method: str, workers: List["_Worker"],
                         fn_image: Dict[int, int], images: List[int],
                         admit: Callable[["_Worker", str], None]) -> None:
    """Provider pre-build phase (paper Fig. 4b), shared by the event engine
    and the vectorized engine (``core/fleet_vec.py``) so home-worker seeding
    can never drift between them: WarmSwap builds each live image once on its
    home worker (image rank modulo fleet size) and registers every function's
    metadata there; Prebaking snapshots every function upfront on the same
    home; Baseline holds nothing. ``admit`` is the engine's resident-admission
    hook (worker pool + cluster tier at t=0)."""
    if method == "warmswap":
        for rank, img in enumerate(images):
            admit(workers[rank % len(workers)], f"img:{img}")
        for fn, img in fn_image.items():
            home = workers[images.index(img) % len(workers)]
            home.metadata_fns.add(fn)
    elif method == "prebaking":
        for fn, img in fn_image.items():
            home = workers[images.index(img) % len(workers)]
            admit(home, f"snap:{fn}")


def simulate_fleet(
    traces: List[Trace],
    method: str,                       # 'warmswap' | 'prebaking' | 'baseline'
    cost: CostModel,
    fleet: Optional[FleetConfig] = None,
) -> FleetResult:
    """Discrete-event fleet simulation (see the module docstring).

    Thin wrapper over the declarative entry point
    (:func:`repro.core.scenario.run` with ``engine='fleet'``): the engine
    body is :func:`_simulate_fleet_impl`, and this signature survives for
    callers that already hold resolved components. New code should build a
    :class:`~repro.core.scenario.Scenario` instead.

    Args:
        traces: per-function arrival traces (times in minutes).
        method: ``'warmswap' | 'prebaking' | 'baseline'``.
        cost: scalar cost model (latencies in seconds, sizes in bytes).
        fleet: :class:`FleetConfig`; ``fleet.page_cost`` switches cold starts
            to the page-granular model with a cluster-shared image cache.

    Returns:
        A :class:`FleetResult`: counts, latency samples (seconds),
        peak resident memory (bytes), queueing/placement/pool stats, and —
        under the page model — shared-cache hit tiers and network page volume.
    """
    # deferred: scenario imports this module (the engine impl lives here)
    from repro.core.scenario import RunOverrides, Scenario, run
    result = run(Scenario(engine="fleet", methods=[method]),
                 overrides=RunOverrides(traces=traces, cost=cost, fleet=fleet))
    return result.raw[method]


def _simulate_fleet_impl(
    traces: Union[List[Trace], TraceStream],
    method: str,
    cost: CostModel,
    fleet: Optional[FleetConfig] = None,
    sanitizer: Optional["FleetSanitizer"] = None,
) -> FleetResult:
    """The discrete-event engine body behind :func:`simulate_fleet` (same
    contract); called by :func:`repro.core.scenario.run`. ``sanitizer``
    threads a :class:`repro.core.sanitize.FleetSanitizer` through the run
    (built automatically under ``REPRO_SANITIZE=1``); its checks are
    assertions only, so a sanitized run returns bit-identical results.

    ``traces`` may be a :class:`~repro.core.trace_stream.TraceStream`: the
    engine then consumes arrival chunks as they are produced (peak arrival
    residency = one chunk) and returns results bit-identical to running the
    stream's ``materialize()`` list (docs/TRACES.md). Disruption schedules
    require a materialized trace (the schedule is built against the horizon,
    which a stream only knows at the end)."""
    fleet = fleet if fleet is not None else FleetConfig()
    san = sanitizer
    if san is None and sanitize_enabled():
        san = FleetSanitizer("fleet", method)
    if fleet.n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {fleet.n_workers}")
    if fleet.shared_cache_bytes is not None and fleet.page_cost is None:
        raise ValueError("shared_cache_bytes bounds the page-model cluster "
                         "tier; set FleetConfig.page_cost to enable it")
    is_stream = isinstance(traces, TraceStream)
    disruption = fleet.disruption
    if is_stream and disruption is not None:
        raise ValueError(
            "disruption schedules are built against the trace horizon, which "
            "a stream only knows after its last chunk; materialize the trace "
            "(stream=false) to combine disruption with this workload")
    if disruption is not None and disruption.n_workers != fleet.n_workers:
        raise ValueError(
            f"disruption schedule was built for "
            f"{disruption.n_workers} worker(s) but the fleet has "
            f"{fleet.n_workers}; rebuild it with the fleet's shape")
    # deferred: repro.serving pulls in the model/engine stack, which a
    # simulation-only import of repro.core should not pay for
    from repro.serving.scheduler import (PLACEMENTS, PlacementContext,
                                         place_invocation)
    strategy = (PLACEMENTS.build(fleet.placement)
                if isinstance(fleet.placement, str) else fleet.placement)
    policy = _make_policy(fleet)
    cold_base = method_cold_latency_s(cost, method)
    page = fleet.page_cost
    # bytes an IDLE instance of this method pins — what byte-aware keep-alive
    # policies reason about: warmswap idles on per-fn metadata only (the
    # image is shared), prebaking on its private snapshot, baseline on its
    # privately initialized dependencies
    idle_bytes = {"warmswap": cost.metadata_bytes,
                  "prebaking": cost.snapshot_bytes,
                  "baseline": cost.image_bytes}[method]
    cap = fleet.max_instances_per_fn
    workers = [_Worker(i, fleet.worker_capacity_bytes)
               for i in range(fleet.n_workers)]
    # placement only ever routes over the LIVE workers; rebound (not mutated)
    # by the worker_fail / worker_recover handlers, so the fair-weather path
    # never pays a per-arrival liveness scan
    live = workers
    orphans: List[Tuple[float, int, int]] = []   # (req_t, idx, fn) waiting for
                                                 #   ANY worker to come back
    # streams expose per-function metadata (rates/images — bounded by fleet
    # size) upfront; only the arrival arrays stay chunked
    trace_meta = traces.meta_traces() if is_stream else traces
    fn_image = {t.fn_index: t.image_id for t in trace_meta}
    images = sorted({t.image_id for t in trace_meta})

    # Cluster-shared image tier (page model only): one ledger of distinct
    # resident images + who holds them. A cluster-capacity eviction drops the
    # image from every worker pool (the tier IS the union of worker pools).
    def _cluster_evict(key: str) -> None:
        for w in workers:
            w.ledger.evict(key)
    cluster = (ClusterImageCache(fleet.shared_cache_bytes,
                                 on_evict=_cluster_evict)
               if page is not None else None)

    def resident_bytes_of(key: str) -> int:
        return cost.snapshot_bytes if key.startswith("snap:") else cost.image_bytes

    def admit_resident(w: _Worker, key: str, t: float) -> None:
        """Admit ``key`` into ``w``'s pool AND the cluster tier, propagating
        any LRU evictions the worker pool makes to the cluster holder sets."""
        nbytes = resident_bytes_of(key)
        for victim in w.ledger.admit(key, nbytes, now=t):
            if cluster is not None:
                cluster.worker_evicted(w.idx, victim)
        if cluster is not None:
            cluster.admit(key, nbytes, w.idx, now=t)
            cluster.touch(key, t)

    res = FleetResult(method=method, n_invocations=0, n_cold=0, n_warm=0,
                      total_latency_s=0.0, memory_bytes=0,
                      n_workers=fleet.n_workers)

    def resident_key(fn: int) -> str:
        """What must be resident in a worker pool to cold-start ``fn`` fast."""
        return (f"img:{fn_image[fn]}" if method == "warmswap"
                else f"snap:{fn}")

    def fleet_bytes() -> int:
        total = 0
        for w in workers:
            total += w.ledger.used_bytes()
            if method == "warmswap":
                total += len(w.metadata_fns) * cost.metadata_bytes
        return total

    def note_peak() -> None:
        res.memory_bytes = max(res.memory_bytes, fleet_bytes())

    # ---------------------------------------------------------------- setup phase
    # Provider pre-builds residents on home workers (paper Fig. 4b): WarmSwap
    # builds each live image once; Prebaking snapshots every function upfront
    # (the paper keeps prebaked snapshots in RAM, §4.5). Baseline holds nothing.
    _seed_home_residents(method, workers, fn_image, images,
                         lambda w, key: admit_resident(w, key, 0.0))
    note_peak()

    # ------------------------------------------------------------- arrival stream
    # Vectorized merge of the per-function arrival arrays; arrivals never enter
    # the event heap — the main loop merges this stream against the heap head.
    # A TraceStream skips this materialization entirely: the loop below pulls
    # one chunk at a time (each chunk is already merged in this same order),
    # so peak arrival residency is one chunk, not the trace.
    if is_stream:
        all_t = np.empty((0,))
        all_fn = np.empty((0,), np.int64)
        n_req = 0
        # finalized to the true last arrival when the stream is exhausted.
        # Unfinalized reads are safe: the clamps below (`min(..., horizon)`,
        # `t > horizon`) can only bind at times past the last arrival, and any
        # event firing while chunks remain is <= the next arrival <= horizon.
        horizon = float("inf")
    else:
        all_t = np.concatenate([t.arrivals_min for t in traces]) if traces \
            else np.empty((0,))
        all_fn = np.concatenate(
            [np.full(len(t.arrivals_min), t.fn_index, np.int64)
             for t in traces]) if traces else np.empty((0,), np.int64)
        order = np.argsort(all_t, kind="stable")
        all_t, all_fn = all_t[order], all_fn[order]
        n_req = len(all_t)
        horizon = float(all_t[-1]) if n_req else 0.0
    # preallocated per-request buffers, filled in place by begin_service; an
    # unfilled (NaN) slot after the loop drains is an engine bug and raises.
    # Streamed runs grow them geometrically as chunks arrive (a request's
    # buffer slot exists before its arrival is processed, so queued requests
    # from earlier chunks always land inside the current capacity).
    samples = np.full(n_req, np.nan)
    waits = np.full(n_req, np.nan)
    events = EventQueue()
    push = events.push
    # Disruption events enter the heap up front at ranks > every fair-weather
    # kind (events.py): at equal timestamps a failure strikes only after the
    # arrivals/completions of that instant resolve.
    if disruption is not None:
        _KIND_INT = {"worker_fail": _FAIL, "worker_recover": _RECOVER,
                     "cache_flush": _FLUSH}
        for dev in disruption.events:
            push(dev.t_min, _KIND_INT[dev.kind], dev.worker)
    arrival_seq = 0                   # round-robin rotates per ARRIVAL; queued
                                       #   requests must not stall the rotation
    # hot-loop counters (folded into ``res`` after the loop): locals are
    # cheaper than dataclass attribute updates at millions of requests
    n_cold_c = n_warm_c = 0
    pw_hits = pp_hits = 0              # placement warm / pool-residency hits
    max_conc = 1
    warm_s = cost.warm_s
    # the base "none" policy has no arrival/completion state worth feeding and
    # a constant keep-alive window — skip its callbacks entirely (subclasses,
    # even ones that override nothing, take the full path)
    trivial_policy = type(policy) is PrewarmPolicy
    fixed_ka = policy.keep_alive_min(0, image_bytes=idle_bytes)

    def tier_of(w: _Worker, key: str) -> str:
        """Where ``key``'s pages would come from for a cold start on ``w``
        (page model): this worker's pool, a peer via the shared tier, or the
        source store. Pure read — no hit/miss counters move. The worker
        ledger is consulted first: an image the bounded shared tier rejected
        (oversized) can still be resident locally."""
        if w.ledger.holds(key):
            return "local"
        return cluster.classify(key, w.idx)

    def start_cost_s(w: _Worker, key: str) -> float:
        """Placement's bandwidth-aware estimate: blocking transfer seconds a
        cold start of this image would pay on ``w`` (the scalar base is the
        same everywhere, so only the transfer term ranks workers)."""
        return page.transfer_blocking_s(tier_of(w, key),
                                        image_bytes=resident_bytes_of(key))

    # One PlacementContext per decision *kind*, built once and mutated in
    # place per arrival (fn / t_min / arrival_seq are plain attribute writes);
    # the signal closures read the current decision through ``cur``. Under the
    # page model the residency signal is the bandwidth/residency-aware
    # transfer-cost estimate (local beats remote beats source-miss); otherwise
    # it is boolean pool residency. Strategies ignore what they don't rank by.
    cur = [0, 0.0, ""]                     # fn, t (minutes), resident key
    warm_cache: Dict[int, _Instance] = {}  # worker idx -> idle inst found by
                                           #   the has_warm scan this decision

    def _load_signal(w: _Worker) -> int:
        return w.in_flight

    def _queue_signal(w: _Worker) -> int:
        return w.queued_now

    def _has_warm_signal(w: _Worker) -> bool:
        inst = w.idle_instance(cur[0], cur[1])
        if inst is None:
            return False
        warm_cache[w.idx] = inst
        return True

    def _residency_signals() -> Dict:
        if page is not None and method != "baseline":
            return {"start_cost": lambda w: start_cost_s(w, cur[2])}
        return {"holds_image": lambda w: w.ledger.holds(cur[2])}

    ctx = PlacementContext(load=_load_signal, queue_depth=_queue_signal,
                           has_warm=_has_warm_signal, **_residency_signals())
    single_worker = len(workers) == 1

    def pick_worker(fn: int, t: float) -> Tuple[_Worker, str,
                                                Optional[_Instance]]:
        """The placement decision for one arrival: the chosen worker, the
        resident key its cold start would need, and its idle warm instance
        (``None`` when a cold start / queue wait is due). With one worker
        every strategy must return it, so the strategy call is skipped."""
        nonlocal pw_hits, pp_hits
        key = resident_key(fn)
        if single_worker:
            w = workers[0]
            inst = w.idle_instance(fn, t)
        else:
            cur[0], cur[1], cur[2] = fn, t, key
            warm_cache.clear()
            ctx.fn, ctx.t_min, ctx.arrival_seq = fn, t, arrival_seq
            w = strategy(live, ctx)
            inst = warm_cache.get(w.idx)
            if inst is None:               # strategy may ignore the warm scan
                inst = w.idle_instance(fn, t)
        if inst is not None:
            pw_hits += 1
        elif w.ledger.holds(key):
            pp_hits += 1
        return w, key, inst

    def cold_start(w: _Worker, fn: int, key: str, t: float) -> float:
        """Admit what the cold start needs into the worker pool (and, under
        the page model, the cluster-shared tier); return its latency in
        seconds. ``key`` is the resident key ``pick_worker`` already derived."""
        if page is not None:
            lat = cold_start_paged(w, fn, key, t)
        else:
            lat = cold_base
            if method == "warmswap":
                if not w.ledger.holds(key):
                    lat += cost.image_revive_s    # disk-tier revive / rebuild
                    res.pool_misses += 1
                w.ledger.admit(key, cost.image_bytes, now=t)
                if fn not in w.metadata_fns:
                    w.metadata_fns.add(fn)
            elif method == "prebaking":
                if not w.ledger.holds(key):
                    # snapshot was evicted: fall back to a from-scratch start
                    # and re-snapshot the result
                    lat = method_cold_latency_s(cost, "baseline")
                    res.pool_misses += 1
                w.ledger.admit(key, cost.snapshot_bytes, now=t)
        w.ledger.touch(key, t)
        if cluster is not None:
            cluster.touch(key, t)
        note_peak()
        return lat

    def cold_start_paged(w: _Worker, fn: int, key: str, t: float) -> float:
        """Page-granular cold start: latency = scalar base + blocking page
        transfer from wherever the image's pages are (worker pool / peer via
        the cluster-shared cache / source store). The fetched image becomes
        resident on ``w`` and in the shared tier, so the cluster pays each
        source fetch once. Network page volume (remote + source tiers) is
        accounted in ``pages_transferred``."""
        if method == "baseline":
            # nothing is ever cached: the full payload streams from source
            res.pages_transferred += page.image_pages()
            return page.cold_latency_s("baseline")
        # classify via the worker ledger first: an image the bounded shared
        # tier rejected (oversized) can still be resident locally
        tier = tier_of(w, key)
        cluster.count(tier)
        if tier == "local":
            res.cache_local_hits += 1
        elif tier == "remote":
            res.cache_remote_hits += 1
            res.pool_misses += 1
        else:
            res.cache_misses += 1
            res.pool_misses += 1
        if method == "warmswap":
            lat = page.cold_latency_s("warmswap", tier=tier)
            if tier != "local":
                res.pages_transferred += page.image_pages()
        else:                          # prebaking
            if tier == "miss":
                # no pool anywhere holds this function's snapshot: rebuild
                # from scratch (priced as a baseline start) and re-snapshot
                lat = page.cold_latency_s("baseline")
                res.pages_transferred += page.image_pages()
            else:
                lat = page.cold_latency_s(
                    "prebaking", tier=tier, image_bytes=cost.snapshot_bytes)
                if tier != "local":
                    res.pages_transferred += page.n_pages(cost.snapshot_bytes)
        admit_resident(w, key, t)
        if method == "warmswap" and fn not in w.metadata_fns:
            w.metadata_fns.add(fn)
        return lat

    # streamed runs rebind samples/waits (geometric growth) and horizon (set
    # once the last chunk lands); the closures below MUST see the rebound
    # values — that is the growth/finalization design, not a stale capture.
    # repro-lint: allow[stale-capture]
    def begin_service(w: _Worker, inst: _Instance, start: float, svc_s: float,
                      req_t: float, idx: int) -> None:
        """Run one request on ``inst`` starting at ``start`` (>= its previous
        ``busy_until`` by construction, so busy_until only ever advances).
        Per-request totals (latency sums, queue counts, per-function
        breakdowns) are NOT accumulated here — they are vectorized over the
        preallocated ``samples``/``waits`` buffers after the loop drains."""
        wait_s = (start - req_t) * 60.0
        busy_until = start + svc_s / 60.0
        if san is not None:
            san.check_service(start=start, req_t=req_t,
                              prev_busy=inst.busy_until,
                              busy_until=busy_until, worker=w.idx,
                              fn=inst.fn)
        inst.busy_until = busy_until
        expires = busy_until + (fixed_ka if trivial_policy
                                else policy.keep_alive_min(
                                    inst.fn, image_bytes=idle_bytes))
        inst.expires = expires
        inst.gen += 1
        inst.cur_idx = idx
        inst.cur_req_t = req_t
        push(busy_until, _FREE, (w, inst))
        push(expires, _EXPIRY, (w, inst, inst.gen))
        w.n_served += 1
        w.in_flight += 1
        samples[idx] = wait_s + svc_s
        waits[idx] = wait_s

    # repro-lint: allow[stale-capture]
    def retire(w: _Worker, inst: _Instance) -> None:
        """Keep-alive expired: remove the instance, account its residency
        clamped to the trace horizon."""
        insts = w.instances.get(inst.fn)
        if insts is not None and inst in insts:
            insts.remove(inst)
        w.instance_min += max(0.0, min(inst.expires, horizon) - inst.created)

    # repro-lint: allow[stale-capture]
    def spawn_prewarm(t: float, fn: int, expire_at: float) -> None:
        if t > horizon:
            # scheduled past the last arrival: drained, accounted, not spawned
            res.prewarm_dropped += 1
            return
        for w in workers:
            if w.alive(fn):
                return                 # something is already warm; don't double-spawn
        if not live:
            # every worker is down: account the spawn as dropped, like a
            # past-horizon spawn, rather than silently losing it
            res.prewarm_dropped += 1
            return
        # pre-warm spawns always use affinity-shaped placement (no instance
        # is warm yet, so only the residency/transfer signal discriminates);
        # spawns are rare, so this context is built fresh rather than shared
        cur[2] = key = resident_key(fn)
        w = place_invocation(live, PlacementContext(
            load=_load_signal, queue_depth=_queue_signal,
            fn=fn, t_min=t, arrival_seq=arrival_seq, **_residency_signals()))
        if method != "baseline":
            admit_resident(w, key, t)
            if method == "warmswap":
                w.metadata_fns.add(fn)
            note_peak()
        inst = _Instance(fn, busy_until=t, expires=expire_at, created=t,
                         prewarmed=True)
        w.instances.setdefault(fn, []).append(inst)
        events.push(expire_at, EventKind.KEEPALIVE_EXPIRY, (w, inst, inst.gen))
        res.prewarm_spawns += 1

    def handle_arrival(t: float, fn: int, idx: int) -> None:
        nonlocal arrival_seq, n_cold_c, n_warm_c, max_conc
        if not trivial_policy:
            policy.on_arrival(fn, t)
        if not live:
            # every worker is down: park the request; the next
            # worker_recover event re-dispatches it (wait accrues from t)
            orphans.append((t, idx, fn))
            arrival_seq += 1
            return
        w, key, inst = pick_worker(fn, t)
        arrival_seq += 1
        if inst is not None:
            n_warm_c += 1
            if inst.prewarmed:
                res.prewarm_hits += 1
                inst.prewarmed = False
            begin_service(w, inst, t, warm_s, t, idx)
        else:
            alive = w.instances.get(fn)
            if alive and cap is not None and len(alive) >= cap:
                # at the instance cap: join this worker's FIFO queue; the next
                # instance-free event dispatches it (latency = wait + warm cost)
                w.queues.setdefault(fn, deque()).append((t, idx))
                w.queued_now += 1
            else:
                svc = cold_start(w, fn, key, t)
                n_cold_c += 1
                inst = _Instance(fn, busy_until=t, expires=t, created=t)
                if alive is None:
                    w.instances[fn] = [inst]
                else:
                    alive.append(inst)
                n_alive = sum(len(ww.alive(fn)) for ww in workers)
                if n_alive > max_conc:
                    max_conc = n_alive
                begin_service(w, inst, t, svc, t, idx)
        if not trivial_policy:
            window = policy.prewarm_after(fn, t)
            if window is not None:
                push(window[0], _SPAWN, (fn, window[1]))

    def redispatch(t: float, req_t: float, fn: int, idx: int) -> None:
        """Re-submit a request displaced by a worker failure at time ``t``,
        keeping its ORIGINAL arrival time ``req_t`` so the time lost to the
        failure lands in its queue wait (``begin_service`` overwrites the
        request's sample slot). Mirrors ``handle_arrival``'s dispatch, but a
        re-dispatch is not an arrival: the policy sees no new arrival and
        the round-robin rotation does not advance."""
        nonlocal n_cold_c, n_warm_c, max_conc
        if not live:
            orphans.append((req_t, idx, fn))
            return
        w, key, inst = pick_worker(fn, t)
        if inst is not None:
            n_warm_c += 1
            if inst.prewarmed:
                res.prewarm_hits += 1
                inst.prewarmed = False
            begin_service(w, inst, t, warm_s, req_t, idx)
            return
        alive = w.instances.get(fn)
        if alive and cap is not None and len(alive) >= cap:
            w.queues.setdefault(fn, deque()).append((req_t, idx))
            w.queued_now += 1
            return
        svc = cold_start(w, fn, key, t)
        n_cold_c += 1
        inst = _Instance(fn, busy_until=t, expires=t, created=t)
        if alive is None:
            w.instances[fn] = [inst]
        else:
            alive.append(inst)
        n_alive = sum(len(ww.alive(fn)) for ww in workers)
        if n_alive > max_conc:
            max_conc = n_alive
        begin_service(w, inst, t, svc, req_t, idx)

    # repro-lint: allow[stale-capture]
    def fail_worker(t: float, w_idx: int) -> None:
        nonlocal live
        w = workers[w_idx]
        if w.failed:
            return
        w.failed = True
        live = [ww for ww in workers if not ww.failed]
        res.worker_failures += 1
        # Displaced requests: the worker's in-flight requests plus its queue,
        # re-dispatched in (original arrival time, request index) order — a
        # deterministic total order, since request indices are unique.
        pending: List[Tuple[float, int, int]] = []
        for insts in w.instances.values():
            for inst in insts:
                inst.killed = True     # pending free/expiry events are stale
                w.instance_min += max(0.0, min(t, horizon) - inst.created)
                if inst.busy_until > t and inst.cur_idx >= 0:
                    pending.append((inst.cur_req_t, inst.cur_idx, inst.fn))
        for fn, q in w.queues.items():
            for req_t, idx in q:
                pending.append((req_t, idx, fn))
        w.instances.clear()
        w.queues.clear()
        w.in_flight = 0
        w.queued_now = 0
        # the pool dies with the worker (propagated to the cluster tier — the
        # shared tier is the union of worker pools); a recovered worker
        # re-warms through the normal cold-start path
        for key in list(w.ledger.entries):
            w.ledger.evict(key)
            if cluster is not None:
                cluster.worker_evicted(w.idx, key)
        w.metadata_fns.clear()
        pending.sort()
        res.requeued += len(pending)
        for req_t, idx, fn in pending:
            redispatch(t, req_t, fn, idx)

    def recover_worker(t: float, w_idx: int) -> None:
        nonlocal live
        w = workers[w_idx]
        if not w.failed:
            return
        w.failed = False
        live = [ww for ww in workers if not ww.failed]
        res.worker_recoveries += 1
        if orphans:
            drain = sorted(orphans)
            orphans.clear()
            for req_t, idx, fn in drain:
                redispatch(t, req_t, fn, idx)

    def flush_caches(t: float) -> None:
        """Shared-image eviction storm: every pool resident leaves every
        worker (and, via the holder sets, the cluster tier). Warm instances
        keep running — a cache eviction does not kill containers — so only
        subsequent cold starts feel it (revive / remote / source miss)."""
        res.cache_flushes += 1
        for w in workers:
            for key in list(w.ledger.entries):
                w.ledger.evict(key)
                if cluster is not None:
                    cluster.worker_evicted(w.idx, key)

    def handle_event(ev_t: float, kind: int, payload) -> None:
        nonlocal n_warm_c
        if kind == _FREE:
            w, inst = payload
            if inst.killed:
                return                 # the worker died mid-service
            w.in_flight -= 1
            if not trivial_policy:
                policy.on_completion(inst.fn, ev_t)
            q = w.queues.get(inst.fn)
            if q:
                req_t, idx = q.popleft()
                w.queued_now -= 1
                n_warm_c += 1
                begin_service(w, inst, ev_t, warm_s, req_t, idx)
        elif kind == _SPAWN:
            fn, expire_at = payload
            spawn_prewarm(ev_t, fn, expire_at)
        elif kind == _EXPIRY:
            w, inst, gen = payload
            if inst.gen == gen and not inst.killed:
                retire(w, inst)        # else: superseded or worker died
        elif kind == _FAIL:
            fail_worker(ev_t, payload)
        elif kind == _RECOVER:
            recover_worker(ev_t, payload)
        else:                          # CACHE_FLUSH
            flush_caches(ev_t)

    # ---------------------------------------------------------------- event loop
    # Merge the pre-sorted arrival stream against the event-heap head. The
    # arrival arrays are materialized as plain Python lists once — float/int
    # extraction per numpy element is several times slower at millions of
    # requests — and the heap head is compared field-wise (no tuple builds).
    # Chunked runs feed the same loop one chunk at a time: the next chunk is
    # fetched BEFORE any heap event later than the current chunk fires, so
    # the event/arrival interleaving is identical to the materialized run.
    all_t_list = all_t.tolist()
    all_fn_list = all_fn.tolist()
    heap = events.heap
    pop = events.pop_raw
    i = 0
    base = 0                      # global index of the current chunk's start
    n_cur = n_req
    fn_parts: List[np.ndarray] = []
    chunk_iter = traces.chunks() if is_stream else None
    draining = chunk_iter is None  # True once no further arrivals can appear
    last_t = 0.0
    while True:
        if i >= n_cur and not draining:
            chunk = next(chunk_iter, None)
            if chunk is None:
                draining = True
                n_req = base + n_cur
                # the stream is exhausted: the horizon (last arrival) is now
                # known, exactly as the materialized path computed it upfront
                horizon = last_t if n_req else 0.0
            else:
                base += n_cur
                all_t_list = chunk.t_min.tolist()
                all_fn_list = chunk.fn.tolist()
                n_cur = len(all_t_list)
                i = 0
                last_t = all_t_list[-1]
                fn_parts.append(chunk.fn)
                need = base + n_cur
                if need > len(samples):
                    grown = np.full(max(need, 2 * len(samples)), np.nan)
                    grown[:len(samples)] = samples
                    samples = grown
                    grown = np.full(len(samples), np.nan)
                    grown[:len(waits)] = waits
                    waits = grown
            continue
        if heap:
            head = heap[0]
            if (i >= n_cur or head[0] < all_t_list[i]
                    or (head[0] == all_t_list[i] and head[1] <= _ARRIVAL)):
                ev = pop()
                if san is not None and san.check_event(ev[0], ev[1], ev[2]):
                    san.check_books(workers, cluster)
                handle_event(ev[0], ev[1], ev[3])
                continue
        elif i >= n_cur:
            break
        handle_arrival(all_t_list[i], all_fn_list[i], base + i)
        i += 1
    if is_stream:
        samples = samples[:n_req]
        waits = waits[:n_req]
        all_fn = (np.concatenate(fn_parts) if fn_parts
                  else np.empty((0,), np.int64))
    res.horizon_min = horizon

    if orphans:
        raise RuntimeError(
            f"{len(orphans)} request(s) were still orphaned when the event "
            f"loop drained: the disruption schedule leaves every worker "
            f"failed with no recovery before the end of the trace")
    if n_req and np.isnan(samples).any():
        raise RuntimeError("fleet engine dropped requests: unfilled latency "
                           "samples after the event loop drained")
    res.latency_samples_s = samples
    res.queue_wait_s = waits
    res.sample_fn = all_fn
    # ------------------------------------------------- vectorized projections
    # Totals, queue stats, and per-function breakdowns from the sample
    # buffers in a few numpy passes instead of per-request accumulation.
    res.n_invocations = n_req
    res.n_cold = n_cold_c
    res.n_warm = n_warm_c
    res.total_latency_s = float(samples.sum())
    res.n_queued = int((waits > 0).sum())
    res.queue_delay_s = float(waits.sum())
    res.placement_warm_hits = pw_hits
    res.placement_pool_hits = pp_hits
    res.max_concurrent_instances = max_conc
    fns = np.array(sorted({t.fn_index for t in trace_meta}), np.int64)
    slots = np.searchsorted(fns, all_fn)
    lat_sums = np.bincount(slots, weights=samples, minlength=len(fns)) \
        if n_req else np.zeros(len(fns))
    inv_counts = np.bincount(slots, minlength=len(fns)) \
        if n_req else np.zeros(len(fns), np.int64)
    res.per_fn_latency = {int(f): float(s) for f, s in zip(fns, lat_sums)}
    res.per_fn_invocations = {int(f): int(c) for f, c in zip(fns, inv_counts)}
    res.evictions = sum(w.ledger.evictions for w in workers)
    res.instance_resident_min = sum(w.instance_min for w in workers)
    if cluster is not None:
        res.shared_cache_peak_bytes = cluster.peak_bytes
        res.shared_cache_evictions = cluster.evictions
    res.per_worker = [{
        "worker": w.idx,
        "served": w.n_served,
        "pool_bytes": w.ledger.used_bytes(),
        "resident": sorted(w.ledger.entries.keys()),
        "metadata_fns": len(w.metadata_fns),
        "evictions": w.ledger.evictions,
        "instance_min": w.instance_min,
    } for w in workers]
    if san is not None:
        san.check_samples(samples, waits)
        san.check_books(workers, cluster)
        san.check_counters(res)
    return res
