import importlib.util
import os
import sys

# Smoke tests and benches must see the single real device; ONLY the dry-run launcher
# forces 512 host devices (and it does so in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Property tests use hypothesis when available; otherwise fall back to the
# deterministic seeded-fuzz shim so those modules still collect and run
# (see tests/_hypothesis_fallback.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
