#!/usr/bin/env python
"""Page-model band asserts over the fleet bench artifact: HotSwap latency
strictly between warm and cold at every image size, dependency-loading
speedup inside the paper's 2.2-3.2x band, and the shared-tier cache
footprint saving in (0, 1). Runs locally and in CI's smoke job.

    python tools/ci/check_page_model.py [results/bench_fleet.json]
"""
import json
import math
import sys


def main(path="results/bench_fleet.json"):
    page = json.load(open(path))["page_model"]
    sizes = page["latency_vs_image_size"]
    assert sizes, "latency_vs_image_size cell is empty"
    for label, cell in sizes.items():
        vals = [cell["warm_s"], cell["hotswap_s"], cell["cold_s"],
                cell["dependency_loading_speedup"]]
        assert all(math.isfinite(v) for v in vals), f"NaN in {label}"
        assert cell["warm_s"] < cell["hotswap_s"] < cell["cold_s"], \
            f"HotSwap latency not strictly between warm and cold: {label}"
    sp = page["dependency_loading_speedup_paper_scale"]
    assert 2.2 <= sp <= 3.2, f"dep-loading speedup {sp} outside 2.2-3.2x"
    fp = page["cache_footprint"]
    assert math.isfinite(fp["saving_fraction"])
    assert 0.0 < fp["saving_fraction"] < 1.0
    assert fp["hotswap_shared_peak_mb"] < fp["prebaking_shared_peak_mb"]
    print(f"ok: {len(sizes)} image sizes, dep speedup {sp:.2f}x, "
          f"cache-footprint saving {fp['saving_fraction']:.1%}")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
