"""Append-only JSONL results store for sweep runs (the executor's backend).

One store file holds one sweep's results, one JSON line per completed grid
point, keyed by a **content hash of the fully resolved scenario spec** (post
overrides, post smoke scaling, post seed derivation) — so a store never
confuses results produced by different specs, an interrupted sweep resumes by
skipping keys already present, and a serial and a parallel run of the same
grid write byte-identical files (the executor appends in grid order).

File layout (``store_schema_version: 1``)::

    {"store_schema_version": 1, "result_schema_version": 1}      <- header
    {"key": "<sha256>", "name": "...", "result": {...}}          <- records
    ...

Durability contract:

  * every record line is flushed + fsynced before the executor counts the
    point as done, so a killed sweep loses at most the line being written;
  * a torn (partially written) **final** line — the signature of a kill mid
    append — is detected and dropped on load, then truncated away by the
    next append, so resume just recomputes that one point;
  * a corrupt line anywhere **else** means the file was edited or the disk
    misbehaved: that is never silently skipped (:class:`CorruptStoreError`);
  * headers written by a different store schema, or records carrying a
    result schema newer than this build, fail with
    :class:`StoreSchemaError` instead of being misread.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, Iterator, List, Mapping, Optional

from repro.core.scenario import RESULT_SCHEMA_VERSION
from repro.core.trace_stream import NON_SEMANTIC_TRACE_KWARGS

#: Version of the store file layout this build reads and writes.
STORE_SCHEMA_VERSION = 1


class StoreError(ValueError):
    """Base class for results-store failures."""


class StoreSchemaError(StoreError):
    """The store was written by an incompatible store/result schema."""


class CorruptStoreError(StoreError):
    """A non-final line failed to parse — the store was damaged, not torn."""


def canonical_json(obj: Any) -> str:
    """Deterministic JSON encoding (sorted keys, no whitespace) — the
    hashing and storage form, so one spec always produces one byte string."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def normalize_spec(spec: Mapping[str, Any]) -> Dict[str, Any]:
    """Deep copy of ``spec`` with non-semantic trace kwargs dropped
    (``traces.kwargs.stream`` / ``chunk_min`` — see
    :data:`repro.core.trace_stream.NON_SEMANTIC_TRACE_KWARGS`). Streamed and
    in-memory execution of one spec are bit-identical by contract, so they
    must share a store key and a derived seed."""
    d = json.loads(canonical_json(spec))
    kwargs = d.get("traces", {}).get("kwargs", {})
    for k in NON_SEMANTIC_TRACE_KWARGS:
        kwargs.pop(k, None)
    return d


def spec_key(spec: Mapping[str, Any]) -> str:
    """Content hash (sha256 hex) of a resolved scenario spec dict.

    This is the store key: two grid points collide iff their fully resolved
    specs are identical *up to non-semantic trace kwargs*
    (:func:`normalize_spec`), in which case their results are identical too
    (the engines are deterministic functions of the spec, and the streaming
    contract makes ``stream``/``chunk_min`` invisible in the results)."""
    return hashlib.sha256(
        canonical_json(normalize_spec(spec)).encode()).hexdigest()


class ResultStore:
    """Append-only JSONL store of ``{key, name, result}`` records.

    ``path`` need not exist yet; the header is written with the first
    :meth:`append`. Reading (:meth:`records`, :meth:`completed_keys`)
    validates the header and every line per the module-docstring contract.
    """

    def __init__(self, path: str):
        self.path = path
        #: True when the last load found (and dropped) a torn final line.
        self.torn_tail = False
        self._valid_bytes: Optional[int] = None   # file prefix known good

    # ------------------------------------------------------------------ read
    def exists(self) -> bool:
        return os.path.exists(self.path)

    def _iter_lines(self) -> Iterator[Dict[str, Any]]:
        """Parsed records, header validated, torn tail dropped.

        A record is committed only once its terminating newline is on disk
        (the writer appends ``line + "\\n"`` atomically-enough and fsyncs), so
        *any* content after the file's last newline is a torn append — even
        content that happens to parse — and is dropped; the next
        :meth:`append` truncates it away. A line that fails to parse anywhere
        **before** the last newline is real damage and raises."""
        self.torn_tail = False
        self._valid_bytes = 0
        if not self.exists():
            return
        with open(self.path, "rb") as f:
            raw = f.read()
        if not raw.strip():
            return
        lines = raw.split(b"\n")
        if lines[-1].strip():
            self.torn_tail = True
        committed, torn = lines[:-1], lines[-1]
        offset = 0
        parsed_any = False
        for li, line in enumerate(committed):
            end = offset + len(line) + 1          # +1 for the newline
            if not line.strip():
                offset = end
                self._valid_bytes = end
                continue
            try:
                obj = json.loads(line)
                if not isinstance(obj, dict):
                    raise ValueError("line is not a JSON object")
            except ValueError as e:
                raise CorruptStoreError(
                    f"{self.path}: corrupt line {li + 1} (before the last "
                    f"newline, so not a torn append — refusing to skip): "
                    f"{e}") from e
            if not parsed_any:
                parsed_any = True
                self._check_header(obj, li + 1)
                self._valid_bytes = end
                offset = end
                continue
            if "key" not in obj or "result" not in obj:
                raise CorruptStoreError(
                    f"{self.path}: line {li + 1} is missing 'key'/'result'")
            self._valid_bytes = end
            offset = end
            yield obj

    def _check_header(self, obj: Mapping[str, Any], lineno: int) -> None:
        if "store_schema_version" not in obj:
            raise StoreSchemaError(
                f"{self.path}: line {lineno} is not a store header "
                f"(expected store_schema_version) — not a results store?")
        sv = obj["store_schema_version"]
        if sv != STORE_SCHEMA_VERSION:
            raise StoreSchemaError(
                f"{self.path}: store_schema_version {sv!r} != "
                f"{STORE_SCHEMA_VERSION} — refusing to mix store layouts")
        rv = obj.get("result_schema_version", RESULT_SCHEMA_VERSION)
        if not isinstance(rv, int) or rv > RESULT_SCHEMA_VERSION:
            raise StoreSchemaError(
                f"{self.path}: result_schema_version {rv!r} is newer than "
                f"this build supports (<= {RESULT_SCHEMA_VERSION})")

    def records(self) -> List[Dict[str, Any]]:
        """All good records, in file order (torn tail dropped; corrupt
        interior lines / schema mismatches raise)."""
        return list(self._iter_lines())

    def completed_keys(self) -> Dict[str, Dict[str, Any]]:
        """``key -> record`` for every stored point (last write wins)."""
        return {r["key"]: r for r in self._iter_lines()}

    # ----------------------------------------------------------------- write
    def append(self, key: str, result: Mapping[str, Any],
               name: str = "") -> None:
        """Append one record durably (flush + fsync before returning).

        The first append writes the header; any torn tail left by a previous
        kill is truncated away first, so the file stays one-line-per-record.
        """
        if self._valid_bytes is None:
            # establish the good prefix (validates header/schema as a side
            # effect; raises rather than appending to an incompatible file)
            for _ in self._iter_lines():
                pass
        new_file = self._valid_bytes == 0
        mode = "r+b" if (self.exists() and not new_file) else "wb"
        with open(self.path, mode) as f:
            if mode == "r+b":
                f.truncate(self._valid_bytes)
                f.seek(self._valid_bytes)
            if new_file:
                header = canonical_json({
                    "store_schema_version": STORE_SCHEMA_VERSION,
                    "result_schema_version": RESULT_SCHEMA_VERSION,
                })
                f.write(header.encode() + b"\n")
            record = canonical_json({"key": key, "name": name,
                                     "result": dict(result)})
            f.write(record.encode() + b"\n")
            f.flush()
            os.fsync(f.fileno())
            self._valid_bytes = f.tell()
        self.torn_tail = False
