"""Pallas TPU flash attention: blockwise online-softmax with VMEM tiling.

Grid layout ``(B, H, n_q_blocks, n_kv_blocks)``; the kv-block axis is the innermost,
sequential ('arbitrary') dimension, carrying the running max / denominator / output
accumulator in VMEM scratch — the standard TPU flash schedule. Supports:

  * causal and non-causal attention,
  * sliding windows (gemma2 local layers, danube3 SWA, recurrentgemma local),
  * attention-logit softcapping (gemma2),
  * GQA via the kv-head index map (no KV replication in memory).

Block sizes default to (128, 128): MXU-aligned on the contraction dims, and the
working set (q/k/v blocks in bf16 + fp32 scratch: 3·128·d·2B + 2·128·128·4B ≈ 0.3 MB
for d = 128) fits far inside the ~16 MB/core VMEM budget, leaving room for
double-buffered block prefetch.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -2.0e38
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    m_scratch, l_scratch, acc_scratch,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    block_q: int,
    block_k: int,
    seq_k: int,                     # true (unpadded) kv length
    n_kv_blocks: int,
):
    i = pl.program_id(2)            # q block index
    j = pl.program_id(3)            # kv block index (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)             # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (bq, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_idx < seq_k
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= (q_idx - k_idx) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]                          # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scratch[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scratch[...] /
                       jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,            # (B, H, Sq, d)
    k: jax.Array,            # (B, Hkv, Sk, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    block_q = max(8, min(block_q, Sq))
    block_k = max(8, min(block_k, Sk))
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Sk_p = q.shape[2], k.shape[2]
    n_q, n_kv = Sq_p // block_q, Sk_p // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, seq_k=Sk, n_kv_blocks=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denominator
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
