"""Kernel micro-benchmarks (interpret-mode correctness timing is meaningless on CPU,
so this reports the jnp-path wall time of the same contracts — the numbers that
matter for CPU CI — plus the kernels' VMEM working-set accounting used to pick
BlockSpecs for the TPU target)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run() -> Dict:
    from repro.models.attention import blockwise_attention
    from repro.models.recurrence import chunked_diag_recurrence
    key = jax.random.PRNGKey(0)
    out = {}

    # attention jnp path (the kernels' oracle) at serving-ish sizes
    B, S, H, Hkv, d = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, d), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, d), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, d), jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    fn = jax.jit(lambda q, k, v: blockwise_attention(
        q, k, v, q_positions=pos, k_positions=pos, causal=True, window=None,
        attn_softcap=None, q_chunk=256))
    dt = _time(fn, q, k, v)
    flops = 4 * B * H * S * S * d
    out["attention_prefill_1k"] = {"s": dt, "gflops_s": flops / dt / 1e9}
    emit("kernel/attention_prefill_1k", dt * 1e6, f"{flops/dt/1e9:.1f} GFLOP/s")

    # diagonal recurrence at mamba-ish size
    Bm, Sm, C = 1, 2048, 4096
    a = jax.random.uniform(key, (Bm, Sm, C), jnp.float32, 0.5, 1.0)
    b = jax.random.normal(key, (Bm, Sm, C), jnp.float32)
    h0 = jnp.zeros((Bm, C))
    fn2 = jax.jit(lambda a, b, h0: chunked_diag_recurrence(a, b, h0, chunk=256))
    dt2 = _time(fn2, a, b, h0)
    bytes_moved = 3 * Bm * Sm * C * 4
    out["diag_recurrence_2k"] = {"s": dt2, "gb_s": bytes_moved / dt2 / 1e9}
    emit("kernel/diag_recurrence_2k", dt2 * 1e6, f"{bytes_moved/dt2/1e9:.1f} GB/s")

    # VMEM working sets for the TPU BlockSpecs (static accounting)
    vmem = {
        "flash_attention(bq=bk=128,d=128)": (3 * 128 * 128 * 2 + 2 * 128 * 4 +
                                             128 * 128 * 4) / 1e6,
        "decode_attention(bk=512,g=8,d=128)": (2 * 512 * 128 * 2 + 8 * 128 * 4 +
                                               8 * 4 * 2) / 1e6,
        "diag_recurrence(chunk=128,bc=2048)": (3 * 128 * 2048 * 4 + 2048 * 4) / 1e6,
        "page_gather(page=4MiB)": 2 * 4.194,
    }
    for k2, mb in vmem.items():
        emit(f"vmem/{k2}", mb * 1e3, "KB working set (vs ~16MB VMEM)")
    out["vmem_working_set_mb"] = vmem
    save_json("bench_kernels", out)
    return out


if __name__ == "__main__":
    run()
