"""Float-determinism checker: order-sensitive reductions, at the AST.

The scalar and vectorized fleet engines must produce *bit-identical* float
arrays (docs/SIMULATION.md, "Vectorized engine"): sha256 over the
per-request latency/wait vectors is the differential-fuzz contract. Float
addition is not associative, so any reduction whose operand order is not
pinned can silently break it:

* ``unstable-sort`` — ``np.sort`` / ``np.argsort`` without
  ``kind="stable"``: numpy's default introsort is *unstable*, so equal keys
  (including ``-0.0`` vs ``0.0``) can land in either order and feed a
  different accumulation order downstream;
* ``set-reduction`` — ``sum`` / ``math.fsum`` / ``np.sum`` over a set (or a
  generator drawing from one): set iteration is hash-order, so the float
  accumulation order differs across processes;
* ``keyed-extremum-over-set`` — ``min`` / ``max`` with a ``key=`` over a
  set: ties resolve to whichever element hash-order yields first.

Scope: ``config.FLOAT_DETERMINISM_SCOPE`` (code shared by both engines).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from tools.analysis import config
from tools.analysis.base import SourceFile, dotted_name, qualname_index
from tools.analysis.findings import Finding

CHECKER = "float-determinism"

_STABLE_KINDS = {"stable", "mergesort"}
_NP_SORTS = {"sort", "argsort"}
_REDUCERS = {"sum", "fsum"}          # bare sum(), math.fsum / np.sum via tail
_EXTREMA = {"min", "max"}


def _numpy_aliases(tree: ast.Module) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "numpy":
                    out.add(a.asname or "numpy")
    return out


def check(src: SourceFile) -> List[Finding]:
    if not config.in_scope(src.rel, config.FLOAT_DETERMINISM_SCOPE):
        return []
    np_names = _numpy_aliases(src.tree)
    scopes = qualname_index(src.tree)
    findings: List[Finding] = []

    def emit(rule: str, node: ast.AST, message: str, suggestion: str) -> None:
        f = src.finding(CHECKER, rule, node, message,
                        scope=scopes.get(node, ""), suggestion=suggestion)
        if f is not None:
            findings.append(f)

    # statically-known set locals per scope (same approximation as the
    # determinism checker's set-iteration rule)
    set_vars: Dict[str, Set[str]] = {}

    def _is_set_expr(node: ast.AST, scope: str) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return (_is_set_expr(node.left, scope)
                    or _is_set_expr(node.right, scope))
        if isinstance(node, ast.Name):
            return node.id in set_vars.get(scope, set())
        return False

    def _draws_from_set(node: ast.AST, scope: str) -> bool:
        """The reduction operand itself, or any generator it iterates."""
        if _is_set_expr(node, scope):
            return True
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return any(_is_set_expr(g.iter, scope) for g in node.generators)
        return False

    for node in ast.walk(src.tree):
        scope = scopes.get(node, "")
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                _is_set_expr(node.value, scope):
            set_vars.setdefault(scope, set()).add(node.targets[0].id)

    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        scope = scopes.get(node, "")
        fname = dotted_name(node.func) or ""
        parts = fname.split(".")
        head, tail = parts[0], parts[-1]

        # ------------------------------------------------------ unstable-sort
        if len(parts) >= 2 and head in np_names and tail in _NP_SORTS:
            kind = next((kw.value for kw in node.keywords
                         if kw.arg == "kind"), None)
            stable = (isinstance(kind, ast.Constant)
                      and kind.value in _STABLE_KINDS)
            if not stable:
                emit("unstable-sort", node,
                     f"'{fname}' without kind=\"stable\" — numpy's default "
                     f"introsort reorders equal keys (incl. -0.0 vs 0.0), "
                     f"so downstream float accumulation order can differ "
                     f"between engines",
                     f'pass kind="stable" to {fname}(...)')

        # ------------------------------------------------------ set-reduction
        is_reducer = ((len(parts) == 1 and tail == "sum")
                      or (len(parts) >= 2 and tail in _REDUCERS))
        if is_reducer and node.args and \
                _draws_from_set(node.args[0], scope):
            emit("set-reduction", node,
                 f"'{fname}' accumulates over a set — iteration is "
                 f"hash-order, and float addition is not associative, so "
                 f"the result differs across processes",
                 "reduce over sorted(...) (or keep a list/dict, which "
                 "preserve insertion order)")

        # -------------------------------------------- keyed-extremum-over-set
        if len(parts) == 1 and tail in _EXTREMA and node.args and \
                any(kw.arg == "key" for kw in node.keywords) and \
                _draws_from_set(node.args[0], scope):
            emit("keyed-extremum-over-set", node,
                 f"'{tail}' with key= over a set — key ties resolve to "
                 f"whichever element hash-order yields first",
                 "iterate sorted(...) so ties break deterministically")

    return findings
