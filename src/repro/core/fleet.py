"""Multi-worker fleet simulation with concurrency (beyond paper Fig. 7).

``simulator.simulate()`` is the paper-faithful single-worker model: one instance
per function, an always-resident shared image, static memory accounting. This
module generalizes it into the regime the paper's fleet-level claims actually
live in:

  * **concurrency** — an arrival that finds every instance of its function busy
    spawns a *new* cold/warm instance instead of being serialized;
  * **N worker nodes** — each with its own Dependency-Manager pool, modeled by
    the same :class:`~repro.core.pool.CapacityLedger` the real manager uses
    (capacity + LRU + refcounts), so images get evicted and revived under
    memory pressure exactly like the live pool;
  * **placement** — invocations are routed by
    :func:`repro.serving.scheduler.place_invocation`: warm-instance affinity,
    then image-affinity (the pool already holds the live image), then
    least-loaded; round-robin and plain least-loaded are available as controls;
  * **pluggable pre-warm policies** (:mod:`repro.core.keepalive`) — fixed
    keep-alive (paper §4.5), histogram-adaptive keep-alive, and SPES-style
    predictive pre-warming, comparable under identical placement.

Degenerate case: ``n_workers=1``, unlimited capacity, ``max_instances_per_fn=1``
reproduces ``simulate()`` — including the ~88 % memory-saving headline at
sharing degree 10 (verified in tests/test_fleet.py).
"""
from __future__ import annotations

import copy
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from repro.core.keepalive import PREWARM_POLICIES, PrewarmPolicy
from repro.core.pool import CapacityLedger
from repro.core.simulator import CostModel, method_cold_latency_s
from repro.core.traces import Trace


@dataclass
class FleetConfig:
    n_workers: int = 1
    placement: str = "affinity"            # 'affinity' | 'least_loaded' | 'round_robin'
    max_instances_per_fn: Optional[int] = None   # None = unbounded concurrency;
                                                 # 1 = simulate()'s serialized model
    worker_capacity_bytes: Optional[int] = None  # per-worker pool capacity
    prewarm: Union[str, PrewarmPolicy] = "none"  # policy name or ready instance
    keep_alive_min: float = 15.0                 # window for the 'none' policy


@dataclass
class _Instance:
    fn: int
    busy_until: float        # minutes
    expires: float           # minutes (keep-alive expiry)
    created: float = 0.0
    prewarmed: bool = False


class _Worker:
    def __init__(self, idx: int, capacity_bytes: Optional[int]):
        self.idx = idx
        self.ledger = CapacityLedger(capacity_bytes)
        self.instances: Dict[int, List[_Instance]] = {}
        self.metadata_fns: set = set()
        self.n_served = 0
        self.instance_min = 0.0      # total warm-instance residency (minutes)

    def alive(self, fn: int, t: float) -> List[_Instance]:
        insts, kept = self.instances.get(fn, ()), []
        for i in insts:
            if i.expires >= t:
                kept.append(i)
            else:
                self.instance_min += i.expires - i.created
        self.instances[fn] = kept
        return kept

    def idle_instance(self, fn: int, t: float) -> Optional[_Instance]:
        avail = [i for i in self.alive(fn, t) if i.busy_until <= t]
        return min(avail, key=lambda i: i.busy_until) if avail else None

    def load(self, t: float) -> int:
        """In-flight requests on this worker (busy, unexpired instances)."""
        return sum(sum(1 for i in self.alive(fn, t) if i.busy_until > t)
                   for fn in list(self.instances))


@dataclass
class FleetResult:
    method: str
    n_invocations: int
    n_cold: int
    n_warm: int
    total_latency_s: float
    memory_bytes: int                    # PEAK fleet-wide resident bytes
    per_fn_latency: Dict[int, float] = field(default_factory=dict)
    per_fn_invocations: Dict[int, int] = field(default_factory=dict)
    n_workers: int = 1
    pool_misses: int = 0                 # cold starts that paid an image revive
    evictions: int = 0
    prewarm_spawns: int = 0
    prewarm_hits: int = 0
    max_concurrent_instances: int = 1    # peak instances of any SINGLE function
                                         #   (>1 means arrivals overlapped)
    placement_warm_hits: int = 0         # routed to a worker with an idle warm inst
    placement_pool_hits: int = 0         # routed by image residency
    instance_resident_min: float = 0.0   # warm instance-minutes across the fleet
                                         #   (the residency SPES-style policies cut)
    per_worker: List[Dict] = field(default_factory=list)

    @property
    def avg_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_invocations, 1)


def _make_policy(cfg: FleetConfig) -> PrewarmPolicy:
    if isinstance(cfg.prewarm, PrewarmPolicy):
        # copy: policies accumulate arrival history, and reusing the caller's
        # instance across runs would leak state between simulations
        return copy.deepcopy(cfg.prewarm)
    if cfg.prewarm == "none":
        return PrewarmPolicy(keep_alive_min=cfg.keep_alive_min)
    if cfg.prewarm not in PREWARM_POLICIES:
        raise ValueError(f"unknown prewarm policy: {cfg.prewarm!r} "
                         f"(choose from {sorted(PREWARM_POLICIES)})")
    return PREWARM_POLICIES[cfg.prewarm]()


def simulate_fleet(
    traces: List[Trace],
    method: str,                       # 'warmswap' | 'prebaking' | 'baseline'
    cost: CostModel,
    fleet: Optional[FleetConfig] = None,
) -> FleetResult:
    fleet = fleet if fleet is not None else FleetConfig()
    if fleet.n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {fleet.n_workers}")
    if fleet.placement not in ("affinity", "least_loaded", "round_robin"):
        raise ValueError(f"unknown placement: {fleet.placement!r}")
    # deferred: repro.serving pulls in the model/engine stack, which a
    # simulation-only import of repro.core should not pay for
    from repro.serving.scheduler import place_invocation
    policy = _make_policy(fleet)
    cold_base = method_cold_latency_s(cost, method)
    workers = [_Worker(i, fleet.worker_capacity_bytes)
               for i in range(fleet.n_workers)]
    fn_image = {t.fn_index: t.image_id for t in traces}
    images = sorted({t.image_id for t in traces})

    res = FleetResult(method=method, n_invocations=0, n_cold=0, n_warm=0,
                      total_latency_s=0.0, memory_bytes=0,
                      n_workers=fleet.n_workers,
                      per_fn_latency={t.fn_index: 0.0 for t in traces},
                      per_fn_invocations={t.fn_index: 0 for t in traces})

    def resident_key(fn: int) -> str:
        """What must be resident in a worker pool to cold-start ``fn`` fast."""
        return (f"img:{fn_image[fn]}" if method == "warmswap"
                else f"snap:{fn}")

    def fleet_bytes() -> int:
        total = 0
        for w in workers:
            total += w.ledger.used_bytes()
            if method == "warmswap":
                total += len(w.metadata_fns) * cost.metadata_bytes
        return total

    def note_peak() -> None:
        res.memory_bytes = max(res.memory_bytes, fleet_bytes())

    # ---------------------------------------------------------------- setup phase
    # Provider pre-builds residents on home workers (paper Fig. 4b): WarmSwap
    # builds each live image once; Prebaking snapshots every function upfront
    # (the paper keeps prebaked snapshots in RAM, §4.5). Baseline holds nothing.
    if method == "warmswap":
        for rank, img in enumerate(images):
            home = workers[rank % len(workers)]
            home.ledger.admit(f"img:{img}", cost.image_bytes, now=0.0)
        for fn, img in fn_image.items():
            home = workers[images.index(img) % len(workers)]
            home.metadata_fns.add(fn)
    elif method == "prebaking":
        for fn, img in fn_image.items():
            home = workers[images.index(img) % len(workers)]
            home.ledger.admit(f"snap:{fn}", cost.snapshot_bytes, now=0.0)
    note_peak()

    # ---------------------------------------------------------------- event feed
    all_t = np.concatenate([t.arrivals_min for t in traces]) if traces else \
        np.empty((0,))
    all_fn = np.concatenate([np.full(len(t.arrivals_min), t.fn_index, np.int64)
                             for t in traces]) if traces else np.empty((0,), np.int64)
    order = np.argsort(all_t, kind="stable")
    all_t, all_fn = all_t[order], all_fn[order]
    prewarm_heap: list = []            # (spawn_at, seq, fn, expire_at)
    seq = itertools.count()

    def pick_worker(fn: int, t: float) -> _Worker:
        key = resident_key(fn)
        if fleet.placement == "round_robin":
            w = workers[res.n_invocations % len(workers)]
        elif fleet.placement == "least_loaded":
            w = place_invocation(workers, load=lambda w: w.load(t))
        else:                          # affinity
            w = place_invocation(
                workers,
                load=lambda w: w.load(t),
                has_warm=lambda w: w.idle_instance(fn, t) is not None,
                holds_image=lambda w: w.ledger.holds(key),
            )
        if w.idle_instance(fn, t) is not None:
            res.placement_warm_hits += 1
        elif w.ledger.holds(key):
            res.placement_pool_hits += 1
        return w

    def cold_start(w: _Worker, fn: int, t: float) -> float:
        """Admit what the cold start needs into the worker pool; return latency."""
        key = resident_key(fn)
        lat = cold_base
        if method == "warmswap":
            if not w.ledger.holds(key):
                lat += cost.image_revive_s        # disk-tier revive / rebuild
                res.pool_misses += 1
            w.ledger.admit(key, cost.image_bytes, now=t)
            if fn not in w.metadata_fns:
                w.metadata_fns.add(fn)
        elif method == "prebaking":
            if not w.ledger.holds(key):
                # snapshot was evicted: fall back to a from-scratch start and
                # re-snapshot the result
                lat = method_cold_latency_s(cost, "baseline")
                res.pool_misses += 1
            w.ledger.admit(key, cost.snapshot_bytes, now=t)
        w.ledger.touch(key, t)
        note_peak()
        return lat

    def spawn_prewarm(t: float, fn: int, expire_at: float) -> None:
        for w in workers:
            if w.alive(fn, t):
                return                 # something is already warm; don't double-spawn
        key = resident_key(fn)
        w = place_invocation(workers, load=lambda w: w.load(t),
                             holds_image=lambda w: w.ledger.holds(key))
        if method != "baseline":
            nbytes = cost.image_bytes if method == "warmswap" else cost.snapshot_bytes
            w.ledger.admit(key, nbytes, now=t)
            if method == "warmswap":
                w.metadata_fns.add(fn)
            note_peak()
        w.instances.setdefault(fn, []).append(
            _Instance(fn, busy_until=t, expires=expire_at, created=t,
                      prewarmed=True))
        res.prewarm_spawns += 1

    # ---------------------------------------------------------------- event loop
    for t, fn in zip(all_t, all_fn):
        t, fn = float(t), int(fn)
        while prewarm_heap and prewarm_heap[0][0] <= t:
            ts, _, pfn, pexp = heapq.heappop(prewarm_heap)
            spawn_prewarm(ts, pfn, pexp)

        policy.on_arrival(fn, t)
        ka = policy.keep_alive_min(fn)
        w = pick_worker(fn, t)
        inst = w.idle_instance(fn, t)
        alive = w.alive(fn, t)

        if inst is not None:
            lat = cost.warm_s
            res.n_warm += 1
            if inst.prewarmed:
                res.prewarm_hits += 1
                inst.prewarmed = False
        elif alive and (fleet.max_instances_per_fn is not None
                        and len(alive) >= fleet.max_instances_per_fn):
            # at the instance cap: serialize onto the soonest-free instance
            # (max_instances_per_fn=1 is exactly simulate()'s warm path)
            lat = cost.warm_s
            res.n_warm += 1
            inst = min(alive, key=lambda i: i.busy_until)
        else:
            lat = cold_start(w, fn, t)
            res.n_cold += 1
            inst = _Instance(fn, busy_until=t, expires=t, created=t)
            w.instances.setdefault(fn, []).append(inst)
            n_alive = sum(len(ww.alive(fn, t)) for ww in workers)
            res.max_concurrent_instances = max(res.max_concurrent_instances,
                                               n_alive)

        inst.busy_until = t + lat / 60.0
        inst.expires = inst.busy_until + ka
        w.n_served += 1
        res.n_invocations += 1
        res.total_latency_s += lat
        res.per_fn_latency[fn] = res.per_fn_latency.get(fn, 0.0) + lat
        res.per_fn_invocations[fn] = res.per_fn_invocations.get(fn, 0) + 1

        window = policy.prewarm_after(fn, t)
        if window is not None:
            heapq.heappush(prewarm_heap,
                           (window[0], next(seq), fn, window[1]))

    res.evictions = sum(w.ledger.evictions for w in workers)
    for w in workers:                    # flush residency of still-alive instances
        for insts in w.instances.values():
            for i in insts:
                w.instance_min += i.expires - i.created
    res.instance_resident_min = sum(w.instance_min for w in workers)
    res.per_worker = [{
        "worker": w.idx,
        "served": w.n_served,
        "pool_bytes": w.ledger.used_bytes(),
        "resident": sorted(w.ledger.entries.keys()),
        "metadata_fns": len(w.metadata_fns),
        "evictions": w.ledger.evictions,
        "instance_min": w.instance_min,
    } for w in workers]
    return res
