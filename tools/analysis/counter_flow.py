"""Counter-flow checker: every fleet counter has a law, a writer, and a
projection — verified by AST dataflow over both engines.

The engines accumulate ~25 counters (``n_cold``, ``pages_transferred``,
cache hit tiers, disruption counters, ...) that the unified result schema
(``scenario.MethodResult``) surfaces and the paper-band checks read. Three
things can silently rot:

* a counter exists but no conservation law covers it (nobody can say what
  "correct" means for it) — ``undeclared-counter``;
* the event engine stops writing a declared counter (a dropped increment:
  the result quietly reads zero forever) — ``unmutated-counter``;
* a counter is accumulated but never projected into ``MethodResult``, so
  serialized results silently lose it — ``unprojected-counter``.

The declarations live in ``config.FLEET_COUNTERS`` (counter -> law +
projection target) / ``config.COUNTER_LAWS`` / ``config.FLEET_RESULT_STATE``
(non-counter fields). Drift *in the declarations* is also a finding:
``unknown-counter`` (declared name that is not a ``FleetResult`` field) and
``unknown-law`` (a cited law with no definition).

Repo-level: runs once per invocation over the module-level ``*_PATH``
targets (monkeypatchable, so mutation tests can prove detection on a
deliberately-broken copy).
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis import config
from tools.analysis.base import REPO_ROOT, rel_path
from tools.analysis.findings import Finding

CHECKER = "counter-flow"

FLEET_PATH = os.path.join(REPO_ROOT, "src", "repro", "core", "fleet.py")
FLEET_VEC_PATH = os.path.join(REPO_ROOT, "src", "repro", "core",
                              "fleet_vec.py")
SCENARIO_PATH = os.path.join(REPO_ROOT, "src", "repro", "core",
                             "scenario.py")


def _finding(rule: str, path: str, line: int, message: str,
             scope: str = "", snippet: str = "",
             suggestion: str = "") -> Finding:
    return Finding(CHECKER, rule, rel_path(path), line, 0, message,
                   scope=scope, snippet=snippet, suggestion=suggestion)


def _dataclass_fields(tree: ast.Module, class_name: str
                      ) -> Tuple[Set[str], int]:
    """(annotated field names, class lineno) of ``class_name`` in ``tree``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            return ({stmt.target.id for stmt in node.body
                     if isinstance(stmt, ast.AnnAssign)
                     and isinstance(stmt.target, ast.Name)}, node.lineno)
    return set(), 1


def _result_writes(tree: ast.Module) -> Dict[str, int]:
    """attr -> first write lineno, over every variable assigned from a
    ``FleetResult(...)`` call: constructor keywords count as writes, as do
    ``<var>.<attr>`` assignments and augmented assignments."""
    res_vars: Set[str] = set()
    writes: Dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                isinstance(node.value, ast.Call):
            callee = node.value.func
            name = callee.id if isinstance(callee, ast.Name) else \
                callee.attr if isinstance(callee, ast.Attribute) else ""
            if name == "FleetResult":
                res_vars.add(node.targets[0].id)
                for kw in node.value.keywords:
                    if kw.arg:
                        writes.setdefault(kw.arg, node.lineno)
    if not res_vars:
        return writes
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id in res_vars:
                    writes.setdefault(t.attr, node.lineno)
    return writes


def _projection(tree: ast.Module) -> Tuple[Set[str], Set[str], int]:
    """From ``_method_result``: (MethodResult(...) keyword names, ``r.<attr>``
    reads of the raw result, function lineno)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and \
                node.name == "_method_result":
            raw = node.args.args[0].arg if node.args.args else "r"
            kwargs: Set[str] = set()
            reads: Set[str] = set()
            for inner in ast.walk(node):
                if isinstance(inner, ast.Call) and \
                        isinstance(inner.func, ast.Name) and \
                        inner.func.id == "MethodResult":
                    kwargs |= {kw.arg for kw in inner.keywords if kw.arg}
                if isinstance(inner, ast.Attribute) and \
                        isinstance(inner.value, ast.Name) and \
                        inner.value.id == raw:
                    reads.add(inner.attr)
            return kwargs, reads, node.lineno
    return set(), set(), 1


def check_repo() -> List[Finding]:
    findings: List[Finding] = []
    with open(FLEET_PATH) as f:
        fleet_tree = ast.parse(f.read())
    with open(FLEET_VEC_PATH) as f:
        vec_tree = ast.parse(f.read())
    with open(SCENARIO_PATH) as f:
        scenario_tree = ast.parse(f.read())

    fields, cls_line = _dataclass_fields(fleet_tree, "FleetResult")
    if not fields:
        return [_finding(
            "unknown-counter", FLEET_PATH, 1,
            "no FleetResult dataclass with annotated fields found in "
            "fleet.py", scope="FleetResult", snippet="class FleetResult",
            suggestion="keep FleetResult an annotated dataclass")]
    declared = set(config.FLEET_COUNTERS) | config.FLEET_RESULT_STATE

    # ------------------------------------------------ declaration hygiene
    for name in sorted(set(config.FLEET_COUNTERS) - fields):
        findings.append(_finding(
            "unknown-counter", FLEET_PATH, cls_line,
            f"config.FLEET_COUNTERS declares {name!r} but FleetResult has "
            f"no such field — the declaration table drifted from the code",
            scope=f"FLEET_COUNTERS.{name}", snippet=name,
            suggestion="remove the stale entry from tools/analysis/"
                       "config.py or restore the field"))
    for name in sorted(config.FLEET_RESULT_STATE - fields):
        findings.append(_finding(
            "unknown-counter", FLEET_PATH, cls_line,
            f"config.FLEET_RESULT_STATE lists {name!r} but FleetResult has "
            f"no such field", scope=f"FLEET_RESULT_STATE.{name}",
            snippet=name,
            suggestion="remove the stale entry from tools/analysis/"
                       "config.py"))
    for name, (law, _target) in sorted(config.FLEET_COUNTERS.items()):
        if law not in config.COUNTER_LAWS:
            findings.append(_finding(
                "unknown-law", FLEET_PATH, cls_line,
                f"counter {name!r} cites conservation law {law!r} which "
                f"config.COUNTER_LAWS does not define",
                scope=f"FLEET_COUNTERS.{name}", snippet=f"{name}: {law}",
                suggestion="define the law in COUNTER_LAWS or cite an "
                           "existing one"))

    # ---------------------------------------------------- undeclared fields
    for name in sorted(fields - declared):
        findings.append(_finding(
            "undeclared-counter", FLEET_PATH, cls_line,
            f"FleetResult.{name} has no declared conservation law and is "
            f"not listed as result state — nobody can say what a correct "
            f"value looks like",
            scope=f"FleetResult.{name}", snippet=name,
            suggestion="declare it in config.FLEET_COUNTERS (with a law "
                       "and a projection) or config.FLEET_RESULT_STATE"))

    # ------------------------------------------------- engine write checks
    fleet_writes = _result_writes(fleet_tree)
    vec_writes = _result_writes(vec_tree)
    for path, writes in ((FLEET_PATH, fleet_writes),
                         (FLEET_VEC_PATH, vec_writes)):
        for name in sorted(set(writes) - declared):
            findings.append(_finding(
                "undeclared-counter", path, writes[name],
                f"engine writes result field {name!r} that is neither a "
                f"declared counter nor declared result state",
                scope=f"write.{name}", snippet=f"res.{name}",
                suggestion="declare the field in tools/analysis/config.py"))
    for name in sorted(set(config.FLEET_COUNTERS) & fields):
        if name not in fleet_writes:
            findings.append(_finding(
                "unmutated-counter", FLEET_PATH, cls_line,
                f"declared counter {name!r} is never written by the event "
                f"engine — a dropped increment means results silently "
                f"read its default forever",
                scope=f"FleetResult.{name}", snippet=name,
                suggestion="restore the counter mutation in "
                           "_simulate_fleet_impl or retire the counter"))

    # -------------------------------------------------- projection checks
    method_fields, _ = _dataclass_fields(scenario_tree, "MethodResult")
    proj_kwargs, proj_reads, proj_line = _projection(scenario_tree)
    if not proj_kwargs:
        findings.append(_finding(
            "unprojected-counter", SCENARIO_PATH, proj_line,
            "no MethodResult(...) construction found in "
            "scenario._method_result — the unified projection is gone",
            scope="_method_result", snippet="_method_result",
            suggestion="keep _method_result building MethodResult with "
                       "explicit keywords"))
        return findings
    for name, (_law, target) in sorted(config.FLEET_COUNTERS.items()):
        if name not in fields:
            continue    # already reported as unknown-counter
        field = target.split(".")[0]
        problem: Optional[str] = None
        if field not in method_fields:
            problem = (f"projection target {field!r} is not a MethodResult "
                       f"field")
        elif field not in proj_kwargs:
            problem = (f"_method_result never passes {field!r} to "
                       f"MethodResult")
        elif name not in proj_reads:
            problem = (f"_method_result never reads the raw counter "
                       f"r.{name}")
        if problem:
            findings.append(_finding(
                "unprojected-counter", SCENARIO_PATH, proj_line,
                f"counter {name!r} is accumulated by the engines but not "
                f"projected into the unified result schema: {problem}",
                scope=f"projection.{name}", snippet=f"{name} -> {target}",
                suggestion="project the counter in scenario._method_result "
                           "and document it in docs/API.md"))
    return findings
