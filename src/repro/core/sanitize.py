"""repro-san: the runtime invariant sanitizer for the fleet engines.

The static layer (``tools/analysis``) proves the *declared* contract is the
*coded* contract; this module checks the contract **holds while a simulation
runs**. With ``REPRO_SANITIZE=1`` (or ``run(..., sanitize=True)``) both fleet
engines execute instrumented assertions at every drain step:

* ``event-order``    — heap pops follow the documented ``(time, kind, seq)``
  total order (docs/SIMULATION.md tie-break table) and never go backwards;
* ``negative-wait``  — no request is served before it arrived;
* ``busy-regression``— an instance's ``busy_until`` only ever advances (no
  double-booked instance, no negative service time);
* ``ledger-books``   — every :class:`~repro.core.pool.CapacityLedger`
  balances: the incremental byte total equals the recomputed sum, refcounts
  and sizes are nonnegative;
* ``cluster-books``  — the shared tier's holder sets and its ledger agree
  bidirectionally, and every holder's worker pool really holds the key;
* ``counter-conservation`` — the counter laws of docs/SIMULATION.md, chiefly
  ``n_invocations <= n_cold + n_warm <= n_invocations + requeued`` (strict
  equality when nothing was requeued);
* ``sample-domain``  — latency/wait sample arrays are finite, nonnegative,
  and elementwise ``latency >= wait``.

A violation raises :class:`SanitizeError` after writing a minimized repro
artifact (``results/sanitizer/<sha16>.json``): the invariant, the resolved
scenario, the first violating event, and a counter snapshot — everything a
debugging session needs to replay the failure. Artifact names are content
hashes, not timestamps, so sanitized runs stay deterministic.

The checks are assertions only: a sanitized run returns bit-identical
results (CI's ``sanitize`` leg replays the golden suite and the reduced
differential fuzz under ``REPRO_SANITIZE=1`` to prove it).
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

#: Artifact layout version (bump on any payload shape change).
SANITIZER_SCHEMA_VERSION = 1

#: Where repro artifacts land unless the caller overrides it.
DEFAULT_ARTIFACT_DIR = os.path.join("results", "sanitizer")

#: FleetResult counters that must never go negative.
_NONNEG_COUNTERS = (
    "n_invocations", "n_cold", "n_warm", "n_queued", "requeued",
    "pool_misses", "evictions", "prewarm_spawns", "prewarm_hits",
    "prewarm_dropped", "max_concurrent_instances", "memory_bytes",
    "cache_local_hits", "cache_remote_hits", "cache_misses",
    "pages_transferred", "shared_cache_peak_bytes", "shared_cache_evictions",
    "placement_warm_hits", "placement_pool_hits", "worker_failures",
    "worker_recoveries", "cache_flushes",
)


def sanitize_enabled() -> bool:
    """True when ``REPRO_SANITIZE`` asks for a sanitized run (any value but
    empty/``0``)."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


class SanitizeError(RuntimeError):
    """An invariant violation caught by the sanitizer; ``artifact_path``
    locates the minimized repro artifact (``None`` if it could not be
    written)."""

    def __init__(self, message: str, artifact_path: Optional[str] = None):
        super().__init__(message)
        self.artifact_path = artifact_path


class FleetSanitizer:
    """Per-simulation invariant checker, threaded through one engine run.

    Args:
        engine: ``"fleet"`` / ``"fleet_vec"`` / ``"single"`` (artifact tag).
        method: the method being simulated (artifact tag).
        scenario: the resolved scenario dict (``Scenario.to_dict()``), echoed
            into the repro artifact so a failure replays from the artifact
            alone; ``None`` for imperative callers.
        artifact_dir: where to write repro artifacts (default
            ``results/sanitizer``).
    """

    #: Full books audits run every this-many heap events (plus once at the
    #: end) — every event would turn O(n log n) runs quadratic.
    BOOKS_EVERY = 4096

    def __init__(self, engine: str, method: str,
                 scenario: Optional[Dict[str, Any]] = None,
                 artifact_dir: Optional[str] = None):
        self.engine = engine
        self.method = method
        self.scenario = scenario
        self.artifact_dir = artifact_dir or DEFAULT_ARTIFACT_DIR
        self._last_event: Optional[Tuple[float, int, int]] = None
        self._n_events = 0

    # ------------------------------------------------------------- failure
    def fail(self, invariant: str, message: str, *,
             event: Optional[Dict[str, Any]] = None,
             counters: Optional[Dict[str, Any]] = None) -> None:
        """Write the repro artifact and raise :class:`SanitizeError`."""
        payload = {
            "sanitizer_schema_version": SANITIZER_SCHEMA_VERSION,
            "invariant": invariant,
            "message": message,
            "engine": self.engine,
            "method": self.method,
            "scenario": self.scenario,
            "event": event,
            "counters": counters,
            "n_events_processed": self._n_events,
        }
        blob = json.dumps(payload, sort_keys=True, indent=1, default=str)
        digest = hashlib.sha256(blob.encode()).hexdigest()[:16]
        path: Optional[str] = os.path.join(self.artifact_dir,
                                           f"{digest}.json")
        try:
            os.makedirs(self.artifact_dir, exist_ok=True)
            with open(path, "w") as f:
                f.write(blob + "\n")
        except OSError:
            path = None
        where = f" (repro artifact: {path})" if path else ""
        raise SanitizeError(
            f"[repro-san/{invariant}] {self.engine}/{self.method}: "
            f"{message}{where}", artifact_path=path)

    # ------------------------------------------------------------ event loop
    def check_event(self, t: float, kind: int, seq: int) -> bool:
        """Validate one heap pop against the ``(time, kind, seq)`` total
        order; returns True when a periodic books audit is due."""
        self._n_events += 1
        ev = {"t": t, "kind": int(kind), "seq": int(seq)}
        if not np.isfinite(t) or t < 0:
            self.fail("event-order",
                      f"event time {t!r} is negative or non-finite",
                      event=ev)
        cur = (t, int(kind), int(seq))
        if self._last_event is not None and cur <= self._last_event:
            self.fail("event-order",
                      f"heap popped {cur} after {self._last_event}: the "
                      f"(time, kind, seq) total order went backwards",
                      event=ev)
        self._last_event = cur
        return self._n_events % self.BOOKS_EVERY == 0

    def check_service(self, *, start: float, req_t: float, prev_busy: float,
                      busy_until: float, worker: int, fn: int) -> None:
        """Validate one service start: nonnegative wait, and the instance's
        ``busy_until`` never regresses (no double-booking, no negative
        service time)."""
        ev = {"t": start, "req_t": req_t, "worker": worker, "fn": fn,
              "prev_busy_until": prev_busy, "busy_until": busy_until}
        if start < req_t:
            self.fail("negative-wait",
                      f"request arriving at t={req_t} started service at "
                      f"t={start}, before it arrived", event=ev)
        if start < prev_busy:
            self.fail("busy-regression",
                      f"instance (worker {worker}, fn {fn}) started a new "
                      f"request at t={start} while busy until "
                      f"t={prev_busy}", event=ev)
        if busy_until < start:
            self.fail("busy-regression",
                      f"instance (worker {worker}, fn {fn}) computed "
                      f"busy_until={busy_until} < start={start}: negative "
                      f"service time", event=ev)

    # ----------------------------------------------------------------- books
    def check_books(self, workers, cluster=None) -> None:
        """Audit every capacity ledger and the shared cluster tier."""
        for w in workers:
            ledger = w.ledger
            recomputed = sum(e.nbytes for e in ledger.entries.values())
            if ledger.used_bytes() != recomputed:
                self.fail("ledger-books",
                          f"worker {w.idx} ledger books do not balance: "
                          f"tracked {ledger.used_bytes()} bytes, entries "
                          f"sum to {recomputed}",
                          event={"worker": w.idx})
            for key, e in ledger.entries.items():
                if e.nbytes < 0 or e.refcount < 0:
                    self.fail("ledger-books",
                              f"worker {w.idx} ledger entry {key!r} has "
                              f"nbytes={e.nbytes}, refcount={e.refcount}",
                              event={"worker": w.idx, "key": key})
        if cluster is None:
            return
        held = set(cluster.holders)
        resident = set(cluster.ledger.entries)
        if held != resident:
            self.fail("cluster-books",
                      f"shared-tier holder sets and ledger disagree: "
                      f"holders-only {sorted(held - resident)}, "
                      f"ledger-only {sorted(resident - held)}")
        by_idx = {w.idx: w for w in workers}
        for key, holders in cluster.holders.items():
            if not holders:
                self.fail("cluster-books",
                          f"shared tier lists {key!r} with an empty holder "
                          f"set (the last worker_evicted should have "
                          f"dropped it)", event={"key": key})
            for idx in holders:
                w = by_idx.get(idx)
                if w is None or not w.ledger.holds(key):
                    self.fail("cluster-books",
                              f"shared tier says worker {idx} holds "
                              f"{key!r} but its pool does not",
                              event={"worker": idx, "key": key})

    # -------------------------------------------------------------- counters
    def check_counters(self, res) -> None:
        """The counter conservation laws (docs/SIMULATION.md) over a final
        ``FleetResult``."""
        snap = {name: getattr(res, name) for name in _NONNEG_COUNTERS
                if hasattr(res, name)}
        for name, value in snap.items():
            if value < 0:
                self.fail("counter-conservation",
                          f"counter {name} is negative: {value}",
                          counters=snap)
        n_inv = res.n_invocations
        starts = res.n_cold + res.n_warm
        requeued = getattr(res, "requeued", 0)
        if requeued == 0 and starts != n_inv:
            self.fail("counter-conservation",
                      f"service conservation violated: n_cold + n_warm = "
                      f"{starts} != n_invocations = {n_inv} with nothing "
                      f"requeued", counters=snap)
        if not (n_inv <= starts <= n_inv + requeued):
            self.fail("counter-conservation",
                      f"service conservation violated: n_invocations = "
                      f"{n_inv} <= n_cold + n_warm = {starts} <= "
                      f"n_invocations + requeued = {n_inv + requeued} "
                      f"does not hold", counters=snap)
        if res.n_queued > n_inv:
            self.fail("counter-conservation",
                      f"n_queued = {res.n_queued} exceeds n_invocations = "
                      f"{n_inv}", counters=snap)
        tiers = (res.cache_local_hits + res.cache_remote_hits
                 + res.cache_misses)
        if tiers > res.n_cold:
            self.fail("counter-conservation",
                      f"cache tier accesses ({tiers}) exceed cold starts "
                      f"({res.n_cold}): every tier classification belongs "
                      f"to one cold start", counters=snap)
        if res.prewarm_hits > res.prewarm_spawns:
            self.fail("counter-conservation",
                      f"prewarm_hits = {res.prewarm_hits} exceeds "
                      f"prewarm_spawns = {res.prewarm_spawns}",
                      counters=snap)
        if res.worker_recoveries > res.worker_failures:
            self.fail("counter-conservation",
                      f"worker_recoveries = {res.worker_recoveries} "
                      f"exceeds worker_failures = {res.worker_failures}",
                      counters=snap)
        if requeued and res.worker_failures == 0:
            self.fail("counter-conservation",
                      f"requeued = {requeued} with zero worker failures",
                      counters=snap)
        for name in ("total_latency_s", "queue_delay_s"):
            v = float(getattr(res, name))
            if not np.isfinite(v) or v < 0:
                self.fail("counter-conservation",
                          f"{name} is negative or non-finite: {v!r}",
                          counters=snap)
        if res.queue_delay_s > res.total_latency_s:
            self.fail("counter-conservation",
                      f"queue_delay_s = {res.queue_delay_s} exceeds "
                      f"total_latency_s = {res.total_latency_s}: latency "
                      f"includes every queue wait", counters=snap)

    def check_samples(self, samples: np.ndarray,
                      waits: np.ndarray) -> None:
        """Finite, nonnegative sample arrays with elementwise
        ``latency >= wait``."""
        for name, arr in (("latency", samples), ("wait", waits)):
            if arr.size and not np.isfinite(arr).all():
                idx = int(np.flatnonzero(~np.isfinite(arr))[0])
                self.fail("sample-domain",
                          f"{name} sample {idx} is non-finite "
                          f"({arr[idx]!r})", event={"index": idx})
        if waits.size and bool((waits < 0).any()):
            idx = int(np.flatnonzero(waits < 0)[0])
            self.fail("sample-domain",
                      f"wait sample {idx} is negative ({waits[idx]!r})",
                      event={"index": idx, "wait_s": float(waits[idx])})
        if samples.size and bool((samples < waits).any()):
            idx = int(np.flatnonzero(samples < waits)[0])
            self.fail("sample-domain",
                      f"latency sample {idx} ({samples[idx]!r}) is below "
                      f"its queue wait ({waits[idx]!r})",
                      event={"index": idx})

    # ------------------------------------------------------- single engine
    def check_single(self, res) -> None:
        """Light post-run checks for the single-worker engine (no requeue,
        no ledgers): exact service conservation and finite totals."""
        if res.n_cold + res.n_warm != res.n_invocations:
            self.fail("counter-conservation",
                      f"service conservation violated: n_cold + n_warm = "
                      f"{res.n_cold + res.n_warm} != n_invocations = "
                      f"{res.n_invocations}")
        for name in ("n_invocations", "n_cold", "n_warm", "memory_bytes"):
            if getattr(res, name) < 0:
                self.fail("counter-conservation",
                          f"counter {name} is negative: "
                          f"{getattr(res, name)}")
        v = float(res.total_latency_s)
        if not np.isfinite(v) or v < 0:
            self.fail("counter-conservation",
                      f"total_latency_s is negative or non-finite: {v!r}")
