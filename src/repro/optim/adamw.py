"""AdamW in pure JAX (no optax dependency) with global-norm clipping.

Optimizer state mirrors the parameter pytree (mu, nu in fp32 regardless of param
dtype — the standard mixed-precision recipe), so it pages/checkpoints/shards through
exactly the same machinery as the parameters (WarmSwap Prebaking images include it;
WarmSwap dependency images deliberately do NOT — that asymmetry is the paper's 88 %
memory saving).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    grads,
    opt_state: dict,
    params,
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = opt_state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
