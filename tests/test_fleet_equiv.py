"""Differential equivalence suite: the vectorized engine (core/fleet_vec.py)
must be BIT-identical to the discrete-event engine (core/fleet.py) — same
sha256 over the per-request latency/wait sample arrays, same counters, same
per-function and per-worker projections — across placement x capacity x
page-model x prewarm configs.  Covers:

  * every checked-in fleet scenario spec, both engines, all methods;
  * a seeded randomized-config fuzz sweep (reduced iterations under
    ``REPRO_SMOKE=1`` — the CI smoke job; tier-1 runs the full sweep);
  * the paper headline bands reproduced THROUGH the vectorized engine
    (88 % +- 5 memory saving, 2.2-3.2x dependency-loading speedup);
  * the ``jax.lax.scan`` path (``scan=True``) against the numpy solver;
  * the fast-path/fallback domain oracle (``fast_path_reason``).
"""
import glob
import hashlib
import os

import numpy as np
import pytest

from repro.core.costmodel import PAGE_COST_MODELS
from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import (SCAN_STATS, _get_scan_fn, fast_path_reason,
                                  simulate_fleet_vec)
from repro.core.scenario import Scenario, run
from repro.core.simulator import CostModel
from repro.core.traces import generate_fleet_traces

SCENARIOS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "scenarios")
CM = CostModel.paper_table2()

#: Reduced fuzz budget under the CI smoke job; tier-1 runs the full sweep.
N_FUZZ = 10 if os.environ.get("REPRO_SMOKE") == "1" else 32

INT_FIELDS = ("n_invocations", "n_cold", "n_warm", "n_queued", "n_workers",
              "pool_misses", "evictions", "max_concurrent_instances",
              "placement_warm_hits", "placement_pool_hits", "memory_bytes",
              "cache_local_hits", "cache_remote_hits", "cache_misses",
              "shared_cache_peak_bytes", "shared_cache_evictions",
              "pages_transferred", "prewarm_spawns", "prewarm_hits",
              "prewarm_dropped")
#: Compared EXACTLY (==, not approx): the contract is bit-identity.
FLOAT_FIELDS = ("total_latency_s", "queue_delay_s", "instance_resident_min",
                "horizon_min")


def _sha(a) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def assert_equiv(ref, vec, label=""):
    """Bit-identity between two FleetResults (event engine vs vectorized)."""
    for name in ("latency_samples_s", "queue_wait_s", "sample_fn"):
        a, b = getattr(ref, name), getattr(vec, name)
        assert a.shape == b.shape, f"{label}: {name} shape {a.shape}!={b.shape}"
        assert _sha(a) == _sha(b), f"{label}: {name} bytes differ"
    for name in INT_FIELDS:
        assert getattr(ref, name) == getattr(vec, name), \
            f"{label}: {name} {getattr(ref, name)} != {getattr(vec, name)}"
    for name in FLOAT_FIELDS:
        assert getattr(ref, name) == getattr(vec, name), \
            f"{label}: {name} {getattr(ref, name)!r} != {getattr(vec, name)!r}"
    assert ref.per_fn_latency == vec.per_fn_latency, f"{label}: per_fn_latency"
    assert ref.per_fn_invocations == vec.per_fn_invocations, \
        f"{label}: per_fn_invocations"
    assert ref.per_worker == vec.per_worker, f"{label}: per_worker"


def check_config(traces, method, fleet_kwargs, label=""):
    """Run both engines on fresh FleetConfigs and assert bit-identity."""
    ref = _simulate_fleet_impl(traces, method, CM, FleetConfig(**fleet_kwargs))
    vec = simulate_fleet_vec(traces, method, CM, FleetConfig(**fleet_kwargs))
    assert_equiv(ref, vec, label=f"{label}/{method}")


# ---------------------------------------------------------------------------------
# Every checked-in fleet scenario, both engines, all methods
# ---------------------------------------------------------------------------------

def _fleet_spec_paths():
    out = []
    for path in sorted(glob.glob(os.path.join(SCENARIOS_DIR, "*.json"))):
        scn = Scenario.from_file(path)
        if scn.engine in ("fleet", "fleet_vec"):
            out.append(os.path.splitext(os.path.basename(path))[0])
    return out


#: Big replay specs get their horizon trimmed so tier-1 stays fast; the full
#: scale runs in the bench job (benchmarks/bench_fleet.py azure_scale cells).
_TIER1_TRIMS = {
    "azure_scale": {"traces.kwargs.horizon_min": 720},
    "azure_scale_xl": {"traces.kwargs.horizon_min": 120},
}


@pytest.mark.parametrize("name", _fleet_spec_paths())
def test_checked_in_scenarios_bit_identical(name):
    scn = Scenario.from_file(
        os.path.join(SCENARIOS_DIR, f"{name}.json")).smoke_scaled()
    overrides = dict(_TIER1_TRIMS.get(name, {}))
    # restore the full method list the smoke overrides may have trimmed
    base = Scenario.from_file(os.path.join(SCENARIOS_DIR, f"{name}.json"))
    overrides["methods"] = list(base.methods)
    ref = run(scn.with_overrides({**overrides, "engine": "fleet"}))
    vec = run(scn.with_overrides({**overrides, "engine": "fleet_vec"}))
    for method in base.methods:
        assert_equiv(ref.raw[method], vec.raw[method],
                     label=f"{name}/{method}")
    assert ref.summary == vec.summary


# ---------------------------------------------------------------------------------
# Randomized-config differential fuzz
# ---------------------------------------------------------------------------------

def _fuzz_config(case):
    """One pinned-seed random config, biased toward fast-path-eligible shapes
    but covering the fallback domain too."""
    rng = np.random.default_rng(1000 + case)
    n_fns = int(rng.integers(2, 16))
    n_images = int(rng.integers(1, min(n_fns, 4) + 1))
    traces = generate_fleet_traces(
        n_functions=n_fns,
        horizon_min=float(rng.integers(200, 1500)),
        seed=int(rng.integers(0, 1 << 16)),
        n_images=n_images,
        rate_model="zipf",
        rate_skew=float(rng.uniform(0.5, 1.5)),
        total_rate_per_min=float(rng.uniform(0.5, 12.0)),
        batched=bool(rng.integers(0, 2)),
    )
    method = ("warmswap", "prebaking", "baseline")[case % 3]
    kwargs = {
        "n_workers": int(rng.choice([1, 1, 2, 4])),
        "max_instances_per_fn": [None, 1, 2][int(rng.integers(0, 3))],
        "placement": str(rng.choice(["affinity", "affinity", "round_robin",
                                     "least_loaded"])),
        "keep_alive_min": float(rng.uniform(0.5, 25.0)),
    }
    page = str(rng.choice(["none", "none", "default", "degenerate"]))
    if page != "none":
        kwargs["page_cost"] = PAGE_COST_MODELS.build(page, cost=CM)
    if rng.integers(0, 4) == 0:
        kwargs["worker_capacity_bytes"] = int(rng.integers(1, 6)) * \
            CM.image_bytes
    if rng.integers(0, 6) == 0:
        kwargs["prewarm"] = "histogram"       # exercises the fallback branch
    return traces, method, kwargs


@pytest.mark.parametrize("case", range(N_FUZZ))
def test_fuzz_differential(case):
    traces, method, kwargs = _fuzz_config(case)
    check_config(traces, method, kwargs, label=f"fuzz{case}")


def test_fuzz_covers_both_paths():
    """The fuzz distribution must actually exercise the fast path AND the
    event-engine fallback, else the sweep proves nothing."""
    fast = fallback = 0
    for case in range(N_FUZZ):
        traces, method, kwargs = _fuzz_config(case)
        if fast_path_reason(traces, method, CM, FleetConfig(**kwargs)) is None:
            fast += 1
        else:
            fallback += 1
    assert fast >= 3 and fallback >= 3, (fast, fallback)


# ---------------------------------------------------------------------------------
# fast_path_reason: the domain oracle
# ---------------------------------------------------------------------------------

def _traces(n_fns=6, n_images=2, seed=3, horizon=500.0, rate=4.0):
    return generate_fleet_traces(n_functions=n_fns, horizon_min=horizon,
                                 seed=seed, n_images=n_images,
                                 rate_model="zipf", total_rate_per_min=rate)


def test_fast_path_domain():
    tr = _traces()
    # degenerate single-worker: in-domain
    assert fast_path_reason(tr, "warmswap", CM,
                            FleetConfig(n_workers=1,
                                        max_instances_per_fn=1)) is None
    # single worker accepts ANY placement string (routing is trivial)
    assert fast_path_reason(tr, "warmswap", CM,
                            FleetConfig(n_workers=1,
                                        placement="least_loaded")) is None
    # multi-worker affinity + sharing methods: in-domain
    assert fast_path_reason(tr, "prebaking", CM,
                            FleetConfig(n_workers=4)) is None
    # multi-worker round-robin baseline: in-domain (static rotation)
    assert fast_path_reason(tr, "baseline", CM,
                            FleetConfig(n_workers=4,
                                        placement="round_robin")) is None
    # default page model strictly favors the home worker: in-domain
    assert fast_path_reason(
        tr, "warmswap", CM,
        FleetConfig(n_workers=4,
                    page_cost=PAGE_COST_MODELS.build("default",
                                                     cost=CM))) is None


def test_fallback_domain_reasons():
    tr = _traces()
    deg_page = PAGE_COST_MODELS.build("degenerate", cost=CM)
    cases = [
        (dict(n_workers=1, prewarm="histogram"), "warmswap", "pre-warm"),
        (dict(n_workers=4, placement="least_loaded"), "warmswap", "load"),
        (dict(n_workers=4, placement="affinity"), "baseline", "load"),
        (dict(n_workers=4, page_cost=deg_page), "warmswap", "tie"),
        (dict(n_workers=2, page_cost=deg_page,
              shared_cache_bytes=CM.image_bytes), "warmswap", "cache"),
    ]
    for kwargs, method, needle in cases:
        reason = fast_path_reason(tr, method, CM, FleetConfig(**kwargs))
        assert reason is not None and needle in reason, (kwargs, reason)


def test_fast_path_reason_validation_parity():
    tr = _traces()
    with pytest.raises(ValueError, match="n_workers"):
        fast_path_reason(tr, "warmswap", CM, FleetConfig(n_workers=0))
    with pytest.raises(ValueError, match="page_cost"):
        fast_path_reason(tr, "warmswap", CM,
                         FleetConfig(shared_cache_bytes=1 << 20))
    with pytest.raises(KeyError):
        fast_path_reason(tr, "warmswap", CM, FleetConfig(placement="afinity"))


def test_fallback_configs_still_bit_identical():
    """Out-of-domain configs route through the event engine — results must
    STILL match it exactly (trivially, but the dispatch must not distort)."""
    tr = _traces()
    check_config(tr, "warmswap", dict(n_workers=4, placement="least_loaded"),
                 label="fallback-least-loaded")
    check_config(tr, "warmswap", dict(n_workers=1, prewarm="histogram"),
                 label="fallback-prewarm")


# ---------------------------------------------------------------------------------
# Paper headline bands, reproduced through the vectorized engine
# ---------------------------------------------------------------------------------

def test_headline_saving_band_via_fleet_vec():
    scn = Scenario.from_file(os.path.join(SCENARIOS_DIR, "degenerate.json"))
    res = run(scn.with_overrides({"engine": "fleet_vec"}), smoke=True)
    assert 0.83 <= res.summary["memory_saving_vs_prebaking"] <= 0.93


def test_headline_speedup_band_via_fleet_vec():
    scn = Scenario.from_file(os.path.join(SCENARIOS_DIR, "page_headline.json"))
    res = run(scn.with_overrides({"engine": "fleet_vec"}), smoke=True)
    assert 2.2 <= res.summary["dependency_loading_speedup"] <= 3.2


# ---------------------------------------------------------------------------------
# jax.lax.scan path
# ---------------------------------------------------------------------------------

def test_scan_path_bit_identical():
    if _get_scan_fn() is None:
        pytest.skip("jax unavailable: scan path disabled")
    tr = _traces(n_fns=8, horizon=1200.0, rate=6.0)
    for method in ("warmswap", "prebaking", "baseline"):
        cfg = dict(n_workers=1, max_instances_per_fn=1)
        ref = _simulate_fleet_impl(tr, method, CM, FleetConfig(**cfg))
        vec = simulate_fleet_vec(tr, method, CM, FleetConfig(**cfg),
                                 scan=True)
        assert SCAN_STATS["groups"] > 0, "scan path never engaged"
        assert_equiv(ref, vec, label=f"scan/{method}")


def test_scan_env_toggle(monkeypatch):
    if _get_scan_fn() is None:
        pytest.skip("jax unavailable: scan path disabled")
    tr = _traces(n_fns=4)
    cfg = dict(n_workers=1, max_instances_per_fn=1)
    monkeypatch.setenv("REPRO_FLEET_VEC_SCAN", "1")
    vec = simulate_fleet_vec(tr, "warmswap", CM, FleetConfig(**cfg))
    assert SCAN_STATS["groups"] > 0
    ref = _simulate_fleet_impl(tr, "warmswap", CM, FleetConfig(**cfg))
    assert_equiv(ref, vec, label="scan-env")
