"""Paper Fig. 7 + §4.5 case study: ten functions sharing ONE dependency image under
two-week Azure-statistics traces — average latency per invocation-rate quartile and
required warm-up memory, WarmSwap vs Prebaking vs Baseline.

Driven by the checked-in ``benchmarks/scenarios/sharing_fig7.json`` spec
(single-worker engine) through the experiments CLI's ``run_file``. Runs twice:
once with the PAPER's measured cost numbers (Table 2; the faithful simulation)
and once with THIS machine's measured cold-start costs (from bench_coldstart
artifacts when present) — the measured variant is the same spec with its
``cost`` component overridden to ``scalar`` + measured kwargs."""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from benchmarks.common import (RESULTS_DIR, emit, save_json, scenario_path,
                               smoke_mode, validated_samples)


def _measured_cost_kwargs() -> Optional[Dict]:
    """Scalar-cost-model kwargs from this machine's bench_coldstart artifact,
    or None when it has not been produced yet."""
    path = os.path.join(RESULTS_DIR, "bench_coldstart.json")
    if not os.path.exists(path):
        return None
    rows = json.load(open(path))
    rnn = rows.get("rnn_serving")
    if not rnn:
        return None
    return {
        "cold_warmswap_s": rnn["cold_warmswap_s"],
        "cold_prebaking_s": rnn["cold_warmswap_s"] * 1.05,  # prebake ~ bulk restore
        "cold_baseline_s": rnn["cold_baseline_s"],
        "warm_s": rnn["warm_warmswap_s"],
    }


def run() -> Dict:
    from repro.experiments import run_file

    smoke = smoke_mode()
    out: Dict = {}
    variants: Dict[str, Optional[Dict]] = {"paper_costs": None}
    measured = _measured_cost_kwargs()
    if measured is not None:
        variants["measured_costs"] = {
            "cost.name": "scalar", "cost.kwargs": measured}

    for label, overrides in variants.items():
        result = run_file(scenario_path("sharing_fig7"), smoke=smoke,
                          overrides=overrides)
        res: Dict = {}
        for method, mr in result.methods.items():
            validated_samples(result.raw[method], f"sharing/{label}/{method}")
            res[method] = {
                "avg_latency_s": mr.avg_latency_s,
                "cold": mr.n_cold, "warm": mr.n_warm,
                "memory_mb": mr.memory_bytes / 1e6,
                "quartile_latency_s": mr.quartile_latency_s,
            }
            emit(f"sharing/{label}/{method}", mr.avg_latency_s * 1e6,
                 f"mem={mr.memory_bytes / 1e6:.0f}MB cold={mr.n_cold}")
        saving = result.summary["memory_saving_vs_prebaking"]
        speed = (res["prebaking"]["avg_latency_s"] /
                 max(res["warmswap"]["avg_latency_s"], 1e-12))
        res["memory_saving_vs_prebaking"] = saving
        res["latency_ratio_vs_prebaking"] = speed
        emit(f"sharing/{label}/headline", saving * 100,
             f"memory_saving_pct (paper: 88); warmswap x{speed:.2f} vs prebaking")
        out[label] = res
    save_json("bench_sharing", out)
    return out


if __name__ == "__main__":
    run()
