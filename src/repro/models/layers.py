"""Core neural layers: norms, rotary embeddings, MLPs, embeddings.

Pure-JAX, functional: every layer is ``apply(params, x, ...)`` with params created by
a matching ``init_*``. Activations run in ``dtype`` (default bf16), numerically
sensitive reductions (norms, softmax) in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def _he(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(scale_dim)).astype(dtype)


# ---------------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale) parameterization


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    if theta <= 0:  # sinusoidal-position archs (whisper) skip RoPE
        return x
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embedding table, computed on the fly (no params)."""
    half = d_model // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * math.log(10_000.0) / max(half - 1, 1))
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * scale[None, :]
    return jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], axis=-1)


def sinusoidal_position_at(pos, d_model: int) -> jax.Array:
    """Sinusoidal embedding row(s) for (traced) scalar or (B,) positions."""
    half = d_model // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * math.log(10_000.0) / max(half - 1, 1))
    angles = jnp.asarray(pos, jnp.float32)[..., None] * scale   # (..., half)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------------
# MLP (dense; MoE lives in moe.py)
# ---------------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": _he(k1, (d, f), d, dtype),
            "w_in": _he(k2, (d, f), d, dtype),
            "w_out": _he(k3, (f, d), f, dtype),
        }
    return {"w_in": _he(k1, (d, f), d, dtype), "w_out": _he(k2, (f, d), f, dtype)}


def mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(x @ params["w_gate"]) * (x @ params["w_in"])
        return h @ params["w_out"]
    return jax.nn.gelu(x @ params["w_in"], approximate=True) @ params["w_out"]


# ---------------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------------

def padded_vocab(cfg: ArchConfig, multiple: int = 512) -> int:
    """Vocab rounded up so the embedding table shards evenly on the model axis."""
    return ((cfg.vocab_size + multiple - 1) // multiple) * multiple


def init_embedding(key, cfg: ArchConfig, dtype) -> dict:
    v = padded_vocab(cfg)
    p = {"tok": _he(key, (v, cfg.d_model), cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["head"] = _he(jax.random.fold_in(key, 1), (cfg.d_model, v), cfg.d_model, dtype)
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = jnp.take(params["tok"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def unembed(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    table = params["head"] if "head" in params else params["tok"].T
    logits = (x @ table).astype(jnp.float32)
    if cfg.final_logit_softcap:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
