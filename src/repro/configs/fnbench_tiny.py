"""fnbench-tiny — paper-workload analogue (FunctionBench, Table 1).

A small dense LM standing in for the `rnn_serving`-class serverless workload used in
the paper's evaluation and sharing case study (Fig. 7). Small enough to run real
cold-start measurements on CPU; big enough that dependency loading dominates.
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="fnbench-tiny",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=1024,
    vocab_size=2048,
    head_dim=64,
    attn_pattern=(GLOBAL_ATTN,),
    mlp="swiglu",
    tie_embeddings=True,
    max_seq_len=4096,
)
