"""Assignment §Roofline: the per-(arch x shape x mesh) roofline table, read from the
dry-run artifacts (results/dryrun/*.json). Single-pod cells form the headline table;
multi-pod cells prove the 'pod' axis shards."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import RESULTS_DIR, emit, save_json

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_records(mesh: str = "single") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_table(recs: List[Dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
           f"{'bound':>7s} {'useful':>7s} {'MFU_ub':>7s} {'live_GB':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"{r.get('status', '?'):>9s}  {r.get('reason', r.get('error', ''))[:60]}")
            continue
        rt = r["roofline"]
        live = r.get("memory", {}).get("live_bytes", 0) / 1e9
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {rt['compute_s']:9.4f} "
            f"{rt['memory_s']:9.4f} {rt['collective_s']:9.4f} "
            f"{rt['bottleneck']:>7s} {rt['useful_flops_ratio']:7.2f} "
            f"{min(rt['mfu_upper_bound'], 99.0):7.3f} {live:8.2f}")
    return "\n".join(lines)


def run() -> Dict:
    out = {}
    for mesh in ("single", "multi"):
        recs = load_records(mesh)
        ok = [r for r in recs if r.get("status") == "ok"]
        failed = [r for r in recs if r.get("status") == "failed"]
        skipped = [r for r in recs if r.get("status") == "skipped"]
        out[mesh] = {"ok": len(ok), "failed": len(failed), "skipped": len(skipped),
                     "records": recs}
        for r in ok:
            rt = r["roofline"]
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 rt["step_lower_bound_s"] * 1e6,
                 f"bound={rt['bottleneck']} useful={rt['useful_flops_ratio']:.2f} "
                 f"mfu_ub={rt['mfu_upper_bound']:.3f}")
        if mesh == "single":
            print()
            print(format_table(recs))
            print()
    save_json("bench_roofline", {m: {k: v for k, v in d.items() if k != "records"}
                                 for m, d in out.items()})
    return out


if __name__ == "__main__":
    run()
