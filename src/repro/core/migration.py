"""Live migration of dependency images: the page server and restore policies.

Implements all four prototypes measured in the paper's Table 2:

  * ``BULK``          — WarmSwap bulk ("initiative") restore: on the first page fault
                        the page server streams ALL remaining pages in the background,
                        in layer order, overlapping with the function's own work.
  * ``LAZY``          — WarmSwap lazy restore: every fault fetches exactly the pages
                        of the faulting leaf, paying per-fault latency each time.
  * ``NO_PAGESERVER`` — copy the whole serialized image into the container, then
                        restore (the paper's "w/o Page Server" variant).
  * ``NO_LAZY``       — transfer every page through the page server *before*
                        execution begins (the paper's "w/o Lazy Migration" variant).

The page server models the provider-side transport: a local pool moves pages at
host-memcpy speed; a remote pool adds a configurable per-request latency and
bandwidth (DCN analogue). All timing is wall-clock measured, not simulated — the
sleeps only extend real copies when a remote link is being modelled.
"""
from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.image import ImageMetadata, LiveDependencyImage
from repro.core.pages import materialize_leaf


class RestorePolicy(enum.Enum):
    BULK = "bulk"
    LAZY = "lazy"
    NO_PAGESERVER = "no_pageserver"
    NO_LAZY = "no_lazy"


@dataclass
class LinkModel:
    """Transport between a page source and a function container.

    Used twice: by the *measured* migration path (``PageServer`` sleeps to
    extend real copies to the modelled speed) and by the *simulated*
    page-granular cost model (``core/costmodel.py``), so measured and
    simulated transfers share one parameterization.
    """
    latency_s: float = 0.0          # seconds per page-server request (RTT)
    bandwidth_bps: Optional[float] = None  # bytes/second; None = infinite
                                           #   (host memcpy, local pool)

    def delay_for(self, nbytes: int) -> float:
        """Seconds one request moving ``nbytes`` bytes takes on this link:
        ``latency_s`` + ``nbytes / bandwidth_bps`` (no bandwidth term when
        ``bandwidth_bps`` is ``None``)."""
        d = self.latency_s
        if self.bandwidth_bps:
            d += nbytes / self.bandwidth_bps
        return d


@dataclass
class MigrationStats:
    requests: int = 0
    pages_transferred: int = 0
    bytes_transferred: int = 0
    faults: int = 0
    fault_wait_s: float = 0.0        # time execution spent blocked on pages
    stream_s: float = 0.0            # background streaming wall time


class PageServer:
    """Provider-side server bound to one live image (paper §3.2: one per target)."""

    def __init__(self, image: LiveDependencyImage,
                 link: Optional[LinkModel] = None):
        self._image = image
        self._link = link if link is not None else LinkModel()
        self.stats = MigrationStats()
        self._lock = threading.Lock()

    @property
    def table(self):
        return self._image.metadata.page_table

    def fetch_pages(self, first_page: int, n_pages: int) -> np.ndarray:
        """Copy a page span out of the pool (the unit of transfer).

        Args:
            first_page: index of the first page in the image's store.
            n_pages: pages to copy.

        Returns:
            ``(n_pages, page_size)`` uint8 array — a real copy, delayed by
            the link model when one is configured. Stats (requests, pages,
            bytes) are updated under the server lock.
        """
        delay = self._link.delay_for(n_pages * self.table.page_size)
        if delay > 0:
            time.sleep(delay)
        pages = np.array(self._image.store[first_page: first_page + n_pages])  # real copy
        with self._lock:
            self.stats.requests += 1
            self.stats.pages_transferred += n_pages
            self.stats.bytes_transferred += pages.nbytes
        return pages


class RestoredImage:
    """Container-side restored dependency: leaves materialize through the chosen
    policy; ``wait_all()`` blocks until the image is fully resident."""

    def __init__(self, metadata: ImageMetadata, server: PageServer, treedef,
                 policy: RestorePolicy):
        self.metadata = metadata
        self.treedef = treedef
        self.policy = policy
        self._server = server
        self._table = metadata.page_table
        self._local: Dict[str, np.ndarray] = {}   # leaf key -> materialized array
        self._events: Dict[str, threading.Event] = {k: threading.Event()
                                                    for k in self._table.order}
        self._claim_lock = threading.Lock()
        self._claimed: set = set()         # leaves some thread is installing
        self._install_error: Optional[BaseException] = None
        self._stream_thread: Optional[threading.Thread] = None
        self._streaming_started = False
        self.stats = server.stats

    # -- internals ---------------------------------------------------------------
    def _claim(self, key: str) -> bool:
        """Check-and-set: exactly one thread wins the right to install ``key``.

        ``fault()`` and the background ``_stream_all`` thread can race on the
        same leaf; without the claim both would fetch its pages (double
        transfer, double-counted stats, concurrent ``_local`` writes)."""
        with self._claim_lock:
            if key in self._claimed:
                return False
            self._claimed.add(key)
            if key not in self._local and self._events[key].is_set():
                # stale marker from a failed install: re-arm so waiters block
                # on this retry instead of reading an absent leaf
                self._events[key].clear()
            return True

    def _install_leaf(self, key: str) -> None:
        """Fetch + materialize one leaf. Caller must have won ``_claim(key)``.

        On failure the claim is released and the event set anyway so waiters
        wake up and surface the error instead of blocking forever."""
        try:
            e = self._table.entries[key]
            pages = self._server.fetch_pages(e.first_page, e.n_pages)
            raw = pages.reshape(-1)[: e.nbytes]
            dt = np.dtype(e.dtype) if e.dtype != "bfloat16" else None
            if dt is None:
                import ml_dtypes
                dt = np.dtype(ml_dtypes.bfloat16)
            self._local[key] = np.frombuffer(raw.tobytes(),
                                             dtype=dt).reshape(e.shape)
        except BaseException as exc:
            with self._claim_lock:
                self._claimed.discard(key)
                self._install_error = exc
            self._events[key].set()
            raise
        self._events[key].set()

    def _ensure_leaf(self, key: str) -> None:
        """Make ``key`` resident: install it if we win the claim, else wait for
        the thread that did (and surface its failure, if any)."""
        if self._events[key].is_set() and key in self._local:
            return
        if self._claim(key):
            self._install_leaf(key)
            return
        while True:
            self._events[key].wait()
            if key in self._local:
                return
            with self._claim_lock:
                installing = key in self._claimed
            if not installing:
                # nobody is retrying: the last installer failed for good
                raise RuntimeError(
                    f"leaf {key!r} failed to install in another thread"
                ) from self._install_error
            # an in-flight retry holds the claim; its clear-on-claim re-armed
            # the event, so the next wait() blocks until it resolves

    def _stream_all(self, skip: Sequence[str] = ()) -> None:
        t0 = time.perf_counter()
        for key in self._table.order:      # layer order == execution order
            if key in skip or key in self._local:
                continue
            if self._claim(key):           # else: a concurrent fault owns it
                try:
                    self._install_leaf(key)
                except Exception:
                    # recorded in _install_error and the claim was released —
                    # keep streaming; wait_all()/fault() retry this leaf
                    continue
        self.stats.stream_s += time.perf_counter() - t0

    def _start_background_stream(self, skip: Sequence[str] = ()) -> None:
        with self._claim_lock:             # two first-faults must not both stream
            if self._streaming_started:
                return
            self._streaming_started = True
        self._stream_thread = threading.Thread(
            target=self._stream_all, args=(tuple(skip),), daemon=True)
        self._stream_thread.start()

    # -- the fault path ------------------------------------------------------------
    def fault(self, key: str) -> np.ndarray:
        """First touch of a leaf by the executing function (userfaultfd
        analogue).

        Args:
            key: leaf path in the image's page table.

        Returns:
            The materialized leaf array. Blocking time is accounted in
            ``stats.fault_wait_s`` (seconds); under ``BULK`` the first fault
            also kicks off the background stream for the remaining leaves.
        """
        if self._events[key].is_set() and key in self._local:
            return self._local[key]
        self.stats.faults += 1
        t0 = time.perf_counter()
        if self.policy == RestorePolicy.LAZY:
            self._ensure_leaf(key)
        elif self.policy == RestorePolicy.BULK:
            # first fault: fetch the faulting leaf synchronously, then stream the rest
            self._ensure_leaf(key)
            self._start_background_stream(skip=(key,))
        else:
            # NO_LAZY / NO_PAGESERVER should have pre-installed everything
            self._events[key].wait()
        self.stats.fault_wait_s += time.perf_counter() - t0
        return self._local[key]

    def wait_all(self) -> None:
        """Block until every leaf of the image is resident container-side
        (policy-appropriately: join the BULK stream and retry dead leaves,
        fault everything under LAZY, no-op for the eager policies)."""
        if self.policy == RestorePolicy.BULK:
            self._start_background_stream()
            if self._stream_thread is not None:
                self._stream_thread.join()
            # leaves claimed by concurrent faults finish outside the stream
            # thread, and a died-mid-stream thread leaves some unclaimed:
            # _ensure_leaf waits for live installers, retries dead ones
            # inline, and surfaces persistent failures instead of hanging
            for key in self._table.order:
                self._ensure_leaf(key)
        elif self.policy == RestorePolicy.LAZY:
            for key in self._table.order:
                self.fault(key)
        # NO_LAZY / NO_PAGESERVER are already resident

    def resident_fraction(self) -> float:
        """Fraction of leaves materialized container-side, in [0, 1] — the
        measured counterpart of the cost model's ``resident_pages`` knob."""
        return len(self._local) / max(len(self._events), 1)

    def as_pytree(self) -> Any:
        """Full parameter pytree (blocks until resident)."""
        self.wait_all()
        import jax
        leaves = [self._local[k] for k in self._table.tree_order]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


class MigrationClient:
    """Container-side orchestrator (paper Fig. 4c)."""

    def __init__(self, link: Optional[LinkModel] = None):
        self.link = link if link is not None else LinkModel()

    def migrate(
        self,
        image: LiveDependencyImage,
        policy: RestorePolicy = RestorePolicy.BULK,
    ) -> RestoredImage:
        """Step 1: metadata transfer. Step 2: page server attach. Step 3: restore
        skeleton (lazy) — pages move on fault / in the background."""
        # step 1 — metadata (small, synchronous; its cost is the communication phase)
        md = image.metadata
        delay = self.link.delay_for(md.nbytes())
        if delay > 0:
            time.sleep(delay)
        # step 2 — page server bound to the image
        server = PageServer(image, self.link)
        restored = RestoredImage(md, server, image.treedef, policy)
        # step 3 — policy-specific eager work
        if policy == RestorePolicy.NO_LAZY:
            restored._stream_all()            # all pages through the server, upfront
        elif policy == RestorePolicy.NO_PAGESERVER:
            # whole-image copy (one giant request), then local restore
            pages = server.fetch_pages(0, md.page_table.n_pages)
            for key in md.page_table.order:
                e = md.page_table.entries[key]
                raw = pages[e.first_page: e.first_page + e.n_pages].reshape(-1)[: e.nbytes]
                dt = np.dtype(e.dtype) if e.dtype != "bfloat16" else None
                if dt is None:
                    import ml_dtypes
                    dt = np.dtype(ml_dtypes.bfloat16)
                restored._local[key] = np.frombuffer(raw.tobytes(), dtype=dt).reshape(e.shape)
                restored._events[key].set()
            restored._claimed.update(md.page_table.order)
        return restored
