"""Policy × scenario tournament: every prewarm × placement cell vs the oracle.

The tournament is the repo's answer to "which online policy should a fleet
run, and how much is left on the table?" It drives the full prewarm ×
placement grid through the resumable sweep executor
(``experiments/executor.py``) on one scenario, scores every cell on the three
axes the paper trades off —

  * **P99 latency** (seconds) — the tail the user feels,
  * **byte-minutes** (idle instance residency × per-method idle bytes) — the
    memory bill keep-alive pays,
  * **cold-start count** — the events the whole system exists to avoid,

— attaches each cell's **oracle gap** (distance above the hindsight floor of
``core/oracle.py``; >= 0 whenever the dominance invariant holds, which CI
asserts), and marks the **Pareto front**: cells no other cell beats on all
three axes simultaneously. The hindsight keep-alive frontier rides along as
the "what would clairvoyance buy" reference curve for the same traces.

One tournament = one scenario spec. Disruption axes (worker churn, preemption
waves, eviction storms — ``core/disruption.py``) enter as different specs,
not extra grid axes, so each foul-weather variant is a first-class, separately
stored tournament (see ``benchmarks/scenarios/tournament.json`` and the
``python -m repro.experiments tournament`` CLI).

All cells share the scenario's traces (the grid only varies policy
components and the trace build is seeded), so one oracle per method prices
every cell — asserted here rather than assumed.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from repro.core.oracle import (OracleResult, idle_bytes_for,
                               keepalive_frontier, oracle_from_scenario)
from repro.core.scenario import Scenario
from repro.core.simulator import COST_MODELS
from repro.experiments.executor import SweepReport, run_sweep

#: Version of the serialized tournament report schema.
TOURNAMENT_SCHEMA_VERSION = 1


@dataclass(frozen=True)
class TournamentCell:
    """One (prewarm, placement, method) outcome with its oracle gap."""
    prewarm: str
    placement: str
    method: str
    total_latency_s: float
    p99_s: float
    byte_minutes: float
    n_cold: int
    n_warm: int
    oracle_gap_total_s: float
    oracle_gap_p99_s: float
    pareto: bool = False

    def objectives(self) -> Sequence[float]:
        """The minimized axes, in report order."""
        return (self.p99_s, self.byte_minutes, float(self.n_cold))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def pareto_front(cells: Sequence[TournamentCell]) -> List[bool]:
    """Non-dominated flags for ``cells`` on their :meth:`~TournamentCell.
    objectives` (all minimized): cell i is dominated when some cell j is <=
    on every axis and strictly < on at least one. O(n^2) — tournament grids
    are tens of cells."""
    objs = [c.objectives() for c in cells]
    flags = []
    for i, oi in enumerate(objs):
        dominated = any(
            all(a <= b for a, b in zip(oj, oi))
            and any(a < b for a, b in zip(oj, oi))
            for j, oj in enumerate(objs) if j != i)
        flags.append(not dominated)
    return flags


@dataclass
class TournamentReport:
    """Everything one tournament produced, JSON-serializable."""
    scenario: Dict[str, Any]
    methods: List[str]
    cells: List[TournamentCell]
    oracle: Dict[str, Dict[str, Any]]            # method -> OracleResult dict
    frontier: Dict[str, List[Dict[str, float]]]  # method -> keep-alive curve
    n_run: int = 0
    n_skipped: int = 0
    schema_version: int = TOURNAMENT_SCHEMA_VERSION

    def pareto_cells(self) -> List[TournamentCell]:
        return [c for c in self.cells if c.pareto]

    def min_gaps(self) -> Dict[str, Dict[str, float]]:
        """Per-method minimum gaps over the grid — the headline the bench
        artifact carries and ``tools/ci/check_bench.py`` gates (>= 0,
        finite). The minimum is the sharpest dominance witness: if any cell
        dipped below the floor, its method's min would go negative."""
        out: Dict[str, Dict[str, float]] = {}
        for m in self.methods:
            cells = [c for c in self.cells if c.method == m]
            out[m] = {
                "min_total_gap_s": min(c.oracle_gap_total_s for c in cells),
                "min_p99_gap_s": min(c.oracle_gap_p99_s for c in cells),
                "n_cells": len(cells),
            }
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "scenario": self.scenario,
            "methods": list(self.methods),
            "cells": [c.to_dict() for c in self.cells],
            "oracle": self.oracle,
            "frontier": self.frontier,
            "min_gaps": self.min_gaps(),
            "n_run": self.n_run,
            "n_skipped": self.n_skipped,
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)


def _grid_axes(prewarms: Optional[Sequence[str]],
               placements: Optional[Sequence[str]]) -> Dict[str, List[str]]:
    """The tournament grid: every registered prewarm × placement by default
    (resolved at call time so newly registered policies are swept
    automatically — the acceptance bar for the dominance gate)."""
    from repro.core.keepalive import PREWARM_POLICIES
    from repro.serving.scheduler import PLACEMENTS
    return {
        "prewarm.name": list(prewarms) if prewarms is not None
        else sorted(PREWARM_POLICIES.names()),
        "placement.name": list(placements) if placements is not None
        else sorted(PLACEMENTS.names()),
    }


def run_tournament(
    base: Scenario,
    *,
    smoke: bool = False,
    parallel: int = 1,
    store_path: Optional[str] = None,
    resume: bool = False,
    prewarms: Optional[Sequence[str]] = None,
    placements: Optional[Sequence[str]] = None,
    frontier_points: int = 9,
    progress=None,
) -> TournamentReport:
    """Run the policy tournament for one scenario.

    Args:
        base: the scenario (must use a fleet engine — the single-worker
            engine has no placement/prewarm surface to tournament).
        smoke: apply the spec's ``smoke_overrides`` (CI scale).
        parallel / store_path / resume / progress: passed through to
            :func:`repro.experiments.executor.run_sweep` (same resumable,
            serial==parallel-identical store semantics).
        prewarms / placements: restrict the grid (default: every
            registered key, sorted).
        frontier_points: points on the hindsight keep-alive curve.

    Returns:
        A :class:`TournamentReport` with every cell gap-scored against the
        hindsight floor and the Pareto front marked.
    """
    if base.engine == "single":
        raise ValueError("the tournament sweeps fleet policies; "
                         "engine='single' has none — use engine='fleet'")
    axes = _grid_axes(prewarms, placements)
    report: SweepReport = run_sweep(
        base, axes, smoke=smoke, parallel=parallel, store_path=store_path,
        resume=resume, progress=progress)

    # one oracle per method prices every cell: the grid varies only policy
    # components, so all cells share the scenario's (seeded) traces
    for p in report.points:
        for key in ("traces", "cost", "page_cost"):
            if p.spec.get(key) != report.points[0].spec.get(key):
                raise RuntimeError(
                    f"tournament cells disagree on {key!r}; one oracle "
                    f"cannot price them all")
    oracles: Dict[str, OracleResult] = oracle_from_scenario(base, smoke=smoke)

    scn = base.smoke_scaled() if smoke else base
    cost = COST_MODELS.build(scn.cost.name, **scn.cost.kwargs)
    cells: List[TournamentCell] = []
    for point, result in zip(report.points, report.results):
        spec = point.spec
        for m, mr in result["methods"].items():
            orc = oracles[m]
            cells.append(TournamentCell(
                prewarm=spec["prewarm"]["name"],
                placement=spec["placement"]["name"],
                method=m,
                total_latency_s=float(mr["total_latency_s"]),
                p99_s=float(mr["latency_percentiles_s"]["p99"]),
                byte_minutes=float(mr["instance_resident_min"])
                * idle_bytes_for(m, cost),
                n_cold=int(mr["n_cold"]),
                n_warm=int(mr["n_warm"]),
                oracle_gap_total_s=float(mr["total_latency_s"])
                - orc.total_latency_s,
                oracle_gap_p99_s=float(mr["latency_percentiles_s"]["p99"])
                - orc.percentile(99),
            ))
    # Pareto per method (cross-method comparison conflates cost models)
    flagged: List[TournamentCell] = []
    for m in scn.methods:
        group = [c for c in cells if c.method == m]
        for c, keep in zip(group, pareto_front(group)):
            flagged.append(dataclasses.replace(c, pareto=keep))
    from repro.core.traces import TRACE_GENERATORS
    traces = TRACE_GENERATORS.build(scn.traces.name, **scn.traces.kwargs)
    frontier = {
        m: [p.to_dict() for p in keepalive_frontier(
            traces, m, cost, n_points=frontier_points)]
        for m in scn.methods}
    return TournamentReport(
        scenario=scn.to_dict(),
        methods=list(scn.methods),
        cells=flagged,
        oracle={m: o.to_dict() for m, o in oracles.items()},
        frontier=frontier,
        n_run=report.n_run,
        n_skipped=report.n_skipped,
    )
