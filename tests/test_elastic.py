"""Elastic restart: a checkpoint written under one mesh resumes under a different
DP width with bit-comparable training trajectory (subprocess: 8 host devices).

This is the fault-tolerance contract at fleet scale: lose a pod -> restart the job
on fewer (or more) chips from the same checkpoint, with the deterministic pipeline
replaying the same global batches regardless of host/device layout.
"""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, tempfile
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointConfig, Checkpointer
from repro.configs import get_reduced
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.api import make_train_step
from repro.models.sharding import param_pspecs
from repro.models.transformer import init_params
from repro.optim import adamw_init

cfg = get_reduced("qwen3_1_7b", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
data = DataConfig(global_batch=8, seq_len=16, seed=11)
step_fn = make_train_step(cfg, remat="none", total_steps=12)
batch_at = lambda s: {k: jnp.asarray(v) for k, v in
                      SyntheticTokenPipeline.batch_at(cfg, data, s).items()}

def run_steps(params, opt, start, n, mesh):
    ns = lambda spec: NamedSharding(mesh, spec)
    p_specs = param_pspecs(cfg, params, mesh.shape["model"])
    with mesh:
        params = jax.device_put(params, jax.tree.map(ns, p_specs))
        opt = jax.device_put(opt, jax.tree.map(lambda _: ns(P()), opt))
        jitted = jax.jit(step_fn)
        for s in range(start, start + n):
            b = jax.device_put(batch_at(s),
                               {k: ns(P("data", *([None] * (v.ndim - 1))))
                                for k, v in batch_at(s).items()})
            params, opt, m = jitted(params, opt, b, jnp.int32(s))
    return jax.device_get(params), jax.device_get(opt), float(m["loss"])

devs = np.array(jax.devices())
mesh_wide = Mesh(devs.reshape(4, 2), ("data", "model"))    # DP=4
mesh_narrow = Mesh(devs.reshape(2, 4), ("data", "model"))  # DP=2, TP=4

params = init_params(jax.random.PRNGKey(3), cfg, jnp.float32)
opt = adamw_init(params)

# reference: 8 uninterrupted steps on the wide mesh
p_ref, o_ref, loss_ref = run_steps(params, opt, 0, 8, mesh_wide)

# elastic: 4 steps wide -> checkpoint -> restore -> 4 steps NARROW (different DP/TP)
with tempfile.TemporaryDirectory() as tmp:
    p1, o1, _ = run_steps(params, opt, 0, 4, mesh_wide)
    ck = Checkpointer(CheckpointConfig(tmp, async_save=False))
    ck.save(4, {"params": p1, "opt_state": o1})
    restored = ck.restore(None, {"params": p1, "opt_state": o1})
    p2, o2, loss_el = run_steps(restored["params"], restored["opt_state"],
                                4, 4, mesh_narrow)

err = max(float(jnp.max(jnp.abs(a - b)))
          for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)))
print(json.dumps({"max_param_err": err, "loss_ref": loss_ref, "loss_el": loss_el}))
"""


def test_elastic_restart_across_mesh_shapes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # fp reassociation across different collective layouts allows small drift
    assert out["max_param_err"] < 1e-3, out
    assert abs(out["loss_ref"] - out["loss_el"]) < 1e-3, out
