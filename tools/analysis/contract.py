"""Contract checker: docs/SIMULATION.md and docs/API.md *are* the spec —
this checker cross-validates them against the code, so docs and engines can
never silently diverge.

Two contracts, both repo-level (run once per invocation, not per file):

* **Event tie-break ranks** — the numbered table under "Event heap
  tie-break order" in ``docs/SIMULATION.md`` lists every ``EventKind`` with
  its integer rank. The checker parses the table and diffs it against the
  actual ``EventKind`` values in ``src/repro/core/events.py`` and the kind
  strings ``core/disruption.py`` schedules. Rules: ``rank-mismatch``
  (documented rank != code rank), ``undocumented-kind`` (code kind missing
  from the table), ``unknown-event-kind`` (table names a kind the enum does
  not define), ``disruption-kind`` (a disruption kind string with no
  matching ``EventKind``).

* **Result schema fields** — the ``methods.<m>`` row of the result-schema
  table in ``docs/API.md`` enumerates the unified per-method fields in
  backticks. The checker diffs that list against the ``MethodResult``
  dataclass in ``src/repro/core/scenario.py``. Rules: ``undocumented-field``
  (a dataclass field the table omits), ``unknown-field`` (the table names a
  field the dataclass lacks).

The module-level ``*_PATH`` constants exist so mutation tests can point the
checker at a deliberately-broken copy and prove it fires.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Set, Tuple

from tools.analysis.base import REPO_ROOT, rel_path
from tools.analysis.findings import Finding

CHECKER = "contract"

DOC_PATH = os.path.join(REPO_ROOT, "docs", "SIMULATION.md")
API_PATH = os.path.join(REPO_ROOT, "docs", "API.md")
EVENTS_PATH = os.path.join(REPO_ROOT, "src", "repro", "core", "events.py")
DISRUPTION_PATH = os.path.join(REPO_ROOT, "src", "repro", "core",
                               "disruption.py")
SCENARIO_PATH = os.path.join(REPO_ROOT, "src", "repro", "core",
                             "scenario.py")

#: The SIMULATION.md heading that opens the tie-break table.
_TIEBREAK_HEADING = "Event heap tie-break order"
#: ``apostrophe-free `NAME` (rank)`` entries inside the tie-break section.
_DOC_RANK = re.compile(r"`([A-Z][A-Z0-9_]*)`\s*\((\d+)\)")
#: The merged arrival stream is documented as ``*arrivals* (rank)``.
_DOC_ARRIVAL = re.compile(r"\*arrivals\*\s*\((\d+)\)")
#: Backticked snake_case field names in the API.md ``methods.<m>`` row.
_DOC_FIELD = re.compile(r"`([a-z][a-z0-9_]*)`")


def _finding(rule: str, path: str, line: int, message: str,
             scope: str = "", snippet: str = "",
             suggestion: str = "") -> Finding:
    return Finding(CHECKER, rule, rel_path(path), line, 0, message,
                   scope=scope, snippet=snippet, suggestion=suggestion)


# ----------------------------------------------------------- tie-break ranks

def _doc_ranks(md_text: str) -> Tuple[Dict[str, int], int]:
    """(kind name -> documented rank, section start line) from the tie-break
    section of SIMULATION.md. The section ends at the next ``## `` heading."""
    lines = md_text.splitlines()
    start = end = None
    for i, raw in enumerate(lines):
        if raw.startswith("## ") and _TIEBREAK_HEADING in raw:
            start = i
        elif start is not None and raw.startswith("## "):
            end = i
            break
    if start is None:
        return {}, 0
    section = "\n".join(lines[start:end])
    ranks = {name: int(rank) for name, rank in _DOC_RANK.findall(section)}
    m = _DOC_ARRIVAL.search(section)
    if m:
        ranks["ARRIVAL"] = int(m.group(1))
    return ranks, start + 1


def _code_ranks(py_text: str) -> Dict[str, int]:
    """``EventKind`` member -> integer value, from the events module AST."""
    tree = ast.parse(py_text)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "EventKind":
            out: Dict[str, int] = {}
            for stmt in node.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name) \
                        and isinstance(stmt.value, ast.Constant) \
                        and isinstance(stmt.value.value, int):
                    out[stmt.targets[0].id] = stmt.value.value
            return out
    return {}


def _disruption_kinds(py_text: str) -> List[str]:
    """The ``EVENT_KINDS`` kind strings disruption schedules may carry."""
    tree = ast.parse(py_text)
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name) and \
                node.targets[0].id == "EVENT_KINDS" and \
                isinstance(node.value, (ast.Tuple, ast.List)):
            return [e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)]
    return []


def _check_event_ranks() -> List[Finding]:
    findings: List[Finding] = []
    with open(DOC_PATH) as f:
        doc_text = f.read()
    with open(EVENTS_PATH) as f:
        events_text = f.read()
    doc, doc_line = _doc_ranks(doc_text)
    code = _code_ranks(events_text)

    if not doc:
        return [_finding(
            "unknown-event-kind", DOC_PATH, 1,
            f'no "{_TIEBREAK_HEADING}" table found in SIMULATION.md — the '
            f"tie-break contract is no longer documented",
            scope="tiebreak", snippet=_TIEBREAK_HEADING,
            suggestion="restore the numbered rank table (docs/SIMULATION.md)")]
    if not code:
        return [_finding(
            "undocumented-kind", EVENTS_PATH, 1,
            "no EventKind enum with integer members found in events.py",
            scope="EventKind", snippet="class EventKind",
            suggestion="keep the EventKind IntEnum parseable (plain NAME = "
                       "int assignments)")]

    for name in sorted(set(doc) & set(code)):
        if doc[name] != code[name]:
            findings.append(_finding(
                "rank-mismatch", DOC_PATH, doc_line,
                f"SIMULATION.md ranks {name} at {doc[name]} but "
                f"events.py defines {name} = {code[name]} — the documented "
                f"tie-break order no longer matches the engines",
                scope=f"tiebreak.{name}",
                snippet=f"{name} ({doc[name]}) != {name} = {code[name]}",
                suggestion="fix whichever side drifted; ranks [0, 3] are "
                           "pinned by tests/test_sim_properties.py"))
    for name in sorted(set(code) - set(doc)):
        findings.append(_finding(
            "undocumented-kind", EVENTS_PATH, 1,
            f"EventKind.{name} = {code[name]} is not in SIMULATION.md's "
            f"tie-break table — every rank is load-bearing and must be "
            f"documented",
            scope=f"EventKind.{name}", snippet=f"{name} = {code[name]}",
            suggestion="add the kind to the tie-break table in "
                       "docs/SIMULATION.md"))
    for name in sorted(set(doc) - set(code)):
        findings.append(_finding(
            "unknown-event-kind", DOC_PATH, doc_line,
            f"SIMULATION.md documents event kind {name} ({doc[name]}) but "
            f"EventKind does not define it",
            scope=f"tiebreak.{name}", snippet=f"{name} ({doc[name]})",
            suggestion="drop the stale table entry or restore the enum "
                       "member"))

    with open(DISRUPTION_PATH) as f:
        disruption_text = f.read()
    for kind in _disruption_kinds(disruption_text):
        if kind.upper() not in code:
            findings.append(_finding(
                "disruption-kind", DISRUPTION_PATH, 1,
                f"disruption kind string {kind!r} has no matching "
                f"EventKind.{kind.upper()} — schedules carrying it cannot "
                f"be injected into the event heap",
                scope=f"EVENT_KINDS.{kind}", snippet=f'"{kind}"',
                suggestion="keep EVENT_KINDS entries aligned with "
                           "EventKind member names (lowercased)"))
    return findings


# --------------------------------------------------------- result schema

def _doc_fields(md_text: str) -> Tuple[Set[str], int]:
    """Backticked field names in the ``methods.<m>`` table row of API.md."""
    for i, raw in enumerate(md_text.splitlines(), start=1):
        if raw.lstrip().startswith("| `methods.<m>`"):
            names = set(_DOC_FIELD.findall(raw))
            names.discard("m")      # from the `methods.<m>` key itself
            return names, i
    return set(), 0


def _dataclass_fields(py_text: str, class_name: str) -> Set[str]:
    tree = ast.parse(py_text)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            return {stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)}
    return set()


def _check_result_schema() -> List[Finding]:
    findings: List[Finding] = []
    with open(API_PATH) as f:
        api_text = f.read()
    with open(SCENARIO_PATH) as f:
        scenario_text = f.read()
    doc, doc_line = _doc_fields(api_text)
    fields = _dataclass_fields(scenario_text, "MethodResult")

    if not doc:
        return [_finding(
            "unknown-field", API_PATH, 1,
            "no `methods.<m>` row found in API.md's result-schema table",
            scope="methods", snippet="methods.<m>",
            suggestion="restore the unified per-method field row in "
                       "docs/API.md")]
    if not fields:
        return [_finding(
            "undocumented-field", SCENARIO_PATH, 1,
            "no MethodResult dataclass with annotated fields found in "
            "scenario.py", scope="MethodResult", snippet="class MethodResult",
            suggestion="keep MethodResult an annotated dataclass")]

    for name in sorted(fields - doc):
        findings.append(_finding(
            "undocumented-field", SCENARIO_PATH, 1,
            f"MethodResult.{name} is not in API.md's `methods.<m>` field "
            f"list — serialized results carry fields the schema doc does "
            f"not admit",
            scope=f"MethodResult.{name}", snippet=name,
            suggestion="add the field to the `methods.<m>` row in "
                       "docs/API.md"))
    for name in sorted(doc - fields):
        findings.append(_finding(
            "unknown-field", API_PATH, doc_line,
            f"API.md documents per-method field `{name}` but MethodResult "
            f"does not define it",
            scope=f"methods.{name}", snippet=name,
            suggestion="drop the stale field from the doc row or add it to "
                       "MethodResult"))
    return findings


def check_repo() -> List[Finding]:
    """All contract findings for the current tree (both contracts)."""
    return _check_event_ranks() + _check_result_schema()
