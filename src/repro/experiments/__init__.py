"""Experiments CLI: run declarative scenario specs from the command line.

    PYTHONPATH=src python -m repro.experiments run benchmarks/scenarios/degenerate.json
    PYTHONPATH=src python -m repro.experiments run spec.json --smoke --out out.json
    PYTHONPATH=src python -m repro.experiments sweep spec.json --axis n_workers=1,4,16
    PYTHONPATH=src python -m repro.experiments sweep spec.json --axis traces.kwargs.seed=0,1,2,3 \\
        --parallel 4 --store results/sweep.jsonl --resume
    PYTHONPATH=src python -m repro.experiments report results/sweep.jsonl
    PYTHONPATH=src python -m repro.experiments tournament benchmarks/scenarios/tournament.json --smoke
    PYTHONPATH=src python -m repro.experiments validate benchmarks/scenarios/*.json
    PYTHONPATH=src python -m repro.experiments smoke benchmarks/scenarios/*.json
    PYTHONPATH=src python -m repro.experiments list

Scenario schema, registry keys, and the result schema: ``docs/API.md``.
The programmatic mirrors (:func:`run_file`, :func:`sweep_file`) are what
``benchmarks/bench_fleet.py`` drives its cells through, so the CLI and the
benchmark suite share one code path. Sweeps run through the parallel,
resumable executor (:mod:`repro.experiments.executor`): ``--parallel N``
fans grid points across a process pool, ``--store`` streams each validated
result to an append-only JSONL store keyed by spec content hash, and
``--resume`` skips points the store already holds.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Mapping, Optional, Sequence

from repro.core.scenario import (Result, Scenario, run, sweep,
                                 validate_result)
from repro.experiments.executor import (SweepReport, run_sweep,
                                        summarize_store)


def run_file(path: str, *, smoke: bool = False,
             overrides: Optional[Mapping[str, Any]] = None) -> Result:
    """Load ``path``, apply optional dotted-path ``overrides``, run it, and
    schema-validate the result before returning it."""
    scn = Scenario.from_file(path)
    if overrides:
        scn = scn.with_overrides(overrides)
    result = run(scn, smoke=smoke)
    validate_result(result.to_dict())
    return result


def sweep_file(path: str, axes: Mapping[str, Sequence[Any]], *,
               smoke: bool = False) -> List[Result]:
    """Load ``path``, expand ``axes`` into the scenario grid, run every cell
    (each result schema-validated)."""
    base = Scenario.from_file(path)
    out = []
    for scn in sweep(base, axes):
        result = run(scn, smoke=smoke)
        validate_result(result.to_dict())
        out.append(result)
    return out


def _parse_value(text: str) -> Any:
    """One axis/override value: JSON literal when it parses, ``None`` for
    none/null, the raw string otherwise."""
    if text.lower() in ("none", "null"):
        return None
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return text


def parse_axis(text: str) -> Dict[str, List[Any]]:
    """``"n_workers=1,4,16"`` -> ``{"n_workers": [1, 4, 16]}``."""
    if "=" not in text:
        raise ValueError(f"--axis needs path=v1,v2,..., got {text!r}")
    path, _, values = text.partition("=")
    return {path.strip(): [_parse_value(v) for v in values.split(",")]}


def _print_result(result: Result, label: str = "") -> None:
    _print_result_dict(result.to_dict(), label)


def _print_result_dict(result: Mapping[str, Any], label: str = "") -> None:
    """Print one serialized result's per-method table + summary lines (the
    one output format; :func:`_print_result` delegates here)."""
    prefix = f"{label}: " if label else ""
    for m, mr in result["methods"].items():
        pct = mr["latency_percentiles_s"]
        print(f"{prefix}{m:9s} avg {mr['avg_latency_s'] * 1e3:9.2f} ms | "
              f"p99 {pct['p99'] * 1e3:9.2f} ms | cold {mr['n_cold']:6d} | "
              f"warm {mr['n_warm']:6d} | queued {mr['n_queued']:5d} | "
              f"mem {mr['memory_bytes'] / 1e6:8.1f} MB")
    for k, v in result["summary"].items():
        print(f"{prefix}summary.{k} = {v:.4f}")


def _write(path: Optional[str], payload) -> None:
    if not path:
        return
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}", file=sys.stderr)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments",
        description="Run declarative simulation scenarios (docs/API.md).")
    sub = ap.add_subparsers(dest="command", required=True)

    p_run = sub.add_parser("run", help="run one scenario spec")
    p_run.add_argument("spec")
    p_run.add_argument("--smoke", action="store_true",
                       help="apply the spec's smoke_overrides (CI scale)")
    p_run.add_argument("--out", default=None, help="write the result JSON here")
    p_run.add_argument("--set", action="append", default=[], metavar="PATH=V",
                       help="dotted-path override, e.g. n_workers=8")

    p_sweep = sub.add_parser("sweep", help="grid-expand axes and run each cell "
                             "(parallel + resumable via the executor)")
    p_sweep.add_argument("spec")
    p_sweep.add_argument("--axis", action="append", default=[], required=True,
                         metavar="PATH=V1,V2,...",
                         help="sweep axis, e.g. --axis n_workers=1,4,16")
    p_sweep.add_argument("--smoke", action="store_true")
    p_sweep.add_argument("--out", default=None,
                         help="write the list of result JSONs here")
    p_sweep.add_argument("--parallel", type=int, default=1, metavar="N",
                         help="worker processes (default 1 = in-process); "
                              "serial and parallel runs store identical "
                              "results")
    p_sweep.add_argument("--store", default=None, metavar="PATH",
                         help="append each validated result to this JSONL "
                              "results store (fsynced per point, keyed by "
                              "spec content hash)")
    p_sweep.add_argument("--resume", action="store_true",
                         help="skip grid points already in --store (e.g. "
                              "after a kill; a torn trailing line is "
                              "recomputed)")
    p_sweep.add_argument("--derive-seeds", action="store_true",
                         help="pin each point's traces.kwargs.seed to a "
                              "stable hash of its spec (independent "
                              "arrivals per point, reproducibly)")

    p_report = sub.add_parser(
        "report", help="summarize a results store back into the unified "
                       "result schema")
    p_report.add_argument("store")
    p_report.add_argument("--out", default=None,
                          help="write the summary JSON here")

    p_tour = sub.add_parser(
        "tournament", help="sweep every registered prewarm x placement over "
                           "one spec, score each cell against the hindsight "
                           "oracle, and mark the Pareto front")
    p_tour.add_argument("spec")
    p_tour.add_argument("--smoke", action="store_true",
                        help="apply the spec's smoke_overrides (CI scale)")
    p_tour.add_argument("--out", default=None,
                        help="write the tournament report JSON here")
    p_tour.add_argument("--parallel", type=int, default=1, metavar="N")
    p_tour.add_argument("--store", default=None, metavar="PATH",
                        help="JSONL results store for the underlying sweep "
                             "(resumable)")
    p_tour.add_argument("--resume", action="store_true",
                        help="skip grid points already in --store")

    p_val = sub.add_parser("validate", help="load + schema-check specs")
    p_val.add_argument("specs", nargs="+")

    p_smoke = sub.add_parser(
        "smoke", help="run specs at smoke scale and schema-check the results")
    p_smoke.add_argument("specs", nargs="+")

    sub.add_parser("list", help="list the component registries")

    args = ap.parse_args(argv)

    if args.command == "run":
        overrides = {}
        for item in args.set:
            if "=" not in item:
                raise ValueError(f"--set needs path=value, got {item!r}")
            path, _, value = item.partition("=")
            overrides[path.strip()] = _parse_value(value)
        result = run_file(args.spec, smoke=args.smoke, overrides=overrides)
        _print_result(result)
        _write(args.out, result.to_dict())
        return 0

    if args.command == "sweep":
        axes: Dict[str, List[Any]] = {}
        for item in args.axis:
            axes.update(parse_axis(item))
        def progress(done, total, point, skipped):
            verb = "skipped (stored)" if skipped else "done"
            print(f"[{done}/{total}] {point.name}: {verb}", file=sys.stderr)

        report = run_sweep(Scenario.from_file(args.spec), axes,
                           smoke=args.smoke, parallel=args.parallel,
                           store_path=args.store, resume=args.resume,
                           derive_seeds=args.derive_seeds,
                           progress=progress)
        for point, result in zip(report.points, report.results):
            _print_result_dict(result, label=point.name)
        if report.n_skipped:
            print(f"resumed: {report.n_skipped} stored point(s) skipped, "
                  f"{report.n_run} run", file=sys.stderr)
        _write(args.out, report.results)
        return 0

    if args.command == "report":
        summary = summarize_store(args.store)
        for row in summary["points"]:
            for m in ("warmswap", "prebaking", "baseline"):
                if m in row:
                    mr = row[m]
                    print(f"{row['name']}: {m:9s} "
                          f"avg {mr['avg_latency_s'] * 1e3:9.2f} ms | "
                          f"p99 {mr['p99_s'] * 1e3:9.2f} ms | "
                          f"cold {mr['n_cold']:6d} | "
                          f"mem {mr['memory_bytes'] / 1e6:8.1f} MB")
            for k, v in row["summary"].items():
                print(f"{row['name']}: summary.{k} = {v:.4f}")
        print(f"{summary['n_points']} point(s) in {args.store}"
              + (" (torn trailing line dropped)"
                 if summary["torn_tail_dropped"] else ""),
              file=sys.stderr)
        _write(args.out, summary)
        return 0

    if args.command == "tournament":
        from repro.experiments.tournament import run_tournament
        def progress(done, total, point, skipped):
            verb = "skipped (stored)" if skipped else "done"
            print(f"[{done}/{total}] {point.name}: {verb}", file=sys.stderr)

        rep = run_tournament(Scenario.from_file(args.spec), smoke=args.smoke,
                             parallel=args.parallel, store_path=args.store,
                             resume=args.resume, progress=progress)
        for c in rep.cells:
            star = "*" if c.pareto else " "
            print(f"{star} {c.method:9s} prewarm={c.prewarm:9s} "
                  f"placement={c.placement:12s} "
                  f"p99 {c.p99_s * 1e3:9.2f} ms | "
                  f"byte-min {c.byte_minutes / 1e9:9.3f} GB-min | "
                  f"cold {c.n_cold:6d} | "
                  f"gap {c.oracle_gap_total_s:9.3f} s")
        for m, g in rep.min_gaps().items():
            print(f"{m}: min total gap {g['min_total_gap_s']:.6f} s, "
                  f"min p99 gap {g['min_p99_gap_s']:.6f} s over "
                  f"{g['n_cells']} cells (* = Pareto front)",
                  file=sys.stderr)
        _write(args.out, rep.to_dict())
        return 0

    if args.command == "validate":
        for path in args.specs:
            scn = Scenario.from_file(path)
            scn.validate_components()      # incl. the placement registry key
            print(f"ok: {path} ({scn.name!r}, engine={scn.engine}, "
                  f"methods={scn.methods})")
        return 0

    if args.command == "smoke":
        for path in args.specs:
            result = run_file(path, smoke=True)
            print(f"ok: {path}")
            _print_result(result, label=result.scenario["name"])
        return 0

    if args.command == "list":
        from repro.core.costmodel import PAGE_COST_MODELS
        from repro.core.disruption import DISRUPTIONS
        from repro.core.keepalive import PREWARM_POLICIES
        from repro.core.simulator import COST_MODELS
        from repro.core.traces import TRACE_GENERATORS
        from repro.serving.scheduler import PLACEMENTS
        for reg in (TRACE_GENERATORS, COST_MODELS, PAGE_COST_MODELS,
                    PREWARM_POLICIES, PLACEMENTS, DISRUPTIONS):
            print(f"{reg.kind}: {', '.join(reg.names())}")
        print("workload: (import repro.core.workloads to list — pulls in "
              "the JAX model stack)")
        return 0

    raise AssertionError(f"unhandled command {args.command!r}")
