"""Declarative scenario API: JSON round-trip identity, registry did-you-mean
errors, schema-version gating, degenerate equivalence of run() with the legacy
simulate()/simulate_fleet() wrappers (incl. the 88 % memory-saving headline
and the paper's 2.2-3.2x dependency-loading band), sweep() grid expansion,
PlacementContext back-compat, and the experiments CLI."""
import glob
import json
import os

import numpy as np
import pytest

from repro.core.costmodel import PageCostModel
from repro.core.keepalive import KeepAlivePolicy
from repro.core.registry import Registry, UnknownComponentError
from repro.core.scenario import (METHODS, RESULT_SCHEMA_VERSION,
                                 SCHEMA_VERSION, ComponentSpec, Scenario,
                                 run, sweep, validate_result)
from repro.core.simulator import CostModel, simulate
from repro.core.fleet import FleetConfig, simulate_fleet
from repro.core.traces import generate_traces
from repro.serving.scheduler import PlacementContext, place_invocation

SCENARIOS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "scenarios")

CM = CostModel.paper_table2()


def _spec_path(name):
    return os.path.join(SCENARIOS_DIR, f"{name}.json")


def _short_scenario(**kw):
    """A fast-running fleet scenario (1-day horizon, 10 fns)."""
    base = dict(engine="fleet", n_workers=1, max_instances_per_fn=1,
                traces={"name": "azure",
                        "kwargs": {"n_functions": 10, "horizon_min": 24 * 60,
                                   "seed": 0}})
    base.update(kw)
    return Scenario(**base)


# ---------------------------------------------------------------------------------
# Generic Registry
# ---------------------------------------------------------------------------------

def test_registry_register_build_and_dict_reads():
    reg = Registry("widget")

    @reg.register("a")
    class A:
        def __init__(self, x=1):
            self.x = x

    assert "a" in reg and reg["a"] is A and list(reg) == ["a"]
    assert reg.build("a", x=5).x == 5
    assert reg.get("missing") is None


def test_registry_duplicate_name_rejected():
    reg = Registry("widget")
    reg.register("a", object())
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", object())


def test_registry_plain_instance_entries():
    reg = Registry("thing")
    obj = object()
    reg.register("x", obj)
    assert reg.build("x") is obj
    with pytest.raises(TypeError):
        reg.build("x", key=1)          # instances take no kwargs


def test_registry_unknown_key_did_you_mean():
    reg = Registry("widget")
    reg.register("histogram", object())
    with pytest.raises(UnknownComponentError) as ei:
        reg.resolve("histgram")
    msg = str(ei.value)
    assert "unknown widget" in msg and "histogram" in msg
    # the error satisfies both legacy except clauses
    assert isinstance(ei.value, ValueError) and isinstance(ei.value, KeyError)


# ---------------------------------------------------------------------------------
# Scenario spec: serialization + validation
# ---------------------------------------------------------------------------------

def test_round_trip_spec_dict_json_identity():
    scn = Scenario(
        name="rt", engine="fleet", methods=["warmswap", "prebaking"],
        traces={"name": "fleet", "kwargs": {"n_functions": 8, "n_images": 2}},
        cost={"name": "scalar", "kwargs": {
            "cold_warmswap_s": 1.0, "cold_prebaking_s": 1.1,
            "cold_baseline_s": 2.0, "warm_s": 0.01}},
        page_cost={"name": "default", "kwargs": {"fault_fraction": 0.1}},
        prewarm={"name": "histogram", "kwargs": {"percentile": 95.0}},
        placement="least_loaded", n_workers=3, worker_capacity_bytes=123,
        smoke_overrides={"traces.kwargs.n_functions": 2})
    assert Scenario.from_dict(scn.to_dict()) == scn
    assert Scenario.from_json(scn.to_json()) == scn
    # a full JSON round trip (dict -> text -> dict) is also identity
    assert Scenario.from_dict(json.loads(json.dumps(scn.to_dict()))) == scn


def test_unknown_scenario_field_did_you_mean():
    with pytest.raises(ValueError, match="n_workers"):
        Scenario.from_dict({"n_worker": 4})


def test_unknown_component_keys_fail_with_suggestions():
    # trace/cost/page-cost/prewarm keys fail at CONSTRUCTION (strict loading)
    with pytest.raises(UnknownComponentError, match="histogram"):
        _short_scenario(prewarm="histgram")
    with pytest.raises(UnknownComponentError, match="unknown trace generator"):
        _short_scenario(traces="nope")
    with pytest.raises(UnknownComponentError, match="unknown cost model"):
        _short_scenario(cost="paper_table3")
    with pytest.raises(UnknownComponentError, match="unknown page cost model"):
        _short_scenario(page_cost="degenerat")
    # placement resolves behind the repro.serving import: caught by
    # validate_components() and run(), not construction
    bad = _short_scenario(placement="afinity")
    with pytest.raises(UnknownComponentError, match="affinity"):
        bad.validate_components()
    with pytest.raises(UnknownComponentError, match="affinity"):
        run(bad)


def test_future_schema_version_rejected():
    d = _short_scenario().to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="newer than this build"):
        Scenario.from_dict(d)
    d["schema_version"] = "2"
    with pytest.raises(ValueError, match="positive integer"):
        Scenario.from_dict(d)


def test_engine_and_method_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        Scenario(engine="cluster")
    with pytest.raises(ValueError, match="warmswap"):
        Scenario(methods=["warmswp"])
    with pytest.raises(ValueError, match="at least one method"):
        Scenario(methods=[])


def test_single_engine_rejects_fleet_only_fields():
    """engine='single' must not silently ignore fleet shape: a spec asking
    for 8 workers + a prewarm policy on the single-worker engine is a
    mistake, not a request."""
    with pytest.raises(ValueError, match="n_workers"):
        Scenario(engine="single", n_workers=8)
    with pytest.raises(ValueError, match="prewarm"):
        Scenario(engine="single", prewarm="histogram")
    with pytest.raises(ValueError, match="worker_capacity_bytes"):
        Scenario(engine="single", worker_capacity_bytes=1 << 20)
    # defaults (and single-engine knobs) stay valid
    Scenario(engine="single", shared_images=4, keep_alive_min=5.0)
    # ...and symmetrically, the fleet engine rejects the single-only knob
    with pytest.raises(ValueError, match="shared_images"):
        Scenario(engine="fleet", shared_images=4)


def test_component_spec_coercion_and_bad_shapes():
    assert ComponentSpec.coerce("x") == ComponentSpec("x")
    assert ComponentSpec.coerce({"name": "x"}) == ComponentSpec("x", {})
    with pytest.raises(ValueError, match="unknown key"):
        ComponentSpec.coerce({"name": "x", "kwarg": {}})
    with pytest.raises(ValueError, match="needs a 'name'"):
        ComponentSpec.coerce({"kwargs": {}})
    with pytest.raises(TypeError):
        ComponentSpec.coerce(42)


def test_smoke_overrides_applied_by_run():
    scn = _short_scenario(
        methods=["warmswap"],
        smoke_overrides={"traces.kwargs.n_functions": 3})
    full = run(scn)
    small = run(scn, smoke=True)
    assert len(full.traces) == 10
    assert len(small.traces) == 3
    assert small.scenario["traces"]["kwargs"]["n_functions"] == 3


# ---------------------------------------------------------------------------------
# run(): degenerate equivalence with the legacy wrappers
# ---------------------------------------------------------------------------------

def test_run_matches_legacy_wrappers_exactly_with_headline():
    """The acceptance contract: run(Scenario.from_json(...)) reproduces the
    scalar engine's numbers exactly — including the ~88 % memory-saving
    headline — against both legacy wrappers."""
    scn = Scenario.from_file(_spec_path("degenerate"))
    res = run(Scenario.from_json(scn.to_json()))       # through JSON, on purpose
    traces = generate_traces(**scn.traces.kwargs)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    for method in METHODS:
        rs = simulate(traces, method, CM, KeepAlivePolicy(15.0))
        rf = simulate_fleet(traces, method, CM, deg)
        mr = res.methods[method]
        assert mr.total_latency_s == pytest.approx(rs.total_latency_s,
                                                   abs=1e-6)
        assert mr.total_latency_s == pytest.approx(rf.total_latency_s,
                                                   abs=1e-6)
        assert mr.memory_bytes == rs.memory_bytes == rf.memory_bytes
        assert (mr.n_cold, mr.n_warm) == (rs.n_cold, rs.n_warm)
    assert 0.85 < res.summary["memory_saving_vs_prebaking"] < 0.92


def test_run_page_degenerate_and_speedup_band():
    """The page-model spec reproduces the scalar engine under the degenerate
    link model, and the default page model's dependency-loading speedup lands
    in the paper's 2.2-3.2x band — both read off run()'s summary/raw."""
    res = run(Scenario.from_file(_spec_path("page_degenerate")), smoke=True)
    traces = res.traces
    for method in METHODS:
        rs = simulate(traces, method, CM, KeepAlivePolicy(15.0))
        assert res.raw[method].total_latency_s == pytest.approx(
            rs.total_latency_s, abs=1e-9)
        assert res.raw[method].memory_bytes == rs.memory_bytes
    # degenerate page model: infinite bandwidth, speedup collapses to the
    # scalar base ratio
    assert res.summary["dependency_loading_speedup"] == pytest.approx(
        CM.cold_baseline_s / CM.cold_warmswap_s)
    # the default page model reports the paper band through the same summary
    res_page = run(_short_scenario(methods=["warmswap"],
                                   page_cost="default"))
    band = res_page.summary["dependency_loading_speedup"]
    assert 2.2 <= band <= 3.2
    assert band == PageCostModel(cost=CM).dependency_loading_speedup()


def test_legacy_wrappers_return_native_result_types():
    traces = generate_traces(4, horizon_min=300, seed=1)
    rs = simulate(traces, "warmswap", CM)
    rf = simulate_fleet(traces, "warmswap", CM)
    assert type(rs).__name__ == "SimResult"
    assert type(rf).__name__ == "FleetResult"
    assert rs.n_invocations == rf.n_invocations == sum(
        len(t.arrivals_min) for t in traces)


def test_run_single_engine_and_shared_images():
    scn = Scenario(engine="single", shared_images=3, methods=["warmswap"],
                   traces={"name": "azure",
                           "kwargs": {"n_functions": 10,
                                      "horizon_min": 24 * 60, "seed": 0}})
    res = run(scn)
    assert res.methods["warmswap"].memory_bytes == (
        3 * CM.image_bytes + 10 * CM.metadata_bytes)


def test_component_kwargs_reach_factories():
    """Per-component kwargs flow from the spec into the built components:
    a 2x keep-alive window halves nothing but must change cold counts vs a
    tiny window on a sparse trace."""
    long_ka = run(_short_scenario(methods=["warmswap"], keep_alive_min=60.0))
    short_ka = run(_short_scenario(methods=["warmswap"], keep_alive_min=0.5))
    assert long_ka.methods["warmswap"].n_cold < \
        short_ka.methods["warmswap"].n_cold
    # prewarm kwargs: a histogram policy built with spec kwargs
    res = run(_short_scenario(
        methods=["warmswap"], max_instances_per_fn=None,
        prewarm={"name": "histogram", "kwargs": {"percentile": 90.0}}))
    assert res.methods["warmswap"].n_invocations > 0


# ---------------------------------------------------------------------------------
# sweep()
# ---------------------------------------------------------------------------------

def test_sweep_grid_expansion_and_names():
    base = _short_scenario(name="base")
    grid = sweep(base, {"n_workers": [1, 2],
                        "placement.name": ["affinity", "round_robin"]})
    assert len(grid) == 4
    assert [s.n_workers for s in grid] == [1, 1, 2, 2]
    assert {s.placement.name for s in grid} == {"affinity", "round_robin"}
    assert grid[0].name == "base[n_workers=1,placement.name=affinity]"
    assert base.n_workers == 1 and base.name == "base"     # base untouched
    assert sweep(base, {}) == [base]


def test_sweep_axis_values_reach_results():
    base = _short_scenario(methods=["warmswap"])
    results = [run(s) for s in sweep(base, {"n_workers": [1, 2]})]
    assert [r.scenario["n_workers"] for r in results] == [1, 2]


# ---------------------------------------------------------------------------------
# Result schema
# ---------------------------------------------------------------------------------

def test_result_dict_schema_and_validation():
    res = run(_short_scenario(methods=["warmswap", "prebaking"]))
    d = res.to_dict()
    assert d["result_schema_version"] == RESULT_SCHEMA_VERSION
    assert set(d["methods"]) == {"warmswap", "prebaking"}
    validate_result(d)                                  # no raise
    validate_result(json.loads(json.dumps(d)))          # survives JSON
    bad = json.loads(json.dumps(d))
    del bad["methods"]["warmswap"]["n_cold"]
    with pytest.raises(ValueError, match="missing"):
        validate_result(bad)
    bad2 = json.loads(json.dumps(d))
    bad2["methods"]["warmswap"]["avg_latency_s"] = float("nan")
    with pytest.raises(ValueError, match="non-finite"):
        validate_result(bad2)
    bad3 = json.loads(json.dumps(d))
    bad3["result_schema_version"] = RESULT_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="result_schema_version"):
        validate_result(bad3)


def test_checked_in_scenarios_load_and_smoke_validate():
    """Every shipped spec must parse; the fast ones must run at smoke scale
    and produce schema-valid results (CI runs ALL of them via the CLI)."""
    paths = sorted(glob.glob(os.path.join(SCENARIOS_DIR, "*.json")))
    assert len(paths) >= 10
    for path in paths:
        scn = Scenario.from_file(path)
        assert scn.name == os.path.splitext(os.path.basename(path))[0]
    for name in ("degenerate", "sharing_fig7", "multi_tenant"):
        res = run(Scenario.from_file(_spec_path(name)), smoke=True)
        validate_result(res.to_dict())


# ---------------------------------------------------------------------------------
# PlacementContext back-compat shim
# ---------------------------------------------------------------------------------

def test_place_invocation_context_equals_legacy_kwargs():
    load = {0: 5, 1: 0, 2: 3}.__getitem__
    ctx = PlacementContext(load=load, has_warm=lambda w: w == 0,
                           holds_image=lambda w: w == 2)
    assert place_invocation([0, 1, 2], ctx) == place_invocation(
        [0, 1, 2], load=load, has_warm=lambda w: w == 0,
        holds_image=lambda w: w == 2) == 0
    assert place_invocation([0, 1, 2], PlacementContext(load=load)) == 1
    with pytest.raises(TypeError, match="not both"):
        place_invocation([0, 1], ctx, load=load)
    with pytest.raises(TypeError):
        place_invocation([0, 1])


def test_custom_placement_strategy_pluggable():
    """A strategy registered at runtime is addressable from FleetConfig by
    its key — the engine never enumerates strategies."""
    from repro.serving.scheduler import PLACEMENTS

    name = "always_last_test_only"
    if name not in PLACEMENTS:
        @PLACEMENTS.register(name)
        def _always_last():
            def place(workers, ctx):
                return workers[-1]
            return place

    traces = generate_traces(4, horizon_min=300, seed=1)
    r = simulate_fleet(traces, "warmswap", CM,
                       FleetConfig(n_workers=3, placement=name))
    assert r.per_worker[0]["served"] == r.per_worker[1]["served"] == 0
    assert r.per_worker[2]["served"] == r.n_invocations


# ---------------------------------------------------------------------------------
# Experiments CLI
# ---------------------------------------------------------------------------------

def test_cli_run_writes_schema_valid_result(tmp_path, capsys):
    from repro.experiments import main

    out = tmp_path / "res.json"
    rc = main(["run", _spec_path("degenerate"), "--smoke", "--out", str(out)])
    assert rc == 0
    validate_result(json.load(open(out)))
    assert "memory_saving_vs_prebaking" in capsys.readouterr().out


def test_cli_sweep_and_validate_and_list(tmp_path, capsys):
    from repro.experiments import main, parse_axis

    assert parse_axis("n_workers=1,4,16") == {"n_workers": [1, 4, 16]}
    assert parse_axis("max_instances_per_fn=none,2") == \
        {"max_instances_per_fn": [None, 2]}
    assert parse_axis("placement.name=affinity,round_robin") == \
        {"placement.name": ["affinity", "round_robin"]}
    with pytest.raises(ValueError):
        parse_axis("no-equals-sign")

    out = tmp_path / "sweep.json"
    rc = main(["sweep", _spec_path("degenerate"), "--smoke",
               "--axis", "n_workers=1,2", "--out", str(out)])
    assert rc == 0
    cells = json.load(open(out))
    assert [c["scenario"]["n_workers"] for c in cells] == [1, 2]
    for c in cells:
        validate_result(c)

    assert main(["validate", _spec_path("degenerate"),
                 _spec_path("prewarm")]) == 0
    assert main(["list"]) == 0
    text = capsys.readouterr().out
    assert "placement strategy" in text and "prewarm policy" in text

    # validate rejects unknown component keys, including placement's
    bad = tmp_path / "bad.json"
    spec = Scenario.from_file(_spec_path("degenerate")).to_dict()
    spec["placement"]["name"] = "afinity"
    bad.write_text(json.dumps(spec))
    with pytest.raises(UnknownComponentError, match="affinity"):
        main(["validate", str(bad)])
    with pytest.raises(ValueError, match="--set"):
        main(["run", _spec_path("degenerate"), "--smoke", "--set",
              "n_workers"])


def test_cli_set_override(capsys):
    from repro.experiments import main

    rc = main(["run", _spec_path("degenerate"), "--smoke",
               "--set", "methods=[\"warmswap\"]",
               "--set", "traces.kwargs.n_functions=4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "warmswap" in out and "prebaking" not in out
