"""Int8 gradient compression with error feedback (distributed-optimization trick).

Before the data-parallel all-reduce, gradients are quantized to int8 with a per-tensor
fp32 scale; the quantization residual is carried in an error-feedback buffer and added
back next step (guarantees the compressed SGD trajectory tracks the exact one).
This cuts DP all-reduce bytes 2x (bf16) / 4x (fp32) — a direct lever on the
collective roofline term, selectable via ``TrainLoopConfig.grad_compression``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8           # int8 quantization


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q(g, ef):
    g = g.astype(jnp.float32) + ef
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    residual = g - q.astype(jnp.float32) * scale
    return q, scale, residual


def compress_gradients(grads, error_feedback) -> Tuple[dict, dict]:
    """Returns ({'q': int8 tree, 'scale': fp32 tree}, new_error_feedback)."""
    qs = jax.tree.map(_q, grads, error_feedback)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda t: isinstance(t, tuple))
    scale = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[2], qs, is_leaf=lambda t: isinstance(t, tuple))
    return {"q": q, "scale": scale}, resid


def decompress_gradients(compressed) -> dict:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        compressed["q"], compressed["scale"])
