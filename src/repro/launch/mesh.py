"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so importing this
module never touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax import to
obtain placeholder devices; smoke tests and benchmarks see the real single device.

Topology (TPU v5e pods):
  * single-pod: (16, 16)    = ('data', 'model')          — 256 chips
  * multi-pod:  (2, 16, 16) = ('pod', 'data', 'model')   — 512 chips, 'pod' is the
    DCN-connected data-parallel axis; 'model' stays inside a pod (ICI-only), which is
    why the parameter shardings in models/sharding.py never touch 'pod'.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — the dry-run "
            "launcher must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_local_mesh(model_axis: int = 1) -> Mesh:
    """Whatever devices exist, as ('data', 'model') — for tests and CPU drivers."""
    devices = np.asarray(jax.devices())
    data_axis = len(devices) // model_axis
    return Mesh(devices[: data_axis * model_axis].reshape(data_axis, model_axis),
                ("data", "model"))
