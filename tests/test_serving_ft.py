"""Serving engine (continuous batching), fleet scheduler (stragglers), and
fault-tolerance (supervisor rollback determinism, pool-based replica recovery)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig
from repro.configs import get_reduced
from repro.core import DependencyManager, RestorePolicy
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.models.api import make_train_step
from repro.models.transformer import decode_step, forward, init_params
from repro.optim import adamw_init
from repro.runtime import InjectedFailure, ReplicaSet, SupervisorConfig, TrainSupervisor
from repro.serving import FleetScheduler, SchedulerConfig, ServeConfig, ServingEngine

CFG = get_reduced("qwen3_1_7b")
PARAMS = init_params(jax.random.PRNGKey(0), CFG, jnp.float32)


def _greedy_reference(prompt, n):
    toks = jnp.asarray(prompt[None])
    logits, _, st = forward(PARAMS, toks, CFG, make_state=True, state_len=64,
                            logits_slice=1)
    seq = [int(jnp.argmax(logits[0, -1, : CFG.vocab_size]))]
    for _ in range(n - 1):
        lg, st = decode_step(PARAMS, st, jnp.asarray([[seq[-1]]], jnp.int32), CFG)
        seq.append(int(jnp.argmax(lg[0, : CFG.vocab_size])))
    return seq


def test_continuous_batching_matches_single_stream():
    eng = ServingEngine(CFG, PARAMS, ServeConfig(max_slots=3, max_seq_len=64,
                                                 max_new_tokens=5))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, n) for n in (4, 9, 6, 11, 5)]
    rids = [eng.submit(p) for p in prompts]
    eng.run_until_done()
    assert len(eng.completed) == len(prompts)
    for rid, prompt in zip(rids, prompts):
        assert eng.completed[rid].tokens == _greedy_reference(prompt, 5)


def test_slot_reuse_is_clean():
    """A slot that served request A must not leak cache state into request B."""
    eng = ServingEngine(CFG, PARAMS, ServeConfig(max_slots=1, max_seq_len=64,
                                                 max_new_tokens=4))
    rng = np.random.default_rng(1)
    p1, p2 = rng.integers(0, CFG.vocab_size, 8), rng.integers(0, CFG.vocab_size, 13)
    r1 = eng.submit(p1)
    r2 = eng.submit(p2)
    eng.run_until_done()
    assert eng.completed[r1].tokens == _greedy_reference(p1, 4)
    assert eng.completed[r2].tokens == _greedy_reference(p2, 4)


def test_scheduler_straggler_redispatch():
    # quarantine_after_flags=1: after one flag the replica's EWMA keeps it from
    # being re-picked, so a second flag never arrives under healthy alternatives
    sched = FleetScheduler(SchedulerConfig(straggler_factor=2.0, min_observations=2,
                                           quarantine_after_flags=1))
    for n in ("a", "b"):
        sched.register_replica(n)
    lat = {"a": [0.01] * 4 + [0.5, 0.5, 0.01], "b": [0.012] * 12}
    idx = {"a": 0, "b": 0}

    def execute(name, item):
        v = lat[name][min(idx[name], len(lat[name]) - 1)]
        idx[name] += 1
        return v

    sched.run([object()] * 10, execute)
    assert any(e[0] == "redispatch" for e in sched.dispatch_log)
    assert sched.health["a"].quarantined           # repeated straggler quarantined
    assert sched.pick() == "b"


def test_supervisor_failure_recovery_is_deterministic():
    """With deterministic data replay, a run interrupted by failures converges to
    the SAME final params as an uninterrupted run."""
    cfg = CFG
    data = DataConfig(global_batch=2, seq_len=16, seed=5)
    step_fn = jax.jit(make_train_step(cfg, remat="none", total_steps=20))
    batch_at = lambda s: {k: jnp.asarray(v) for k, v in
                          SyntheticTokenPipeline.batch_at(cfg, data, s).items()}

    def run(fail):
        with tempfile.TemporaryDirectory() as tmp:
            sup = TrainSupervisor(
                SupervisorConfig(checkpoint_every=4,
                                 checkpoint=CheckpointConfig(tmp, async_save=False)),
                step_fn, batch_at)
            p = init_params(jax.random.PRNGKey(9), cfg, jnp.float32)
            o = adamw_init(p)
            fails = {6: InjectedFailure("node died"),
                     9: InjectedFailure("nan storm")} if fail else None
            p, o, hist = sup.run(p, o, 0, 12, fail_at=fails)
            return p, sup.restores

    p_clean, r0 = run(False)
    p_faulty, r1 = run(True)
    assert r0 == 0 and r1 == 2
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_faulty)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_replica_failure_pool_recovery():
    """Node-failure recovery via the dependency pool (re-warm) works and the
    replacement replica serves identical results."""
    mgr = DependencyManager()
    mgr.register_image("base", CFG.name,
                       lambda: init_params(jax.random.PRNGKey(0), CFG, jnp.float32))

    def make_engine(manager, image_id, cfg, method):
        if method == "warmswap":
            return ServingEngine.from_pool(manager, image_id, cfg,
                                           ServeConfig(max_slots=1, max_seq_len=64,
                                                       max_new_tokens=4))
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)  # cold load
        return ServingEngine(cfg, params, ServeConfig(max_slots=1, max_seq_len=64,
                                                      max_new_tokens=4))

    rs = ReplicaSet(mgr, "base", CFG, make_engine, n_replicas=2)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, CFG.vocab_size, 6)
    ref = _greedy_reference(prompt, 4)

    rs.kill("replica-0")
    assert "replica-0" not in rs.replicas
    dt = rs.recover("replica-0", method="warmswap")
    assert dt > 0
    eng = rs.replicas["replica-0"]
    rid = eng.submit(prompt)
    eng.run_until_done()
    assert eng.completed[rid].tokens == ref
