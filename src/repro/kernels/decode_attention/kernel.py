"""Pallas TPU flash-decode: one query token against a long KV cache.

Decode attention is memory-bound (the whole KV cache streams HBM->VMEM once per
step), so the kernel's job is to keep that stream dense: grid ``(B, Hkv, n_kv_blocks)``
with the kv axis innermost/sequential, online-softmax scratch carried in VMEM, and all
``g = H/Hkv`` grouped query heads processed per kv block (GQA means each cache block
is reused g times from VMEM — the only reuse available in decode).

Ring-buffer semantics are handled by the ``valid`` mask input, computed in O(S) by the
wrapper from slot positions — the kernel itself is layout-agnostic.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -2.0e38
DEFAULT_BLOCK_K = 512


def _decode_kernel(
    q_ref,                   # (1, 1, g, d)
    k_ref, v_ref,            # (1, 1, bk, d)
    valid_ref,               # (1, bk) int32 (bool as int)
    o_ref,                   # (1, 1, g, d)
    m_scratch, l_scratch, acc_scratch,
    *,
    scale: float,
    softcap: Optional[float],
    n_kv_blocks: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)              # (g, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    valid = valid_ref[0] != 0                        # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (g, bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scratch[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scratch[...] = alpha * l_scratch[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scratch[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scratch[...] /
                       jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,            # (B, H, d)
    k_cache: jax.Array,      # (B, Hkv, S, d)
    v_cache: jax.Array,
    valid: jax.Array,        # (S,) bool
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, H, d = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    block_k = max(8, min(block_k, S))
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    Sp = k_cache.shape[2]
    n_kv = Sp // block_k
    qg = q.reshape(B, Hkv, g, d)
    valid_i = valid.astype(jnp.int32)[None]          # (1, Sp)

    kernel = functools.partial(_decode_kernel, scale=scale, softcap=softcap,
                               n_kv_blocks=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid_i)
    return out.reshape(B, H, d)
