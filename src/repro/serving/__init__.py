from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.serving.scheduler import FleetScheduler, SchedulerConfig
from repro.serving.state_utils import state_extract, state_reset_slot, state_splice

__all__ = [
    "Request", "ServeConfig", "ServingEngine",
    "FleetScheduler", "SchedulerConfig",
    "state_extract", "state_reset_slot", "state_splice",
]
