"""Spec/registry cross-validator: scenario JSON vs the live component
registries, without running a simulation.

A checked-in ``benchmarks/scenarios/*.json`` can silently rot: a component
gets renamed, a factory kwarg is dropped, a required argument grows. The
runtime catches that only when the spec is *executed* — this checker catches
it at lint time by resolving every component ``{name, kwargs}`` against the
registered factory's ``inspect.signature``:

* ``unknown-component`` — the name is not in the field's registry
  (did-you-mean suggestions included);
* ``unknown-kwarg`` — a kwarg the factory does not accept (did-you-mean
  against the real parameter names);
* ``missing-required-arg`` — a required factory parameter the spec does not
  supply (kwargs injected by the runtime — ``cost`` for page-cost models —
  are accounted for, config.SPEC_INJECTED_KWARGS);
* ``invalid-spec`` — everything ``Scenario.from_dict`` rejects (unknown
  fields, bad engine/methods, cross-field constraints), surfaced without
  running anything.

Importing the registries executes module-level registration only — no
simulation runs. Only files that *look like* scenario specs (JSON objects
carrying a scenario marker field) are checked, so arbitrary JSON artifacts
pass through untouched.
"""
from __future__ import annotations

import inspect
import json
import os
import sys
from typing import Any, Dict, List, Mapping, Optional, Tuple

from tools.analysis import config
from tools.analysis.base import REPO_ROOT, rel_path
from tools.analysis.findings import Finding

CHECKER = "spec-registry"

#: A JSON object is treated as a scenario spec iff it has one of these keys.
#: ``traces`` only counts when shaped like a component (string or mapping):
#: golden test fixtures carry raw trace *arrays* under the same key.
_SCENARIO_MARKERS = ("schema_version", "engine", "traces")

#: spec field -> how to find its registry (module, attribute).
_REGISTRY_SOURCES = {
    "traces": ("repro.core.traces", "TRACE_GENERATORS"),
    "cost": ("repro.core.simulator", "COST_MODELS"),
    "page_cost": ("repro.core.costmodel", "PAGE_COST_MODELS"),
    "prewarm": ("repro.core.keepalive", "PREWARM_POLICIES"),
    "placement": ("repro.serving.scheduler", "PLACEMENTS"),
    "disruption": ("repro.core.disruption", "DISRUPTIONS"),
}


def _ensure_importable() -> None:
    src = os.path.join(REPO_ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)


def _registries() -> Dict[str, Any]:
    """Field -> live Registry. Imports are module-level registration only."""
    import importlib
    _ensure_importable()
    out = {}
    for fld, (mod, attr) in _REGISTRY_SOURCES.items():
        out[fld] = getattr(importlib.import_module(mod), attr)
    return out


def _did_you_mean(name: str, choices) -> str:
    import difflib
    close = difflib.get_close_matches(str(name), list(choices), n=3)
    return f" — did you mean {', '.join(map(repr, close))}?" if close else ""


def _factory_signature(obj: Any) -> Optional[inspect.Signature]:
    try:
        return inspect.signature(obj)
    except (TypeError, ValueError):
        return None


def looks_like_scenario(data: Any) -> bool:
    if not isinstance(data, Mapping):
        return False
    if "schema_version" in data or "engine" in data:
        return True
    return isinstance(data.get("traces"), (str, Mapping))


def check_file(path: str) -> List[Finding]:
    rel = rel_path(path)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [Finding(CHECKER, "invalid-spec", rel, 1, 0,
                        f"unreadable JSON: {e}",
                        suggestion="fix the JSON syntax")]
    if not looks_like_scenario(data):
        return []
    return check_spec(data, rel)


def _check_stream_misuse(data: Mapping[str, Any], rel: str) -> List[Finding]:
    """Static rules for ``traces.kwargs.stream: true`` specs.

    The runtime rejects (or silently papers over) these only when the spec
    is *executed*; surfacing them at lint time keeps a stale checked-in spec
    from passing the analysis job and then failing (or lying) in smoke:

    * ``stream-with-disruption`` — disruption schedules are built against
      the trace horizon, which a stream only knows after its last chunk;
      ``scenario.run`` raises on this combination.
    * ``stream-with-single-engine`` — the single-worker engine always
      materializes streams, so the spec's out-of-core claim is false
      advertising; set ``stream: false`` (bit-identical by contract).
    """
    findings: List[Finding] = []
    traces = data.get("traces")
    if not isinstance(traces, Mapping):
        return findings
    kwargs = traces.get("kwargs")
    if not isinstance(kwargs, Mapping) or not kwargs.get("stream"):
        return findings
    if data.get("disruption") is not None:
        findings.append(Finding(
            CHECKER, "stream-with-disruption", rel, 1, 0,
            "traces.kwargs.stream=true cannot be combined with a disruption "
            "component: disruption schedules are built against the trace "
            "horizon, which a stream only knows after its last chunk",
            scope="traces", snippet="stream: true + disruption",
            suggestion="set traces.kwargs.stream=false (bit-identical by "
                       "contract) or drop the disruption component"))
    if data.get("engine") == "single":
        findings.append(Finding(
            CHECKER, "stream-with-single-engine", rel, 1, 0,
            "traces.kwargs.stream=true with engine 'single': the "
            "single-worker engine materializes streams, so the spec gains "
            "nothing and misstates its memory profile",
            scope="traces", snippet="stream: true + engine: single",
            suggestion="set traces.kwargs.stream=false, or use the fleet "
                       "engine to consume chunks natively"))
    return findings


def check_spec(data: Mapping[str, Any], rel: str) -> List[Finding]:
    findings: List[Finding] = []
    registries = _registries()

    for fld, registry in registries.items():
        comp = data.get(fld)
        if comp is None:
            continue
        if isinstance(comp, str):
            name, kwargs = comp, {}
        elif isinstance(comp, Mapping):
            unknown_keys = set(comp) - {"name", "kwargs"}
            if unknown_keys or "name" not in comp:
                findings.append(Finding(
                    CHECKER, "invalid-spec", rel, 1, 0,
                    f"component '{fld}' must be a string or "
                    f"{{'name', 'kwargs'}}, got keys {sorted(comp)}",
                    scope=fld,
                    snippet=json.dumps(comp, sort_keys=True)[:120],
                    suggestion="use {\"name\": ..., \"kwargs\": {...}}"))
                continue
            name, kwargs = comp["name"], dict(comp.get("kwargs") or {})
        else:
            findings.append(Finding(
                CHECKER, "invalid-spec", rel, 1, 0,
                f"component '{fld}' must be a string or dict, "
                f"got {type(comp).__name__}", scope=fld,
                snippet=repr(comp)[:120]))
            continue

        if name not in registry:
            findings.append(Finding(
                CHECKER, "unknown-component", rel, 1, 0,
                f"unknown {fld} component {name!r} (registered: "
                f"{sorted(registry.names())})"
                + _did_you_mean(name, registry.names()),
                scope=f"{fld}.{name}", snippet=f"{fld}: {name}",
                suggestion="use a registered key, or register the component"))
            continue

        factory = registry.get(name)
        sig = _factory_signature(factory)
        if sig is None:
            continue
        injected = config.SPEC_INJECTED_KWARGS.get(fld, set())
        params = sig.parameters
        takes_var_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                           for p in params.values())
        accepted = {pname for pname, p in params.items()
                    if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}

        if not takes_var_kw:
            for kw in sorted(set(kwargs) - accepted):
                findings.append(Finding(
                    CHECKER, "unknown-kwarg", rel, 1, 0,
                    f"{fld} component {name!r} got unknown kwarg {kw!r} "
                    f"(accepts: {sorted(accepted - injected)})"
                    + _did_you_mean(kw, accepted - injected),
                    scope=f"{fld}.{name}", snippet=f"{name}({kw}=...)",
                    suggestion="drop or rename the kwarg to match the "
                               "factory signature"))
        for pname, p in params.items():
            if p.kind in (p.VAR_POSITIONAL, p.VAR_KEYWORD):
                continue
            if p.default is inspect.Parameter.empty and \
                    pname not in kwargs and pname not in injected:
                findings.append(Finding(
                    CHECKER, "missing-required-arg", rel, 1, 0,
                    f"{fld} component {name!r} requires {pname!r} and the "
                    f"spec does not provide it", scope=f"{fld}.{name}",
                    snippet=f"{name}(...{pname}...)",
                    suggestion=f"add {pname!r} to the component's kwargs"))

    findings.extend(_check_stream_misuse(data, rel))

    # cross-field/schema validation — only when the structured pass is clean,
    # so one root cause doesn't surface twice
    if not findings:
        try:
            _ensure_importable()
            from repro.core.scenario import Scenario
            Scenario.from_dict(data)
        except (TypeError, ValueError) as e:
            findings.append(Finding(
                CHECKER, "invalid-spec", rel, 1, 0, str(e),
                snippet=str(data.get("name", "")),
                suggestion="fix the spec to satisfy Scenario.from_dict"))
    return findings
