"""counter-flow checker: every fleet counter has a law, a writer, and a
projection. The shipped tree must be clean; seeded mutations (a dropped
increment, an undeclared field, a severed projection) must each be caught."""
from tools.analysis import config, counter_flow
from tools.analysis.__main__ import main


def rules(findings):
    return sorted(f.rule for f in findings)


def test_shipped_tree_is_clean():
    assert counter_flow.check_repo() == []


def test_dropped_increment_is_caught(tmp_path, monkeypatch):
    with open(counter_flow.FLEET_PATH) as f:
        src = f.read()
    assert "res.worker_failures += 1" in src
    p = tmp_path / "fleet.py"
    p.write_text(src.replace("res.worker_failures += 1", "pass"))
    monkeypatch.setattr(counter_flow, "FLEET_PATH", str(p))
    fs = counter_flow.check_repo()
    assert any(f.rule == "unmutated-counter"
               and "worker_failures" in f.message for f in fs)


def test_dropped_increment_fails_the_cli(tmp_path, monkeypatch, capsys):
    with open(counter_flow.FLEET_PATH) as f:
        src = f.read()
    p = tmp_path / "fleet.py"
    p.write_text(src.replace("res.requeued += len(pending)", "pass"))
    monkeypatch.setattr(counter_flow, "FLEET_PATH", str(p))
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "--no-baseline"]) == 1
    assert "counter-flow/unmutated-counter" in capsys.readouterr().out


def test_undeclared_field_is_caught(monkeypatch):
    pruned = {k: v for k, v in config.FLEET_COUNTERS.items()
              if k != "requeued"}
    monkeypatch.setattr(config, "FLEET_COUNTERS", pruned)
    fs = counter_flow.check_repo()
    assert any(f.rule == "undeclared-counter"
               and "requeued" in f.message for f in fs)


def test_stale_declaration_is_caught(monkeypatch):
    augmented = dict(config.FLEET_COUNTERS)
    augmented["phantom_counter"] = ("service-conservation",
                                    "phantom_counter")
    monkeypatch.setattr(config, "FLEET_COUNTERS", augmented)
    fs = counter_flow.check_repo()
    assert any(f.rule == "unknown-counter"
               and "phantom_counter" in f.message for f in fs)


def test_unknown_law_is_caught(monkeypatch):
    augmented = dict(config.FLEET_COUNTERS)
    augmented["n_cold"] = ("law-of-the-jungle", "n_cold")
    monkeypatch.setattr(config, "FLEET_COUNTERS", augmented)
    fs = counter_flow.check_repo()
    assert any(f.rule == "unknown-law"
               and "law-of-the-jungle" in f.message for f in fs)


def test_severed_projection_is_caught(tmp_path, monkeypatch):
    with open(counter_flow.SCENARIO_PATH) as f:
        src = f.read()
    needle = "requeued=r.requeued if is_fleet else 0,"
    assert needle in src
    p = tmp_path / "scenario.py"
    p.write_text(src.replace(needle, ""))
    monkeypatch.setattr(counter_flow, "SCENARIO_PATH", str(p))
    fs = counter_flow.check_repo()
    assert any(f.rule == "unprojected-counter"
               and "'requeued'" in f.message for f in fs)


def test_missing_projection_function_is_caught(tmp_path, monkeypatch):
    p = tmp_path / "scenario.py"
    p.write_text("class MethodResult:\n    method: str\n")
    monkeypatch.setattr(counter_flow, "SCENARIO_PATH", str(p))
    fs = counter_flow.check_repo()
    assert any(f.rule == "unprojected-counter"
               and "_method_result" in f.message for f in fs)


def test_every_declared_law_exists():
    for name, (law, _target) in config.FLEET_COUNTERS.items():
        assert law in config.COUNTER_LAWS, (name, law)
