"""Decode-state surgery for continuous batching.

The batched decode state stores the batch dimension at axis 1 for unit-stacked leaves
(``unit``/``cross``: (n_units, B, ...)) and axis 0 elsewhere (``rem`` leaves, ``pos``).
These helpers splice a single request's state into / out of a batch slot and reset
slots, using the same path rules as the sharding layer.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _batch_axis(path) -> int:
    kp = jax.tree_util.keystr(path)
    return 1 if (kp.startswith("['unit']") or "cross" in kp) else 0


def state_splice(batched: Any, single: Any, slot: int) -> Any:
    """Insert ``single`` (batch size 1) into ``batched`` at ``slot``."""
    def ins(path, b, s):
        ax = _batch_axis(path)
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype), slot, axis=ax) \
            if b.ndim > 0 else s
    return jax.tree_util.tree_map_with_path(ins, batched, single)


def state_extract(batched: Any, slot: int) -> Any:
    """Extract a single-request view (batch size 1) from ``batched``."""
    def ext(path, b):
        ax = _batch_axis(path)
        return jax.lax.dynamic_slice_in_dim(b, slot, 1, axis=ax) if b.ndim > 0 else b
    return jax.tree_util.tree_map_with_path(ext, batched)


def state_reset_slot(batched: Any, slot: int) -> Any:
    """Clear one slot: caches emptied (k_pos = -1), states zeroed, pos = 0."""
    def rst(path, b):
        if b.ndim == 0:
            return b
        ax = _batch_axis(path)
        idx = [slice(None)] * b.ndim
        idx[ax] = slot
        fill = -1 if (b.dtype == jnp.int32 and "k_pos" in jax.tree_util.keystr(path)) \
            else 0
        return b.at[tuple(idx)].set(fill)
    return jax.tree_util.tree_map_with_path(rst, batched)
