"""Disruption semantics: worker churn / preemption / eviction storms through
the fleet engine (core/disruption.py + core/fleet.py), pinned against the
normative contract in docs/SIMULATION.md, "Oracle and disruption semantics":

  * a failed worker's in-flight and queued requests are re-queued with their
    ORIGINAL arrival times — time lost to the failure lands in queue wait;
  * under disruption ``n_cold + n_warm`` counts service STARTS
    (``n_invocations + requeued``), and the disruption counters mirror the
    schedule that was applied;
  * a schedule that leaves every worker dead with requests parked raises
    (silently dropping arrivals would corrupt every latency percentile);
  * cache flushes evict pools (cluster tier included) but never kill warm
    instances — only later cold starts pay;
  * the vectorized engine declares disruption out of its fast-path domain
    (``fast_path_reason``) and falls back to the event engine, so both
    engines agree bit-for-bit;
  * ``runtime.fault_tolerance.replay_disruption`` applies the same schedule
    artifact to a live ReplicaSet (worker i -> "replica-i").
"""
import numpy as np
import pytest

from repro.core.disruption import (DISRUPTIONS, DisruptionEvent,
                                   DisruptionSchedule)
from repro.core.events import EventKind
from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import fast_path_reason, simulate_fleet_vec
from repro.core.scenario import Scenario, run
from repro.core.simulator import CostModel, method_cold_latency_s
from repro.core.traces import Trace, generate_fleet_traces
from repro.runtime import ReplicaSet
from repro.runtime.fault_tolerance import replay_disruption

CM = CostModel.paper_table2()

ENGINES = [("fleet", _simulate_fleet_impl), ("fleet_vec", simulate_fleet_vec)]


def _trace(fn, arrivals, image_id=0):
    return Trace(fn, 1.0, np.asarray(arrivals, np.float64), image_id=image_id)


# ---------------------------------------------------------------------------------
# Event kinds and schedule construction
# ---------------------------------------------------------------------------------

def test_disruption_event_ranks_pinned():
    """Disruption kinds are appended AFTER the fair-weather ranks — at one
    timestamp a failure fires after arrivals, so a request arriving at the
    failure instant is admitted first and then displaced (deterministic)."""
    assert [EventKind.WORKER_FAIL, EventKind.WORKER_RECOVER,
            EventKind.CACHE_FLUSH] == [4, 5, 6]
    assert EventKind.KEEPALIVE_EXPIRY < EventKind.WORKER_FAIL


def test_schedule_validates_and_sorts():
    with pytest.raises(ValueError, match="unknown disruption event kind"):
        DisruptionEvent(1.0, "meteor", 0)
    with pytest.raises(ValueError, match=">= 0"):
        DisruptionEvent(-1.0, "worker_fail", 0)
    with pytest.raises(ValueError, match="targets worker 3"):
        DisruptionSchedule([DisruptionEvent(1.0, "worker_fail", 3)],
                           n_workers=2)
    # cache_flush is fleet-wide: its worker index is not validated
    DisruptionSchedule([DisruptionEvent(1.0, "cache_flush")], n_workers=2)
    sch = DisruptionSchedule(
        [DisruptionEvent(5.0, "worker_recover", 0),
         DisruptionEvent(1.0, "worker_fail", 0)], n_workers=1)
    assert [e.t_min for e in sch.events] == [1.0, 5.0]
    assert len(sch) == 2 and bool(sch)
    assert not DisruptionSchedule([], n_workers=1)


def test_factories_are_deterministic_and_bounded():
    a = DISRUPTIONS.build("churn", n_workers=4, horizon_min=1440.0, seed=3)
    b = DISRUPTIONS.build("churn", n_workers=4, horizon_min=1440.0, seed=3)
    assert a.events == b.events
    assert a.events != DISRUPTIONS.build("churn", n_workers=4,
                                         horizon_min=1440.0, seed=4).events
    fails = [e for e in a.events if e.kind == "worker_fail"]
    recovers = [e for e in a.events if e.kind == "worker_recover"]
    assert len(fails) == len(recovers) >= 1
    assert all(e.t_min < 1440.0 for e in fails)      # recoveries may overrun

    pre = DISRUPTIONS.build("preempt", n_workers=4, horizon_min=100.0,
                            workers=[1, 3], downtime_min=5.0)
    assert sorted(e.worker for e in pre.events if e.kind == "worker_fail") \
        == [1, 3]
    assert {e.t_min for e in pre.events} == {50.0, 55.0}

    st = DISRUPTIONS.build("storm", n_workers=2, horizon_min=100.0,
                           first_at_frac=0.25, count=3)
    assert [e.kind for e in st.events] == ["cache_flush"] * 3
    assert st.events[0].t_min == 25.0
    with pytest.raises(ValueError, match="count"):
        DISRUPTIONS.build("storm", n_workers=2, horizon_min=100.0, count=0)
    with pytest.raises(ValueError, match="period_min"):
        DISRUPTIONS.build("storm", n_workers=2, horizon_min=100.0,
                          period_min=-1.0)


# ---------------------------------------------------------------------------------
# Requeue semantics (both engines)
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("engine,impl", ENGINES)
def test_requeue_preserves_original_arrival_time(engine, impl):
    """One request, killed mid-service, re-served after recovery: its latency
    sample is EXACTLY (recovery delay as queue wait) + (a fresh pool-miss
    cold start) — the documented accounting, float-for-float."""
    traces = [_trace(0, [0.0])]
    sch = DisruptionSchedule(
        [DisruptionEvent(0.005, "worker_fail", 0),
         DisruptionEvent(0.01, "worker_recover", 0)], n_workers=1)
    r = impl(traces, "warmswap", CM,
             FleetConfig(n_workers=1, disruption=sch))
    want_wait = (0.01 - 0.0) * 60.0
    want_svc = method_cold_latency_s(CM, "warmswap") + CM.image_revive_s
    assert r.n_invocations == 1
    assert r.requeued == 1, engine
    assert (r.worker_failures, r.worker_recoveries) == (1, 1)
    # service starts: the killed first attempt + the post-recovery restart
    assert (r.n_cold, r.n_warm) == (2, 0), engine
    assert float(r.queue_wait_s[0]) == want_wait, engine
    assert float(r.latency_samples_s[0]) == want_wait + want_svc, engine
    assert r.n_queued == 1


@pytest.mark.parametrize("method", ["warmswap", "prebaking", "baseline"])
def test_service_start_accounting_under_churn(method):
    """The books balance under heavy churn: every requeue adds exactly one
    extra service start, waits stay non-negative, samples stay finite."""
    traces = generate_fleet_traces(n_functions=6, horizon_min=240.0, seed=11,
                                   n_images=2, total_rate_per_min=6.0)
    sch = DISRUPTIONS.build("churn", n_workers=3, horizon_min=240.0, seed=2,
                            mean_uptime_min=30.0, downtime_min=5.0)
    assert sch.events, "churn drew no failures — the case tests nothing"
    r = _simulate_fleet_impl(traces, method, CM,
                             FleetConfig(n_workers=3, disruption=sch))
    assert r.n_cold + r.n_warm == r.n_invocations + r.requeued
    assert r.worker_failures >= 1
    assert r.worker_failures == r.worker_recoveries
    assert (r.queue_wait_s >= 0.0).all()
    assert np.isfinite(r.latency_samples_s).all()
    assert (r.latency_samples_s >= r.queue_wait_s).all()
    assert r.instance_resident_min >= 0.0


@pytest.mark.parametrize("engine,impl", ENGINES)
def test_unrecovered_schedule_raises(engine, impl):
    """Every worker dead with requests parked and no recovery coming is a
    spec bug, not a silent drop."""
    traces = [_trace(0, [0.0, 1.0])]
    sch = DisruptionSchedule([DisruptionEvent(0.5, "worker_fail", 0)],
                             n_workers=1)
    with pytest.raises(RuntimeError, match="orphaned"):
        impl(traces, "warmswap", CM, FleetConfig(n_workers=1, disruption=sch))


@pytest.mark.parametrize("engine,impl", ENGINES)
def test_schedule_shape_mismatch_raises(engine, impl):
    traces = [_trace(0, [0.0])]
    sch = DisruptionSchedule([DisruptionEvent(1.0, "worker_fail", 0)],
                             n_workers=2)
    with pytest.raises(ValueError, match="rebuild it with the fleet's shape"):
        impl(traces, "warmswap", CM, FleetConfig(n_workers=4, disruption=sch))


# ---------------------------------------------------------------------------------
# Eviction storms
# ---------------------------------------------------------------------------------

def test_cache_flush_spares_warm_instances_but_costs_later_colds():
    """A flush between two warm-window arrivals changes nothing for the warm
    serve (instances survive eviction); the post-expiry cold start pays the
    revive the flush destroyed."""
    traces = [_trace(0, [0.0, 1.0, 20.0])]
    flush = DisruptionSchedule([DisruptionEvent(0.5, "cache_flush")],
                               n_workers=1)
    fair = _simulate_fleet_impl(traces, "warmswap", CM,
                                FleetConfig(n_workers=1, keep_alive_min=15.0))
    hit = _simulate_fleet_impl(
        traces, "warmswap", CM,
        FleetConfig(n_workers=1, keep_alive_min=15.0, disruption=flush))
    # setup seeds the pool, so fair weather never misses
    assert (fair.n_cold, fair.n_warm, fair.pool_misses) == (2, 1, 0)
    assert (hit.n_cold, hit.n_warm) == (2, 1)          # instances survived
    assert hit.cache_flushes == 1
    assert hit.pool_misses == 1                         # t=20 cold re-misses
    assert hit.total_latency_s == pytest.approx(
        fair.total_latency_s + CM.image_revive_s)
    assert hit.requeued == 0 and hit.worker_failures == 0


# ---------------------------------------------------------------------------------
# Engine agreement and determinism
# ---------------------------------------------------------------------------------

_DISRUPTION_KWARGS = {
    "churn": {"seed": 5, "mean_uptime_min": 60.0, "downtime_min": 10.0},
    "preempt": {"at_frac": 0.5, "kill_frac": 0.5, "downtime_min": 15.0},
    "storm": {"first_at_frac": 0.25, "count": 2},
}

_COUNTERS = ("n_invocations", "n_cold", "n_warm", "n_queued", "pool_misses",
             "evictions", "requeued", "worker_failures", "worker_recoveries",
             "cache_flushes", "prewarm_spawns", "prewarm_hits",
             "max_concurrent_instances")


@pytest.mark.parametrize("name", sorted(_DISRUPTION_KWARGS))
def test_vec_engine_identical_under_disruption(name):
    """Disruption forces the vectorized engine onto its exact event-engine
    fallback — declared via ``fast_path_reason`` — so results agree
    bit-for-bit, counters included."""
    traces = generate_fleet_traces(n_functions=8, horizon_min=240.0, seed=9,
                                   n_images=3, total_rate_per_min=8.0)
    sch = DISRUPTIONS.build(name, n_workers=4, horizon_min=240.0,
                            **_DISRUPTION_KWARGS[name])
    assert sch.events
    fc = lambda: FleetConfig(n_workers=4, disruption=sch)
    reason = fast_path_reason(traces, "warmswap", CM, fc())
    assert reason is not None and "disruption" in reason
    ref = _simulate_fleet_impl(traces, "warmswap", CM, fc())
    vec = simulate_fleet_vec(traces, "warmswap", CM, fc())
    for fld in ("latency_samples_s", "queue_wait_s", "sample_fn"):
        assert np.array_equal(getattr(ref, fld), getattr(vec, fld)), fld
    for fld in _COUNTERS:
        assert getattr(ref, fld) == getattr(vec, fld), fld
    assert ref.total_latency_s == vec.total_latency_s
    assert ref.instance_resident_min == vec.instance_resident_min


def test_empty_schedule_keeps_fast_path_domain():
    """An empty schedule is fair weather: it must not push a config off the
    vectorized fast path (whatever that verdict is without disruption)."""
    traces = generate_fleet_traces(n_functions=4, horizon_min=60.0, seed=1)
    empty = DisruptionSchedule([], n_workers=1)
    assert fast_path_reason(traces, "warmswap", CM,
                            FleetConfig(n_workers=1, disruption=empty)) == \
        fast_path_reason(traces, "warmswap", CM, FleetConfig(n_workers=1))


def test_disruption_runs_are_deterministic():
    traces = generate_fleet_traces(n_functions=6, horizon_min=240.0, seed=4,
                                   total_rate_per_min=5.0)
    sch = DISRUPTIONS.build("churn", n_workers=2, horizon_min=240.0, seed=1,
                            mean_uptime_min=40.0, downtime_min=5.0)
    fc = lambda: FleetConfig(n_workers=2, disruption=sch)
    a = _simulate_fleet_impl(traces, "warmswap", CM, fc())
    b = _simulate_fleet_impl(traces, "warmswap", CM, fc())
    assert np.array_equal(a.latency_samples_s, b.latency_samples_s)
    assert a.total_latency_s == b.total_latency_s
    assert a.requeued == b.requeued


# ---------------------------------------------------------------------------------
# Scenario wiring
# ---------------------------------------------------------------------------------

def test_scenario_disruption_reaches_the_engine():
    scn = Scenario(engine="fleet", methods=["warmswap"], n_workers=2,
                   traces={"name": "fleet",
                           "kwargs": {"n_functions": 6, "horizon_min": 240.0,
                                      "seed": 2, "total_rate_per_min": 5.0}},
                   disruption={"name": "storm", "kwargs": {"count": 2}})
    res = run(scn)
    assert res.raw["warmswap"].cache_flushes == 2


def test_single_engine_rejects_disruption():
    with pytest.raises(ValueError, match="engine='single'"):
        Scenario(engine="single", disruption={"name": "storm"})


def test_checked_in_churn_spec_actually_churns():
    """The shipped churn spec is not a no-op at smoke scale: its schedule
    fires and requests get displaced."""
    scn = Scenario.from_file("benchmarks/scenarios/churn.json")
    res = run(scn, smoke=True)
    for m, r in res.raw.items():
        assert r.worker_failures >= 1, m
        assert r.worker_failures == r.worker_recoveries, m
        assert r.n_cold + r.n_warm == r.n_invocations + r.requeued, m


# ---------------------------------------------------------------------------------
# Live ReplicaSet replay (runtime/fault_tolerance.py)
# ---------------------------------------------------------------------------------

def test_replay_disruption_against_replica_set():
    """The same schedule artifact the simulator replays drives a live
    ReplicaSet: worker i maps to replica-i, fails kill, recovers re-warm
    (and are the only events returned), flushes are a no-op."""
    built = []

    def make_engine(manager, image_id, cfg, method):
        built.append(method)
        return object()

    rs = ReplicaSet(None, "img", None, make_engine, n_replicas=2)
    assert built == ["warmswap", "warmswap"]
    sch = DisruptionSchedule(
        [DisruptionEvent(1.0, "worker_fail", 0),
         DisruptionEvent(2.0, "cache_flush"),
         DisruptionEvent(3.0, "worker_recover", 0)], n_workers=2)
    events = replay_disruption(rs, sch, method="warmswap")
    assert [e.replica for e in events] == ["replica-0"]
    assert events[0].method == "warmswap" and events[0].seconds >= 0.0
    assert set(rs.replicas) == {"replica-0", "replica-1"}
    assert built == ["warmswap"] * 3                   # flush built nothing
