"""WarmSwap core: live dependency sharing for serverless model serving.

Public API:
  * pages       — pytree <-> page-store encoding (the memory-page layer)
  * image       — LiveDependencyImage / build_image (the shareable unit)
  * pool        — DependencyManager (provider-side pool, RAM+disk tiers, LRU)
  * migration   — PageServer + MigrationClient, 4 restore policies (Table 2)
  * registry    — FunctionRegistry (endpoints = image ref + private handler)
  * coldstart   — ColdStartOrchestrator with per-phase timers (Figs. 3/6)
  * keepalive   — E_cs(λ) arrival math (§2.2) + pluggable pre-warm policies
  * traces      — Azure-statistics / Zipf fleet trace generation (§4.5)
  * simulator   — single-worker, queue-accurate simulation (Fig. 7)
  * events      — typed discrete-event core (heap + tie-break contract)
  * fleet       — multi-worker discrete-event fleet simulation: concurrency,
                  queueing, placement, capacity, latency percentiles
  * workloads   — FunctionBench-analogue suite (Table 1)
"""
from repro.core.coldstart import ColdStartConfig, ColdStartOrchestrator, PhaseTimes
from repro.core.costmodel import PageCostModel
from repro.core.events import Event, EventKind, EventQueue
from repro.core.fleet import FleetConfig, FleetResult, simulate_fleet
from repro.core.image import ImageMetadata, LiveDependencyImage, build_image
from repro.core.keepalive import (BytesAwareKeepAlive, HistogramKeepAlive,
                                  KeepAlivePolicy, PrewarmPolicy, SpesPrewarm,
                                  expected_cold_starts)
from repro.core.migration import LinkModel, MigrationClient, PageServer, RestorePolicy
from repro.core.pages import PageTable, materialize, paginate
from repro.core.pool import CapacityLedger, ClusterImageCache, DependencyManager
from repro.core.registry import FunctionRegistry
from repro.core.simulator import CostModel, memory_saving_fraction, simulate
from repro.core.traces import generate_fleet_traces, generate_traces

__all__ = [
    "ColdStartConfig", "ColdStartOrchestrator", "PhaseTimes",
    "Event", "EventKind", "EventQueue",
    "FleetConfig", "FleetResult", "simulate_fleet",
    "ImageMetadata", "LiveDependencyImage", "build_image",
    "KeepAlivePolicy", "expected_cold_starts",
    "PrewarmPolicy", "HistogramKeepAlive", "SpesPrewarm", "BytesAwareKeepAlive",
    "LinkModel", "MigrationClient", "PageServer", "RestorePolicy",
    "PageTable", "materialize", "paginate",
    "CapacityLedger", "ClusterImageCache", "DependencyManager",
    "FunctionRegistry",
    "CostModel", "PageCostModel", "memory_saving_fraction", "simulate",
    "generate_traces", "generate_fleet_traces",
]
