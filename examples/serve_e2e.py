"""End-to-end serving driver (the paper's kind: serving) — the main example.

A provider fleet: one shared dependency image, two serving replicas brought up by
live migration, continuous-batched decode traffic, a simulated node failure, and
pool-based recovery — timed at every step.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 24]
"""
import argparse
import time

import numpy as np

from repro.configs import get_reduced
from repro.core import DependencyManager, RestorePolicy
from repro.models.transformer import init_params
from repro.runtime import ReplicaSet
from repro.serving import ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arch", default="qwen3_1_7b")
    args = ap.parse_args()

    import jax, jax.numpy as jnp
    cfg = get_reduced(args.arch)
    mgr = DependencyManager()
    mgr.register_image("base", cfg.name,
                       lambda: init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    print(f"[pool] image 'base' live: {mgr.pool_bytes()/1e6:.1f} MB")

    scfg = ServeConfig(max_slots=4, max_seq_len=128, max_new_tokens=8)

    def make_engine(manager, image_id, cfg, method):
        if method == "warmswap":
            return ServingEngine.from_pool(manager, image_id, cfg, scfg,
                                           policy=RestorePolicy.BULK)
        return ServingEngine(cfg, init_params(jax.random.PRNGKey(0), cfg,
                                              jnp.float32), scfg)

    fleet = ReplicaSet(mgr, "base", cfg, make_engine, n_replicas=2)
    for e in fleet.events:
        print(f"[fleet] {e.replica} up via {e.method} in {e.seconds:.3f}s")

    # traffic
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    names = list(fleet.replicas)
    for i in range(args.requests):
        eng = fleet.replicas[names[i % len(names)]]
        eng.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 32))))
    for name, eng in fleet.replicas.items():
        eng.run_until_done()
        m = eng.metrics()
        print(f"[serve] {name}: {m['completed']} done, "
              f"ttft {m['mean_ttft_s']*1e3:.0f}ms, "
              f"latency {m['mean_latency_s']*1e3:.0f}ms")
    print(f"[serve] wall: {time.perf_counter()-t0:.2f}s")

    # failure + recovery through the pool
    victim = names[0]
    print(f"[fault] killing {victim}")
    fleet.kill(victim)
    dt_warm = fleet.recover(victim, method="warmswap")
    fleet.kill(victim)
    dt_cold = fleet.recover(victim, method="baseline")
    print(f"[fault] recovery via pool: {dt_warm:.3f}s | cold reload: {dt_cold:.3f}s "
          f"-> x{dt_cold/max(dt_warm,1e-9):.1f} faster")
    eng = fleet.replicas[victim]
    eng.submit(rng.integers(0, cfg.vocab_size, 8))
    eng.run_until_done()
    print(f"[fault] recovered replica serving again: "
          f"{eng.metrics()['completed']} request(s) done")


if __name__ == "__main__":
    main()
