"""AOT executable serialization for dependency images (paper §3.2 disk tier).

A live dependency image carries pre-built executables (the XLA analogue of
pre-imported middleware). In-process that's a warm jit cache; to survive the disk
tier and process restarts — the paper's "checkpoint images on disk regenerate live
images without re-running initialization" — executables are exported with
``jax.export`` into portable serialized artifacts:

    blobs = serialize_executables({'prefill': jitted_fn}, {'prefill': sample_args})
    ...process restart / image revived from disk...
    execs = deserialize_executables(blobs)      # no XLA re-compile
    execs['prefill'](params, tokens)

Deserialized entries are thin callables over ``jax.export.deserialize(...).call``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
from jax import export as jax_export


def serialize_executables(
    execs: Dict[str, Callable],
    sample_args: Dict[str, Tuple[Any, ...]],
) -> Dict[str, bytes]:
    """Export each jitted callable traced at its sample arguments."""
    blobs: Dict[str, bytes] = {}
    for name, fn in execs.items():
        args = sample_args[name]
        exported = jax_export.export(fn if hasattr(fn, "lower") else jax.jit(fn))(
            *jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
                if hasattr(a, "shape") else a, args))
        blobs[name] = bytes(exported.serialize())
    return blobs


def deserialize_executables(blobs: Dict[str, bytes]) -> Dict[str, Callable]:
    """Rehydrate serialized executables into callables (no retrace/recompile of the
    original function; XLA consumes the stored StableHLO)."""
    out: Dict[str, Callable] = {}
    for name, blob in blobs.items():
        exported = jax_export.deserialize(blob)

        def call(*args, _exp=exported):
            return _exp.call(*args)

        out[name] = call
    return out


def executables_nbytes(blobs: Dict[str, bytes]) -> int:
    return sum(len(b) for b in blobs.values())
