import importlib.util
import os
import sys

# tests/test_analysis_*.py and tests/test_ci_checks.py import the repo-root
# `tools` package; `python -m pytest` from the root already has cwd on
# sys.path, this keeps bare `pytest` / other cwds working too.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

# Smoke tests and benches must see the single real device; ONLY the dry-run launcher
# forces 512 host devices (and it does so in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Property tests use hypothesis when available; otherwise fall back to the
# deterministic seeded-fuzz shim so those modules still collect and run
# (see tests/_hypothesis_fallback.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies
