"""GQA attention: chunked (flash-style) prefill and single-token decode.

Covers every assigned attention variant:
  * full causal ("global") and sliding-window ("local") layers — gemma2's
    alternating pattern, danube3's SWA, recurrentgemma's local layers;
  * attention-logit softcapping (gemma2);
  * per-head qk RMSNorm (qwen3);
  * QKV bias (qwen1.5 / internvl2);
  * non-causal encoder attention + cross attention (whisper).

Prefill is blockwise over query chunks (``lax.map`` + ``jax.checkpoint``) so the
(Sq, Sk) logit matrix never fully materializes — O(B·H·chunk·band) live memory.
Local layers additionally band-slice the keys, so their cost is O(S·window) not O(S²).

KV caches are ring buffers of capacity C (= min(window, seq) for local layers, seq for
global) with an explicit per-slot logical-position array ``k_pos`` (-1 ⇒ empty); masks
are computed from positions, which makes ring wraparound trivially correct.

On TPU the prefill path can be served by the Pallas flash kernel
(`repro.kernels.flash_attention`); the jnp path here is also the reference oracle.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import _he, apply_rope, softcap

NEG_INF = -2.0e38  # fp32-safe mask value


class KVCache(NamedTuple):
    # Layout (B, Hkv, C, hd): kv-heads ahead of sequence so the decode einsum
    # 'bhgd,bhsd->bhgs' consumes the cache with NO transpose copies (perf
    # iteration A2, EXPERIMENTS.md §Perf) and the flash-decode kernel's BlockSpec
    # tiles (1, 1, block_k, hd) stream contiguously.
    k: jax.Array       # (B, Hkv, C, hd)
    v: jax.Array       # (B, Hkv, C, hd)
    k_pos: jax.Array   # (B, C) int32 logical position per slot, -1 = empty
                       # (per-batch: continuous batching gives each slot its own
                       # position stream)


# ---------------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype, *, cross: bool = False) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _he(ks[0], (d, h * hd), d, dtype),
        "wk": _he(ks[1], (d, hk * hd), d, dtype),
        "wv": _he(ks[2], (d, hk * hd), d, dtype),
        "wo": _he(ks[3], (h * hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hk * hd,), dtype)
        p["bv"] = jnp.zeros((hk * hd,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qk_rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (xf * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def _project_qkv(params: dict, xq: jax.Array, xkv: jax.Array, cfg: ArchConfig):
    """Returns q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd)."""
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = xq @ params["wq"]
    k = xkv @ params["wk"]
    v = xkv @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(*xq.shape[:-1], h, hd)
    k = k.reshape(*xkv.shape[:-1], hk, hd)
    v = v.reshape(*xkv.shape[:-1], hk, hd)
    if "q_norm" in params:
        q = _qk_rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_rmsnorm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------------
# Blockwise (flash-style) attention core — also the kernels' reference semantics
# ---------------------------------------------------------------------------------

def _attend(qc, kc, vc, mask, scale, cap):
    """qc: (B,C,Hkv,G,hd)  kc/vc: (B,S,Hkv,hd)  mask: (C,S) bool or None."""
    logits = jnp.einsum("bqhgd,bshd->bqhgs", qc, kc, preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = softcap(logits, cap)
    if mask is not None:
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqhgs,bshd->bqhgd", probs.astype(vc.dtype), vc)
    return out


def blockwise_attention(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Sk, Hkv, hd)
    v: jax.Array,                 # (B, Sk, Hkv, hd)
    *,
    q_positions: jax.Array,       # (Sq,) int32
    k_positions: jax.Array,       # (Sk,) int32 (-1 = invalid slot)
    causal: bool,
    window: Optional[int],        # None = unbounded
    attn_softcap: Optional[float],
    q_chunk: int = 512,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, Hkv, G, hd)

    C = min(q_chunk, Sq)
    pad = (-Sq) % C
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-(10**9))
    n_chunks = qg.shape[1] // C
    qg = qg.reshape(B, n_chunks, C, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_positions.reshape(n_chunks, C)

    banded = window is not None and causal and Sk > window + C
    band = min(Sk, (window or 0) + C)

    @jax.checkpoint
    def chunk_fn(args):
        qc, qpc, i0 = args
        if banded:
            # keys needed for q positions [i0, i0+C) lie in [i0-window+1, i0+C);
            # band = window + C, so the band ending at i0+C covers them all.
            start = jnp.clip(i0 + C - band, 0, Sk - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kpc = jax.lax.dynamic_slice_in_dim(k_positions, start, band, axis=0)
        else:
            kc, vc, kpc = k, v, k_positions
        mask = kpc[None, :] >= 0
        if causal:
            mask &= kpc[None, :] <= qpc[:, None]
        if window is not None:
            mask &= (qpc[:, None] - kpc[None, :]) < window
        return _attend(qc, kc, vc, mask, scale, attn_softcap)

    i0s = jnp.arange(n_chunks, dtype=jnp.int32) * C
    out = jax.lax.map(chunk_fn, (qg, qp, i0s))          # (n_chunks, B, C, Hkv, G, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_chunks * C, H, hd)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,                 # (B, 1, H, hd)
    cache: KVCache,
    pos,                          # int32 scalar or (B,): position of the new token
    *,
    window: Optional[int],
    attn_softcap: Optional[float],
) -> jax.Array:
    B, _, H, hd = q.shape
    Hkv = cache.k.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hkv, G, hd)
    # masks from logical slot positions — ring wraparound safe; per-batch positions
    kp = cache.k_pos                                        # (B, C)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1), kp.shape[:1] + (1,))
    valid = (kp >= 0) & (kp <= pos_b)
    if window is not None:
        valid &= (pos_b - kp) < window
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, cache.k, preferred_element_type=jnp.float32)
    logits = softcap(logits * scale, attn_softcap)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    # explicit max/exp/sum so a seq-sharded cache reduces with small all-reduces
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - jax.lax.stop_gradient(m))
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = (e / denom).astype(cache.v.dtype)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, cache.v)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------------
# Cache construction / update
# ---------------------------------------------------------------------------------

def cache_capacity(cfg: ArchConfig, layer_type: str, seq_len: int) -> int:
    from repro.models.config import LOCAL_ATTN
    if layer_type == LOCAL_ATTN:
        return min(cfg.window, seq_len)
    return seq_len


def build_cache_from_prefill(k: jax.Array, v: jax.Array, capacity: int) -> KVCache:
    """Ring-aligned cache from prefill keys: position p lives at slot p % C.
    k/v arrive as (B, S, Hkv, hd); the cache stores (B, Hkv, C, hd)."""
    B, S, Hkv, hd = k.shape
    C = capacity
    kt = k.transpose(0, 2, 1, 3)                 # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)
    if C >= S:
        pad = ((0, 0), (0, 0), (0, C - S), (0, 0))
        kc, vc = jnp.pad(kt, pad), jnp.pad(vt, pad)
        k_pos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                 jnp.full((C - S,), -1, jnp.int32)])
        return KVCache(kc, vc, jnp.broadcast_to(k_pos, (B, C)))
    shift = S % C
    kc = jnp.roll(kt[:, :, S - C:], shift, axis=2)
    vc = jnp.roll(vt[:, :, S - C:], shift, axis=2)
    k_pos = jnp.roll(jnp.arange(S - C, S, dtype=jnp.int32), shift)
    return KVCache(kc, vc, jnp.broadcast_to(k_pos, (B, C)))


def empty_cache(cfg: ArchConfig, layer_type: str, batch: int, seq_len: int, dtype) -> KVCache:
    C = cache_capacity(cfg, layer_type, seq_len)
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return KVCache(
        jnp.zeros((batch, hk, C, hd), dtype),
        jnp.zeros((batch, hk, C, hd), dtype),
        jnp.full((batch, C), -1, jnp.int32),
    )


def update_cache(cache: KVCache, k_new: jax.Array, v_new: jax.Array, pos) -> KVCache:
    """Write one token per batch row at its ring slot pos_b % C (per-slot positions:
    continuous batching). k_new/v_new: (B, 1, Hkv, hd); pos: scalar or (B,).

    Implemented as a masked select, not a scatter (perf iteration A3, EXPERIMENTS.md
    §Perf): per-batch-row scatters made XLA round-trip the cache through f32
    transpose copies; the select is one fused bf16 read+write in the cache's native
    layout."""
    import os
    B, Hkv, C, hd = cache.k.shape
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    slot = pos_b % C
    if os.environ.get("REPRO_PERF_BASELINE", "") == "1":   # pre-A3 scatter path
        bidx = jnp.arange(B)
        k = cache.k.at[bidx, :, slot].set(k_new[:, 0].astype(cache.k.dtype))
        v = cache.v.at[bidx, :, slot].set(v_new[:, 0].astype(cache.v.dtype))
        k_pos = cache.k_pos.at[bidx, slot].set(pos_b)
        return KVCache(k, v, k_pos)
    hit = jnp.arange(C, dtype=jnp.int32)[None, :] == slot[:, None]       # (B, C)
    kn = k_new[:, 0].astype(cache.k.dtype)[:, :, None, :]               # (B,Hkv,1,hd)
    vn = v_new[:, 0].astype(cache.v.dtype)[:, :, None, :]
    k = jnp.where(hit[:, None, :, None], kn, cache.k)
    v = jnp.where(hit[:, None, :, None], vn, cache.v)
    k_pos = jnp.where(hit, pos_b[:, None], cache.k_pos)
    return KVCache(k, v, k_pos)


# ---------------------------------------------------------------------------------
# Full attention sublayer (projections + rope + core + out-projection)
# ---------------------------------------------------------------------------------

def attention_prefill(
    params: dict,
    x: jax.Array,                  # (B, S, D)
    cfg: ArchConfig,
    layer_type: str,
    positions: jax.Array,          # (S,)
    *,
    causal: bool = True,
    make_cache: bool = False,
    state_len: Optional[int] = None,   # total cache capacity (prompt + generation)
    q_chunk: int = 512,
) -> Tuple[jax.Array, Optional[KVCache]]:
    from repro.models.config import LOCAL_ATTN
    q, k, v = _project_qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = cfg.window if layer_type == LOCAL_ATTN else None
    out = blockwise_attention(
        q, k, v,
        q_positions=positions, k_positions=positions,
        causal=causal, window=window, attn_softcap=cfg.attn_logit_softcap,
        q_chunk=q_chunk,
    )
    out = out.reshape(*x.shape[:-1], -1) @ params["wo"]
    cache = None
    if make_cache:
        cap = cache_capacity(cfg, layer_type, max(state_len or 0, x.shape[1]))
        cache = build_cache_from_prefill(k, v, cap)
    return out, cache


def attention_decode(
    params: dict,
    x: jax.Array,                  # (B, 1, D)
    cache: KVCache,
    pos,                           # scalar int32
    cfg: ArchConfig,
    layer_type: str,
) -> Tuple[jax.Array, KVCache]:
    from repro.models.config import LOCAL_ATTN
    q, k, v = _project_qkv(params, x, x, cfg)
    pos_arr = jnp.asarray(pos, jnp.int32)
    pos_arr = pos_arr.reshape(-1, 1) if pos_arr.ndim else pos_arr[None]  # (B,1)|(1,)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)
    cache = update_cache(cache, k, v, pos)
    window = cfg.window if layer_type == LOCAL_ATTN else None
    out = decode_attention(q, cache, pos, window=window, attn_softcap=cfg.attn_logit_softcap)
    out = out.reshape(*x.shape[:-1], -1) @ params["wo"]
    return out, cache


def cross_attention(
    params: dict,
    x: jax.Array,                  # (B, Sq, D)
    enc_k: jax.Array,              # (B, Senc, Hkv, hd)
    enc_v: jax.Array,
    cfg: ArchConfig,
) -> jax.Array:
    """Whisper decoder cross-attention over precomputed encoder K/V (non-causal)."""
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(*x.shape[:-1], h, hd)
    if "bq" in params:
        q = q + params["bq"].reshape(h, hd)
    Senc = enc_k.shape[1]
    pos_q = jnp.zeros((x.shape[1],), jnp.int32)
    pos_k = jnp.arange(Senc, dtype=jnp.int32)
    out = blockwise_attention(
        q, enc_k, enc_v,
        q_positions=pos_q, k_positions=pos_k,
        causal=False, window=None, attn_softcap=None,
    )
    return out.reshape(*x.shape[:-1], -1) @ params["wo"]


def project_cross_kv(params: dict, enc_out: jax.Array, cfg: ArchConfig):
    hk, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(*enc_out.shape[:-1], hk, hd)
    v = (enc_out @ params["wv"]).reshape(*enc_out.shape[:-1], hk, hd)
    return k, v
