"""Trace-driven fleet simulation: WarmSwap vs Prebaking vs Baseline (paper §4.5).

Discrete-event simulation over per-function invocation traces:

  * each function keeps at most one instance; an invocation within the keep-alive
    window is a **warm start**, otherwise a **cold start** (the >99 % case the paper
    scopes to, §2.2);
  * cold-start latency comes from a per-method :class:`CostModel` — either measured
    numbers produced by ``benchmarks/bench_coldstart.py`` on this machine, or the
    paper's own Table 2 values for a paper-faithful simulation;
  * memory accounting follows each method's structure: WarmSwap = one shared image
    per *dependency* + per-function metadata/handler; Prebaking = one full snapshot
    per *function*; Baseline = nothing resident.

Outputs match Fig. 7: average latency per invocation-rate quartile + required cache
memory, and the headline "X % memory saved when N functions share one image".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.keepalive import KeepAlivePolicy
from repro.core.traces import Trace, quartile_groups


@dataclass
class CostModel:
    """Per-method start latencies (seconds) and memory shapes."""
    cold_warmswap_s: float
    cold_prebaking_s: float
    cold_baseline_s: float
    warm_s: float
    container_s: float = 0.5          # included for cold starts of BOTH methods (§4.5)
    image_bytes: int = 230 << 20      # one shared dependency image (paper: 260 MB total
    metadata_bytes: int = 3 << 20     #   = image + 10 x per-fn metadata, §4.5)
    snapshot_bytes: int = 230 << 20   # one prebaked snapshot per function (~2.3 GB /10)
    image_revive_s: float = 0.4       # extra cold-start cost when the worker's pool
                                      #   must revive/rebuild the image first
                                      #   (disk-tier revive, §3.2; fleet sim only)

    @classmethod
    def paper_table2(cls) -> "CostModel":
        """The paper's measured rnn_serving-class numbers (Table 2 / §4.5)."""
        return cls(cold_warmswap_s=0.89, cold_prebaking_s=0.91, cold_baseline_s=2.2,
                   warm_s=0.004)


def method_cold_latency_s(cost: CostModel, method: str) -> float:
    """Cold-start latency for a method, pool hit assumed (shared with fleet.py)."""
    return {
        "warmswap": cost.cold_warmswap_s + cost.container_s,
        "prebaking": cost.cold_prebaking_s + cost.container_s,
        "baseline": cost.cold_baseline_s + cost.container_s,
    }[method]


def method_memory_bytes(cost: CostModel, method: str, n_functions: int,
                        shared_images: int = 1) -> int:
    """Single-worker resident-memory model: WarmSwap = shared images + per-fn
    metadata; Prebaking = one snapshot per function; Baseline = nothing."""
    return {
        "warmswap": shared_images * cost.image_bytes
                    + n_functions * cost.metadata_bytes,
        "prebaking": n_functions * cost.snapshot_bytes,
        "baseline": 0,
    }[method]


@dataclass
class SimResult:
    method: str
    n_invocations: int
    n_cold: int
    n_warm: int
    total_latency_s: float
    memory_bytes: int
    per_fn_latency: Dict[int, float] = field(default_factory=dict)
    per_fn_invocations: Dict[int, int] = field(default_factory=dict)

    @property
    def avg_latency_s(self) -> float:
        return self.total_latency_s / max(self.n_invocations, 1)


def simulate(
    traces: List[Trace],
    method: str,                       # 'warmswap' | 'prebaking' | 'baseline'
    cost: CostModel,
    keep_alive: Optional[KeepAlivePolicy] = None,
    shared_images: int = 1,            # distinct dependency images across the fleet
) -> SimResult:
    keep_alive = keep_alive if keep_alive is not None else KeepAlivePolicy(15.0)
    cold_latency = method_cold_latency_s(cost, method)

    n_cold = n_warm = 0
    total = 0.0
    per_fn_lat: Dict[int, float] = {}
    per_fn_n: Dict[int, int] = {}
    for tr in traces:
        expiry = -np.inf
        lat_sum = 0.0
        for t_min in tr.arrivals_min:
            if t_min <= expiry:
                n_warm += 1
                lat = cost.warm_s
            else:
                n_cold += 1
                lat = cold_latency
            lat_sum += lat
            # instance busy then kept alive from completion
            expiry = t_min + lat / 60.0 + keep_alive.keep_alive_min
        total += lat_sum
        per_fn_lat[tr.fn_index] = lat_sum
        per_fn_n[tr.fn_index] = len(tr.arrivals_min)

    memory = method_memory_bytes(cost, method, len(traces), shared_images)
    return SimResult(method=method, n_invocations=n_cold + n_warm, n_cold=n_cold,
                     n_warm=n_warm, total_latency_s=total, memory_bytes=memory,
                     per_fn_latency=per_fn_lat, per_fn_invocations=per_fn_n)


def quartile_latencies(traces: List[Trace], result: SimResult) -> Dict[str, float]:
    """Fig. 7-left: average latency per invocation-rate quartile."""
    groups = quartile_groups(traces)
    out = {}
    for name, members in groups.items():
        lat = sum(result.per_fn_latency.get(t.fn_index, 0.0) for t in members)
        n = sum(result.per_fn_invocations.get(t.fn_index, 0) for t in members)
        out[name] = lat / max(n, 1)
    return out


def memory_saving_fraction(warmswap: SimResult, prebaking: SimResult) -> float:
    """The paper's headline: WarmSwap saves ~88 % of warm-up memory for 10 functions
    sharing one image."""
    if prebaking.memory_bytes == 0:
        return 0.0
    return 1.0 - warmswap.memory_bytes / prebaking.memory_bytes
