"""Multi-worker fleet simulator: the discrete-event engine (queueing, monotone
busy_until, horizon-clamped residency, prewarm draining), concurrency,
placement, capacity accounting, pre-warm policies, and the degenerate-case
equivalence with simulate()."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.events import EventKind, EventQueue
from repro.core.fleet import FleetConfig, simulate_fleet
from repro.core.keepalive import (HistogramKeepAlive, KeepAlivePolicy,
                                  PrewarmPolicy, SpesPrewarm)
from repro.core.pool import CapacityLedger
from repro.core.simulator import (CostModel, latency_percentiles,
                                  memory_saving_fraction, quartile_latencies,
                                  quartile_percentiles, simulate)
from repro.core.traces import (Trace, assign_images, generate_fleet_traces,
                               generate_traces, sharing_degrees, zipf_weights)
from repro.serving.scheduler import FleetScheduler, place_invocation

CM = CostModel.paper_table2()
COLD_WS = CM.cold_warmswap_s + CM.container_s     # 1.39 s


def _trace(fn, arrivals, image=0):
    arr = np.asarray(arrivals, np.float64)
    rate = len(arr) / max(float(arr[-1]) if len(arr) else 1.0, 1.0)
    return Trace(fn, rate, arr, image_id=image)


# ---------------------------------------------------------------------------------
# Degenerate case: 1 worker / 1 instance per fn / unlimited capacity == simulate()
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["warmswap", "prebaking", "baseline"])
def test_degenerate_matches_simulate(method):
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    rf = simulate_fleet(traces, method, CM, deg)
    rs = simulate(traces, method, CM, KeepAlivePolicy(15.0))
    assert (rf.n_cold, rf.n_warm) == (rs.n_cold, rs.n_warm)
    assert rf.total_latency_s == pytest.approx(rs.total_latency_s, abs=1e-6)
    assert rf.memory_bytes == rs.memory_bytes
    for fn in rs.per_fn_latency:
        assert rf.per_fn_latency[fn] == pytest.approx(rs.per_fn_latency[fn])


def test_degenerate_preserves_88pct_headline():
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    rw = simulate_fleet(traces, "warmswap", CM, deg)
    rp = simulate_fleet(traces, "prebaking", CM, deg)
    assert 0.85 < memory_saving_fraction(rw, rp) < 0.92
    ql = quartile_latencies(traces, rw)       # FleetResult is duck-compatible
    assert set(ql) == {"lowest", "25-50%", "50-75%", "highest"}


# ---------------------------------------------------------------------------------
# Concurrency
# ---------------------------------------------------------------------------------

def test_overlapping_arrivals_spawn_concurrent_instances():
    # two arrivals 0.06 s apart; a cold start takes ~1.39 s, so the second
    # arrival finds the only instance busy -> a second (cold) instance spawns
    traces = [_trace(0, [10.0, 10.001])]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.n_cold == 2 and r.n_warm == 0
    assert r.max_concurrent_instances == 2


def test_instance_cap_serializes_like_paper_model():
    traces = [_trace(0, [10.0, 10.001])]
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    assert r.n_cold == 1 and r.n_warm == 1
    assert r.max_concurrent_instances == 1


# ---------------------------------------------------------------------------------
# Queueing semantics (the discrete-event engine)
# ---------------------------------------------------------------------------------

def test_capped_overlap_latency_includes_queue_delay():
    """An at-cap arrival waits for the instance-free event; its latency is the
    hand-computed queue delay + warm cost, and busy_until never rewinds."""
    traces = [_trace(0, [10.0, 10.001])]
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    free_at = 10.0 + COLD_WS / 60.0                 # first (cold) completion
    expected_wait = (free_at - 10.001) * 60.0
    assert r.latency_samples_s[0] == pytest.approx(COLD_WS)
    assert r.latency_samples_s[1] == pytest.approx(expected_wait + CM.warm_s)
    assert r.n_queued == 1
    assert r.queue_delay_s == pytest.approx(expected_wait)
    assert r.total_latency_s == pytest.approx(r.latency_samples_s.sum())
    # busy_until monotone: each service starts no earlier than the previous
    # completion on the single instance
    starts = np.array([10.0, 10.001]) + r.queue_wait_s / 60.0
    ends = starts + np.array([COLD_WS, CM.warm_s]) / 60.0
    assert starts[1] >= ends[0] - 1e-12
    # the same trace against queue-accurate simulate(): exact agreement
    rs = simulate(traces, "warmswap", CM, KeepAlivePolicy(15.0))
    assert rs.total_latency_s == pytest.approx(r.total_latency_s)
    assert rs.n_queued == 1


def test_contended_burst_p99_exceeds_average():
    """A burst on one capped instance: queue delays grow linearly across the
    burst, so tail latency is strictly above the mean (the load signal the
    arrival-ordered loop could never produce)."""
    # arrival gap (0.6 ms) < warm service (4 ms): the queue builds during the
    # initial cold start and keeps growing, so waits rise along the burst
    burst = [_trace(0, [10.0 + 1e-5 * k for k in range(20)])]
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    r = simulate_fleet(burst, "warmswap", CM, cfg)
    assert r.n_queued == 19
    pct = r.latency_percentiles()
    assert pct["p99"] > r.avg_latency_s
    assert pct["p99"] >= pct["p95"] >= pct["p50"] >= 0.0
    # waits are strictly increasing along the FIFO queue
    assert (np.diff(r.queue_wait_s) > 0).all()


def test_uncapped_overlap_still_spawns_and_percentiles_populate():
    traces = [_trace(0, [10.0, 10.001])]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.n_queued == 0 and r.queue_delay_s == 0.0
    assert len(r.latency_samples_s) == 2
    assert np.isfinite(r.latency_samples_s).all()
    qp = quartile_percentiles(traces, r)
    assert set(qp) == {"lowest", "25-50%", "50-75%", "highest"}


def test_prewarm_events_after_last_arrival_fire_or_are_dropped():
    """A pre-warm window inside the horizon fires; one scheduled past the last
    arrival is drained and accounted as dropped, not silently lost."""
    class NearAndFar(PrewarmPolicy):
        def __init__(self):
            super().__init__(keep_alive_min=0.01)    # instances die fast
        def prewarm_after(self, fn, t_min):
            return (t_min + 1.0, t_min + 5.0)
    traces = [_trace(0, [10.0, 12.0])]
    cfg = FleetConfig(n_workers=1, prewarm=NearAndFar())
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    # window from t=10 spawns at 11 (inside horizon=12) and serves t=12 warm;
    # window from t=12 would spawn at 13 > horizon: dropped
    assert r.prewarm_spawns == 1
    assert r.prewarm_hits == 1
    assert r.prewarm_dropped == 1
    assert r.n_cold == 1 and r.n_warm == 1


def test_residency_clamped_to_horizon_hand_computed():
    """3 arrivals, one instance: keep-alive extends past the last arrival, but
    instance_resident_min clamps at the horizon — exactly horizon - created."""
    traces = [_trace(0, [10.0, 12.0, 20.0])]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    # one instance created at 10; last completion 20 + warm_s/60, expiry
    # ~35.00007 min, clamped to horizon 20.0 -> residency = 20 - 10 = 10
    assert r.horizon_min == 20.0
    assert r.n_cold == 1 and r.n_warm == 2
    assert r.instance_resident_min == pytest.approx(10.0)


@given(st.lists(st.floats(0.001, 2.0), min_size=1, max_size=15),
       st.floats(0.15, 0.85))
@settings(max_examples=25, deadline=None)
def test_total_latency_monotone_in_offered_load(gaps, compress):
    """Compressing inter-arrival gaps (more offered load, identical work) can
    only increase total latency: Lindley's recursion under a fixed service
    sequence. Keep-alive is huge so the service sequence (1 cold + warms)
    doesn't change with compression."""
    arrivals = 1.0 + np.cumsum(np.asarray(gaps))
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1,
                      keep_alive_min=1e6)
    sparse = simulate_fleet([_trace(0, arrivals)], "warmswap", CM, cfg)
    dense = simulate_fleet([_trace(0, 1.0 + compress * (arrivals - 1.0))],
                           "warmswap", CM, cfg)
    assert dense.total_latency_s >= sparse.total_latency_s - 1e-9
    assert (dense.queue_wait_s >= -1e-12).all()
    assert dense.queue_delay_s >= sparse.queue_delay_s - 1e-9


def test_event_queue_tiebreak_order():
    q = EventQueue()
    q.push(5.0, EventKind.KEEPALIVE_EXPIRY, "expiry")
    q.push(5.0, EventKind.INSTANCE_FREE, "free")
    q.push(5.0, EventKind.PREWARM_SPAWN, "prewarm")
    q.push(4.0, EventKind.KEEPALIVE_EXPIRY, "early")
    order = [q.pop().payload for _ in range(len(q))]
    assert order == ["early", "free", "prewarm", "expiry"]
    # an arrival at t=5 ranks after instance-free/prewarm, before expiry
    q.push(5.0, EventKind.INSTANCE_FREE, None)
    assert q.peek_key() <= (5.0, int(EventKind.ARRIVAL))
    q.pop()
    q.push(5.0, EventKind.KEEPALIVE_EXPIRY, None)
    assert not (q.peek_key() <= (5.0, int(EventKind.ARRIVAL)))


def test_warm_reuse_after_completion():
    traces = [_trace(0, [10.0, 12.0])]        # second arrival: idle, in window
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.n_cold == 1 and r.n_warm == 1


# ---------------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------------

def test_affinity_beats_round_robin_on_skewed_trace():
    traces = generate_fleet_traces(24, horizon_min=2 * 24 * 60, seed=3,
                                   n_images=4, rate_model="zipf",
                                   total_rate_per_min=4.0)
    results = {}
    for placement in ("affinity", "round_robin"):
        cfg = FleetConfig(n_workers=4, placement=placement,
                          worker_capacity_bytes=2 * CM.image_bytes)
        results[placement] = simulate_fleet(traces, "warmswap", CM, cfg)
    aff, rr = results["affinity"], results["round_robin"]
    assert aff.n_cold < rr.n_cold
    assert aff.pool_misses < rr.pool_misses
    assert aff.avg_latency_s < rr.avg_latency_s


def test_place_invocation_priority():
    load = {0: 5, 1: 0, 2: 3}.__getitem__
    # warm beats pool-residency beats load
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: w == 0,
                            holds_image=lambda w: w == 2) == 0
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: False,
                            holds_image=lambda w: w == 2) == 2
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: False,
                            holds_image=lambda w: False) == 1


def test_placement_pool_hit_counter_counts_residency_routing():
    """A cold arrival routed to the worker whose pool already holds the
    image must increment placement_pool_hits (regression: a stale closure
    over the event-loop's heap key silently zeroed the counter)."""
    traces = generate_fleet_traces(12, horizon_min=24 * 60, seed=1,
                                   n_images=4, rate_model="zipf",
                                   total_rate_per_min=6.0)
    cfg = FleetConfig(n_workers=4, worker_capacity_bytes=2 * CM.image_bytes)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    # the setup phase seeds each image on a home worker, so affinity routing
    # must land cold starts on pool holders
    assert r.placement_pool_hits > 0
    assert r.placement_warm_hits > 0


def test_fleet_scheduler_pick_affine_prefers_residency():
    s = FleetScheduler()
    for name in ("a", "b"):
        s.register_replica(name)
    s.observe("a", 0.001)                      # 'a' is fast
    s.observe("b", 0.1)                        # 'b' is slow
    assert s.pick_affine("img", {"b": {"img"}}) == "b"   # residency wins
    assert s.pick_affine("img", {}) == "a"               # else fastest EWMA


# ---------------------------------------------------------------------------------
# Memory accounting
# ---------------------------------------------------------------------------------

def test_warmswap_memory_is_O_images_per_worker():
    # 12 functions all sharing ONE image on one worker: pool holds 1 image,
    # metadata scales with functions — never 12 images
    n_fns = 12
    traces = [_trace(i, [float(10 + i)], image=0) for i in range(n_fns)]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.memory_bytes == CM.image_bytes + n_fns * CM.metadata_bytes
    assert r.per_worker[0]["resident"] == ["img:0"]
    rp = simulate_fleet(traces, "prebaking", CM, FleetConfig(n_workers=1))
    assert rp.memory_bytes == n_fns * CM.snapshot_bytes


def test_capacity_pressure_causes_evictions_and_revives():
    # 3 images on 1 worker with room for only 1 -> thrashing: evictions and
    # revive-penalty cold starts must show up
    traces = [_trace(i, [10.0 * (i + 1), 200.0 + 10.0 * i], image=i)
              for i in range(3)]
    cfg = FleetConfig(n_workers=1, worker_capacity_bytes=CM.image_bytes)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    assert r.evictions > 0
    assert r.pool_misses > 0
    assert r.memory_bytes <= CM.image_bytes + 3 * CM.metadata_bytes


def test_capacity_ledger_lru_and_pins():
    led = CapacityLedger(capacity_bytes=100)
    led.admit("a", 60, now=1.0)
    led.admit("b", 40, now=2.0)
    evicted = led.admit("c", 50, now=3.0)      # must evict LRU 'a'
    assert evicted == ["a"] and led.holds("b") and led.holds("c")
    led2 = CapacityLedger(capacity_bytes=100)
    led2.admit("pinned", 60, now=1.0, pinned=True)
    led2.admit("ref", 40, now=2.0)
    led2.acquire("ref")
    assert led2.admit("x", 50, now=3.0) == []  # nothing evictable: admit anyway
    assert led2.used_bytes() == 150


def test_capacity_ledger_readmit_refreshes_size():
    """Re-admitting a resident key must refresh its nbytes (resized/reshared
    image), re-run eviction when it grew, and never evict itself."""
    led = CapacityLedger(capacity_bytes=100)
    led.admit("a", 40, now=1.0)
    led.admit("b", 40, now=2.0)
    evicted = led.admit("a", 90, now=3.0)      # grew: 'b' must go, never 'a'
    assert evicted == ["b"]
    assert led.holds("a") and not led.holds("b")
    assert led.entries["a"].nbytes == 90 and led.used_bytes() == 90
    led.admit("a", 10, now=4.0)                # shrink also refreshes
    assert led.used_bytes() == 10
    # unchanged size: pure touch, no eviction
    led.admit("c", 80, now=5.0)
    assert led.admit("c", 80, now=6.0) == [] and led.used_bytes() == 90
    # re-admit also refreshes pin state, not just size
    led.admit("c", 80, now=7.0, pinned=True)
    assert led.entries["c"].pinned


# ---------------------------------------------------------------------------------
# Pre-warm policies
# ---------------------------------------------------------------------------------

def _periodic_traces(n_fns=6, period=10.0, horizon=2000.0):
    return [_trace(fn, np.arange(5.0 + fn, horizon, period)) for fn in range(n_fns)]


def test_histogram_keepalive_cuts_cold_starts_on_periodic_load():
    # period 20 min > fixed 15-min keep-alive: fixed policy cold-starts every
    # time, the histogram policy learns the inter-arrival time and covers it
    traces = _periodic_traces(period=20.0)
    base = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="none"))
    hist = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="histogram"))
    assert hist.n_cold < base.n_cold


def test_spes_prewarm_cuts_residency_and_hits():
    traces = _periodic_traces(period=20.0)
    base = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="none"))
    spes = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="spes"))
    assert spes.prewarm_spawns > 0 and spes.prewarm_hits > 0
    assert spes.instance_resident_min < base.instance_resident_min
    assert spes.n_cold < base.n_cold           # predictions land on periodic load


def test_policy_state_isolation():
    p1, p2 = HistogramKeepAlive(), HistogramKeepAlive()
    p1.on_arrival(0, 1.0)
    p1.on_arrival(0, 2.0)
    assert p2._iats.get(0) is None             # no shared mutable state


# ---------------------------------------------------------------------------------
# Fleet traces
# ---------------------------------------------------------------------------------

def test_zipf_weights_and_image_assignment():
    w = zipf_weights(10, 1.2)
    assert w.sum() == pytest.approx(1.0) and (np.diff(w) < 0).all()
    imgs = assign_images(40, 4, skew=1.2, seed=0)
    assert set(imgs) == {0, 1, 2, 3}           # coverage guarantee
    deg = sharing_degrees(generate_fleet_traces(40, 100.0, seed=0, n_images=4))
    assert sum(deg.values()) == 40


def test_fleet_traces_deterministic():
    a = generate_fleet_traces(8, 500.0, seed=9, n_images=3)
    b = generate_fleet_traces(8, 500.0, seed=9, n_images=3)
    for ta, tb in zip(a, b):
        assert ta.image_id == tb.image_id
        assert np.array_equal(ta.arrivals_min, tb.arrivals_min)
