"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * (step + 1) / jnp.maximum(warmup_steps, 1)  # nonzero at step 0
    progress = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                        0.0, 1.0)
    cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < warmup_steps, warm, cos)
