"""Fleet simulation tour: concurrency, placement, capacity, pre-warm policies.

Walks the multi-worker simulator (repro.core.fleet) through the questions the
single-worker model (repro.core.simulator) cannot answer:

  1. Degenerate check — 1 worker / 1 instance per function reproduces the
     paper's Fig. 7 numbers, including the ~88 % memory-saving headline.
  2. Does image-affinity placement beat round-robin on a skewed workload?
  3. What does pool capacity pressure do to each method?
  4. How do keep-alive / pre-warm policies trade latency for residency?
  5. What does an instance cap do to the tail? (queue-accurate P50/P95/P99
     from the discrete-event engine — queued requests pay their wait.)
  6. What does a cold start actually *cost* when it is priced page by page?
     (page-granular cost model + cluster-shared image cache: local pool hits
     vs remote peer fetches vs source misses — see docs/SIMULATION.md.)

    PYTHONPATH=src python examples/fleet_sim.py
"""
from repro.core import (CostModel, FleetConfig, KeepAlivePolicy, PageCostModel,
                        simulate, simulate_fleet)
from repro.core.simulator import memory_saving_fraction
from repro.core.traces import generate_fleet_traces, generate_traces, sharing_degrees


def main() -> None:
    cm = CostModel.paper_table2()

    # --- 1. degenerate point == the paper's simulation --------------------------
    traces10 = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    rw, rp = (simulate_fleet(traces10, m, cm, deg)
              for m in ("warmswap", "prebaking"))
    ref = simulate(traces10, "warmswap", cm, KeepAlivePolicy(15.0))
    print(f"degenerate: fleet avg {rw.avg_latency_s * 1e3:.2f} ms "
          f"== simulate() {ref.avg_latency_s * 1e3:.2f} ms; "
          f"memory saving {memory_saving_fraction(rw, rp) * 100:.1f} % "
          f"(paper: 88 %)\n")

    # --- a skewed 40-function fleet over 4 shared images ------------------------
    traces = generate_fleet_traces(40, horizon_min=7 * 24 * 60, seed=1,
                                   n_images=4, rate_model="zipf",
                                   total_rate_per_min=6.0)
    print(f"fleet workload: 40 fns, sharing degrees {sharing_degrees(traces)}")

    # --- 2. placement policies under identical everything else ------------------
    print("\nplacement (4 workers, pool capacity = 2 images each, warmswap):")
    for placement in ("affinity", "least_loaded", "round_robin"):
        cfg = FleetConfig(n_workers=4, placement=placement,
                          worker_capacity_bytes=2 * cm.image_bytes)
        r = simulate_fleet(traces, "warmswap", cm, cfg)
        print(f"  {placement:13s} avg {r.avg_latency_s * 1e3:7.1f} ms | "
              f"cold {r.n_cold:5d} | pool misses {r.pool_misses:4d} | "
              f"evictions {r.evictions:4d} | peak mem {r.memory_bytes >> 20} MB")

    # --- 3. capacity pressure per method ----------------------------------------
    print("\npool capacity (4 workers, affinity):")
    for cap in (1, 2, None):
        cfg = FleetConfig(n_workers=4, worker_capacity_bytes=(
            None if cap is None else cap * cm.image_bytes))
        row = []
        for method in ("warmswap", "prebaking", "baseline"):
            r = simulate_fleet(traces, method, cm, cfg)
            row.append(f"{method} {r.avg_latency_s * 1e3:6.1f} ms/"
                       f"{r.memory_bytes >> 20:4d} MB")
        print(f"  {str(cap or 'unlimited'):>9s} images/worker: " + " | ".join(row))

    # --- 4. pre-warm policies ----------------------------------------------------
    print("\npre-warm policy (4 workers, warmswap): latency vs residency")
    for pw in ("none", "histogram", "spes"):
        cfg = FleetConfig(n_workers=4, prewarm=pw)
        r = simulate_fleet(traces, "warmswap", cm, cfg)
        print(f"  {pw:9s} avg {r.avg_latency_s * 1e3:7.1f} ms | "
              f"cold {r.n_cold:5d} | warm-instance residency "
              f"{r.instance_resident_min:9.0f} inst-min | "
              f"prewarm spawns/hits {r.prewarm_spawns}/{r.prewarm_hits}")
    print("\nconcurrency: arrivals overlapping a busy instance spawn new ones "
          "(peak concurrent instances of one function above: "
          f"{simulate_fleet(traces, 'warmswap', cm, FleetConfig(n_workers=4)).max_concurrent_instances})")

    # --- 5. queueing: instance caps make the tail visible ------------------------
    print("\ninstance cap (2 workers, warmswap): queue delay shows in the tail")
    for cap in (None, 2, 1):
        cfg = FleetConfig(n_workers=2, max_instances_per_fn=cap,
                          worker_capacity_bytes=2 * cm.image_bytes)
        r = simulate_fleet(traces, "warmswap", cm, cfg)
        p = r.latency_percentiles()
        print(f"  cap={str(cap):>4s} avg {r.avg_latency_s * 1e3:7.1f} ms | "
              f"P50 {p['p50'] * 1e3:6.1f} | P95 {p['p95'] * 1e3:7.1f} | "
              f"P99 {p['p99'] * 1e3:7.1f} ms | queued {r.n_queued:4d} "
              f"({r.queue_delay_s:.1f}s waiting)")

    # --- 6. page-granular cold starts + the cluster-shared image cache ----------
    model = PageCostModel(cost=cm)
    n_img = model.image_pages()
    print(f"\npage-granular cost model ({n_img} pages x "
          f"{model.page_size >> 20} MiB for the {cm.image_bytes >> 20} MB image):")
    for tier, label in (("local", "local pool hit (memcpy)"),
                        ("remote", "remote peer via shared cache (DCN)"),
                        ("miss", "source-store fetch (cache miss)")):
        lat = model.cold_latency_s("warmswap", tier=tier)
        print(f"  warmswap cold, {label:36s} {lat * 1e3:7.1f} ms")
    half = model.cold_latency_s("warmswap", tier="remote",
                                resident_pages=n_img // 2)
    print(f"  warmswap cold, remote + half-resident image   {half * 1e3:7.1f} ms"
          f"  (partial residency: only missing pages move)")
    print(f"  baseline  cold (full source fetch, no cache)  "
          f"{model.cold_latency_s('baseline') * 1e3:7.1f} ms | "
          f"dependency-loading speedup "
          f"{model.dependency_loading_speedup():.2f}x (paper band: 2.2-3.2x)")

    print("\ncluster-shared cache (4 workers, pool = 1 image each, shared tier"
          " = 2 images, round-robin to force cross-worker traffic):")
    r = simulate_fleet(traces, "warmswap", cm,
                       FleetConfig(n_workers=4, placement="round_robin",
                                   page_cost=model,
                                   worker_capacity_bytes=cm.image_bytes,
                                   shared_cache_bytes=2 * cm.image_bytes))
    print(f"  cold starts by tier: local {r.cache_local_hits} | "
          f"remote {r.cache_remote_hits} | source miss {r.cache_misses} | "
          f"cluster evictions {r.shared_cache_evictions}")
    print(f"  network page volume {r.pages_transferred} pages | avg latency "
          f"{r.avg_latency_s * 1e3:.1f} ms | shared-tier peak "
          f"{r.shared_cache_peak_bytes >> 20} MB")
    ra = simulate_fleet(traces, "warmswap", cm,
                        FleetConfig(n_workers=4, page_cost=model,
                                    worker_capacity_bytes=cm.image_bytes,
                                    shared_cache_bytes=2 * cm.image_bytes))
    print(f"  ...with bandwidth-aware affinity placement instead: local "
          f"{ra.cache_local_hits} | remote {ra.cache_remote_hits} | miss "
          f"{ra.cache_misses} | {ra.pages_transferred} pages moved "
          f"({ra.avg_latency_s * 1e3:.1f} ms avg)")


if __name__ == "__main__":
    main()
