"""Pins the RNG stream interleaving of ``poisson_arrivals_batched`` vs the
per-function ``poisson_arrivals`` loop, and the ``sorted=`` normalization
knob.  The two draw modes are DIFFERENT deterministic streams for one seed
(batched draws all counts before any arrival times); each must stay exactly
reproducible, because checked-in scenario specs and the golden fixtures pin
results under one of them.  Both fleet engines normalize arrival order with
one global stable argsort, so ``sorted=False`` arrays (same multiset, raw
draw order) must produce bit-identical results.
"""
import numpy as np
import pytest

from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import simulate_fleet_vec
from repro.core.simulator import CostModel
from repro.core.traces import (Trace, generate_fleet_traces, poisson_arrivals,
                               poisson_arrivals_batched)

CM = CostModel.paper_table2()
RATES = [2.0, 0.0, 5.5, 0.75]
HORIZON = 100.0
SEED = 42


def test_batched_interleaving_pinned():
    """Batched mode draws ALL counts, then ONE uniform fill, then sorts each
    segment — exactly this, nothing else. A reimplementation that interleaves
    differently changes every downstream per-seed artifact."""
    got = poisson_arrivals_batched(RATES, HORIZON, np.random.default_rng(SEED))
    rng = np.random.default_rng(SEED)
    counts = rng.poisson(np.maximum(np.asarray(RATES), 0.0) * HORIZON)
    counts[np.asarray(RATES) <= 0] = 0
    flat = rng.uniform(0.0, HORIZON, size=int(counts.sum()))
    want = [np.sort(s) for s in np.split(flat, np.cumsum(counts)[:-1])]
    assert len(got) == len(want) == len(RATES)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)
    assert len(got[1]) == 0                    # zero-rate fn stays empty


def test_per_fn_interleaving_pinned():
    """The unbatched path is two RNG calls per function, in function order —
    the legacy stream every pre-batching artifact was pinned against."""
    rng = np.random.default_rng(SEED)
    got = [poisson_arrivals(r, HORIZON, rng) for r in RATES]
    rng = np.random.default_rng(SEED)
    want = []
    for r in RATES:
        if r <= 0:
            want.append(np.empty((0,), np.float64))
            continue
        n = rng.poisson(r * HORIZON)
        want.append(np.sort(rng.uniform(0.0, HORIZON, size=n)))
    for g, w in zip(got, want):
        assert np.array_equal(g, w)


def test_batched_and_per_fn_streams_differ_but_match_statistically():
    """One seed, two modes: different values (the documented interleaving
    difference), same counts — nobody should 'fix' one to equal the other."""
    batched = poisson_arrivals_batched(RATES, HORIZON,
                                       np.random.default_rng(SEED))
    rng = np.random.default_rng(SEED)
    per_fn = [poisson_arrivals(r, HORIZON, rng) for r in RATES]
    assert any(len(b) != len(p) or not np.array_equal(b, p)
               for b, p in zip(batched, per_fn))


def test_sorted_false_same_multiset_unsorted():
    srt = poisson_arrivals_batched(RATES, HORIZON, np.random.default_rng(SEED))
    raw = poisson_arrivals_batched(RATES, HORIZON, np.random.default_rng(SEED),
                                   sorted=False)
    assert any(len(r) > 1 and not np.array_equal(r, np.sort(r)) for r in raw), \
        "sorted=False returned already-sorted segments — knob is dead"
    for s, r in zip(srt, raw):
        assert np.array_equal(s, np.sort(r))   # same multiset per function


@pytest.mark.parametrize("engine", ["fleet", "fleet_vec"])
def test_engines_normalize_arrival_order(engine):
    """Both engines globally stable-argsort the merged stream, so feeding
    raw-draw-order arrivals is bit-identical to feeding sorted ones."""
    traces = generate_fleet_traces(n_functions=6, horizon_min=300.0, seed=9,
                                   n_images=2, rate_model="zipf",
                                   total_rate_per_min=8.0)
    rng = np.random.default_rng(3)
    shuffled = []
    for t in traces:
        arr = t.arrivals_min.copy()
        rng.shuffle(arr)
        shuffled.append(Trace(t.fn_index, t.rate_per_min, arr,
                              image_id=t.image_id))
    impl = simulate_fleet_vec if engine == "fleet_vec" else _simulate_fleet_impl
    for method in ("warmswap", "baseline"):
        a = impl(traces, method, CM, FleetConfig(n_workers=2))
        b = impl(shuffled, method, CM, FleetConfig(n_workers=2))
        assert np.array_equal(a.latency_samples_s, b.latency_samples_s)
        assert np.array_equal(a.queue_wait_s, b.queue_wait_s)
        assert a.total_latency_s == b.total_latency_s
        assert (a.n_cold, a.n_warm, a.n_queued) == \
            (b.n_cold, b.n_warm, b.n_queued)
