"""End-to-end training driver (CPU-runnable on reduced configs; mesh-aware).

Wires the full substrate: config -> init -> sharded jit train_step -> deterministic
data pipeline -> TrainSupervisor (async checkpoints, NaN/failure rollback,
deterministic replay) -> metrics log.

  python -m repro.launch.train --arch fnbench_tiny --steps 200 --batch 8 --seq 128
  python -m repro.launch.train --arch qwen3_1_7b --reduced --steps 50 --resume
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fnbench_tiny")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="results/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "unit", "dots"])
    ap.add_argument("--log", default="results/train_log.jsonl")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointConfig, latest_step
    from repro.configs import get_config, get_reduced
    from repro.data import DataConfig, SyntheticTokenPipeline
    from repro.launch.mesh import make_local_mesh
    from repro.models.api import make_train_step
    from repro.models.sharding import param_pspecs, to_shardings
    from repro.models.transformer import init_params
    from repro.optim import adamw_init
    from repro.runtime import SupervisorConfig, TrainSupervisor

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh(model_axis=args.model_axis)
    data = DataConfig(global_batch=args.batch, seq_len=args.seq, seed=args.seed)

    params = init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    opt_state = adamw_init(params)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"batch={args.batch} seq={args.seq} mesh={dict(mesh.shape)}")

    step_fn = make_train_step(cfg, peak_lr=args.lr, total_steps=args.steps,
                              remat=args.remat)
    p_specs = param_pspecs(cfg, params, mesh.shape["model"])
    with mesh:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

        start = 0
        sup = TrainSupervisor(
            SupervisorConfig(checkpoint_every=args.ckpt_every,
                             checkpoint=CheckpointConfig(args.ckpt_dir)),
            jitted,
            lambda s: {k: jnp.asarray(v) for k, v in
                       SyntheticTokenPipeline.batch_at(cfg, data, s).items()})
        if args.resume and latest_step(args.ckpt_dir) is not None:
            restored = sup.ckpt.restore(None, {"params": params,
                                               "opt_state": opt_state})
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt_state"])
            start = int(restored["__manifest__"]["step"])
            print(f"[train] resumed from step {start}")

        os.makedirs(os.path.dirname(args.log) or ".", exist_ok=True)
        logf = open(args.log, "a")
        t0 = time.perf_counter()

        def on_metrics(step, m):
            logf.write(json.dumps(m) + "\n")
            if step % 10 == 0 or step == start:
                dt = time.perf_counter() - t0
                tok_s = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                      f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.2f} "
                      f"({tok_s:.0f} tok/s)")

        params, opt_state, hist = sup.run(params, opt_state, start,
                                          args.steps - start,
                                          on_metrics=on_metrics)
        logf.close()
    first = next(h for h in hist if "loss" in h)
    print(f"[train] done: loss {first['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {len(hist)} steps")


if __name__ == "__main__":
    main()
