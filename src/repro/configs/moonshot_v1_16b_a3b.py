"""moonshot-v1-16b-a3b [moe] — kimi/moonlight-style, 64 experts top-6.

48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert vocab=163840, head_dim=128.
[hf:moonshotai/Moonlight-16B-A3B; hf]. 64 % 16 == 0 -> true expert parallelism
over the `model` mesh axis.
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    head_dim=128,
    attn_pattern=(GLOBAL_ATTN,),
    n_experts=64,
    top_k=6,
    mlp="swiglu",
    tie_embeddings=False,
)
