"""Multi-worker fleet simulator: concurrency, placement, capacity accounting,
pre-warm policies, and the degenerate-case equivalence with simulate()."""
import numpy as np
import pytest

from repro.core.fleet import FleetConfig, simulate_fleet
from repro.core.keepalive import (HistogramKeepAlive, KeepAlivePolicy,
                                  PrewarmPolicy, SpesPrewarm)
from repro.core.pool import CapacityLedger
from repro.core.simulator import (CostModel, memory_saving_fraction,
                                  quartile_latencies, simulate)
from repro.core.traces import (Trace, assign_images, generate_fleet_traces,
                               generate_traces, sharing_degrees, zipf_weights)
from repro.serving.scheduler import FleetScheduler, place_invocation

CM = CostModel.paper_table2()


def _trace(fn, arrivals, image=0):
    arr = np.asarray(arrivals, np.float64)
    rate = len(arr) / max(float(arr[-1]) if len(arr) else 1.0, 1.0)
    return Trace(fn, rate, arr, image_id=image)


# ---------------------------------------------------------------------------------
# Degenerate case: 1 worker / 1 instance per fn / unlimited capacity == simulate()
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["warmswap", "prebaking", "baseline"])
def test_degenerate_matches_simulate(method):
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    rf = simulate_fleet(traces, method, CM, deg)
    rs = simulate(traces, method, CM, KeepAlivePolicy(15.0))
    assert (rf.n_cold, rf.n_warm) == (rs.n_cold, rs.n_warm)
    assert rf.total_latency_s == pytest.approx(rs.total_latency_s, abs=1e-6)
    assert rf.memory_bytes == rs.memory_bytes
    for fn in rs.per_fn_latency:
        assert rf.per_fn_latency[fn] == pytest.approx(rs.per_fn_latency[fn])


def test_degenerate_preserves_88pct_headline():
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    rw = simulate_fleet(traces, "warmswap", CM, deg)
    rp = simulate_fleet(traces, "prebaking", CM, deg)
    assert 0.85 < memory_saving_fraction(rw, rp) < 0.92
    ql = quartile_latencies(traces, rw)       # FleetResult is duck-compatible
    assert set(ql) == {"lowest", "25-50%", "50-75%", "highest"}


# ---------------------------------------------------------------------------------
# Concurrency
# ---------------------------------------------------------------------------------

def test_overlapping_arrivals_spawn_concurrent_instances():
    # two arrivals 0.06 s apart; a cold start takes ~1.39 s, so the second
    # arrival finds the only instance busy -> a second (cold) instance spawns
    traces = [_trace(0, [10.0, 10.001])]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.n_cold == 2 and r.n_warm == 0
    assert r.max_concurrent_instances == 2


def test_instance_cap_serializes_like_paper_model():
    traces = [_trace(0, [10.0, 10.001])]
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    assert r.n_cold == 1 and r.n_warm == 1
    assert r.max_concurrent_instances == 1


def test_warm_reuse_after_completion():
    traces = [_trace(0, [10.0, 12.0])]        # second arrival: idle, in window
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.n_cold == 1 and r.n_warm == 1


# ---------------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------------

def test_affinity_beats_round_robin_on_skewed_trace():
    traces = generate_fleet_traces(24, horizon_min=2 * 24 * 60, seed=3,
                                   n_images=4, rate_model="zipf",
                                   total_rate_per_min=4.0)
    results = {}
    for placement in ("affinity", "round_robin"):
        cfg = FleetConfig(n_workers=4, placement=placement,
                          worker_capacity_bytes=2 * CM.image_bytes)
        results[placement] = simulate_fleet(traces, "warmswap", CM, cfg)
    aff, rr = results["affinity"], results["round_robin"]
    assert aff.n_cold < rr.n_cold
    assert aff.pool_misses < rr.pool_misses
    assert aff.avg_latency_s < rr.avg_latency_s


def test_place_invocation_priority():
    load = {0: 5, 1: 0, 2: 3}.__getitem__
    # warm beats pool-residency beats load
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: w == 0,
                            holds_image=lambda w: w == 2) == 0
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: False,
                            holds_image=lambda w: w == 2) == 2
    assert place_invocation([0, 1, 2], load=load,
                            has_warm=lambda w: False,
                            holds_image=lambda w: False) == 1


def test_fleet_scheduler_pick_affine_prefers_residency():
    s = FleetScheduler()
    for name in ("a", "b"):
        s.register_replica(name)
    s.observe("a", 0.001)                      # 'a' is fast
    s.observe("b", 0.1)                        # 'b' is slow
    assert s.pick_affine("img", {"b": {"img"}}) == "b"   # residency wins
    assert s.pick_affine("img", {}) == "a"               # else fastest EWMA


# ---------------------------------------------------------------------------------
# Memory accounting
# ---------------------------------------------------------------------------------

def test_warmswap_memory_is_O_images_per_worker():
    # 12 functions all sharing ONE image on one worker: pool holds 1 image,
    # metadata scales with functions — never 12 images
    n_fns = 12
    traces = [_trace(i, [float(10 + i)], image=0) for i in range(n_fns)]
    r = simulate_fleet(traces, "warmswap", CM, FleetConfig(n_workers=1))
    assert r.memory_bytes == CM.image_bytes + n_fns * CM.metadata_bytes
    assert r.per_worker[0]["resident"] == ["img:0"]
    rp = simulate_fleet(traces, "prebaking", CM, FleetConfig(n_workers=1))
    assert rp.memory_bytes == n_fns * CM.snapshot_bytes


def test_capacity_pressure_causes_evictions_and_revives():
    # 3 images on 1 worker with room for only 1 -> thrashing: evictions and
    # revive-penalty cold starts must show up
    traces = [_trace(i, [10.0 * (i + 1), 200.0 + 10.0 * i], image=i)
              for i in range(3)]
    cfg = FleetConfig(n_workers=1, worker_capacity_bytes=CM.image_bytes)
    r = simulate_fleet(traces, "warmswap", CM, cfg)
    assert r.evictions > 0
    assert r.pool_misses > 0
    assert r.memory_bytes <= CM.image_bytes + 3 * CM.metadata_bytes


def test_capacity_ledger_lru_and_pins():
    led = CapacityLedger(capacity_bytes=100)
    led.admit("a", 60, now=1.0)
    led.admit("b", 40, now=2.0)
    evicted = led.admit("c", 50, now=3.0)      # must evict LRU 'a'
    assert evicted == ["a"] and led.holds("b") and led.holds("c")
    led2 = CapacityLedger(capacity_bytes=100)
    led2.admit("pinned", 60, now=1.0, pinned=True)
    led2.admit("ref", 40, now=2.0)
    led2.acquire("ref")
    assert led2.admit("x", 50, now=3.0) == []  # nothing evictable: admit anyway
    assert led2.used_bytes() == 150


# ---------------------------------------------------------------------------------
# Pre-warm policies
# ---------------------------------------------------------------------------------

def _periodic_traces(n_fns=6, period=10.0, horizon=2000.0):
    return [_trace(fn, np.arange(5.0 + fn, horizon, period)) for fn in range(n_fns)]


def test_histogram_keepalive_cuts_cold_starts_on_periodic_load():
    # period 20 min > fixed 15-min keep-alive: fixed policy cold-starts every
    # time, the histogram policy learns the inter-arrival time and covers it
    traces = _periodic_traces(period=20.0)
    base = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="none"))
    hist = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="histogram"))
    assert hist.n_cold < base.n_cold


def test_spes_prewarm_cuts_residency_and_hits():
    traces = _periodic_traces(period=20.0)
    base = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="none"))
    spes = simulate_fleet(traces, "warmswap", CM,
                          FleetConfig(n_workers=2, prewarm="spes"))
    assert spes.prewarm_spawns > 0 and spes.prewarm_hits > 0
    assert spes.instance_resident_min < base.instance_resident_min
    assert spes.n_cold < base.n_cold           # predictions land on periodic load


def test_policy_state_isolation():
    p1, p2 = HistogramKeepAlive(), HistogramKeepAlive()
    p1.on_arrival(0, 1.0)
    p1.on_arrival(0, 2.0)
    assert p2._iats.get(0) is None             # no shared mutable state


# ---------------------------------------------------------------------------------
# Fleet traces
# ---------------------------------------------------------------------------------

def test_zipf_weights_and_image_assignment():
    w = zipf_weights(10, 1.2)
    assert w.sum() == pytest.approx(1.0) and (np.diff(w) < 0).all()
    imgs = assign_images(40, 4, skew=1.2, seed=0)
    assert set(imgs) == {0, 1, 2, 3}           # coverage guarantee
    deg = sharing_degrees(generate_fleet_traces(40, 100.0, seed=0, n_images=4))
    assert sum(deg.values()) == 40


def test_fleet_traces_deterministic():
    a = generate_fleet_traces(8, 500.0, seed=9, n_images=3)
    b = generate_fleet_traces(8, 500.0, seed=9, n_images=3)
    for ta, tb in zip(a, b):
        assert ta.image_id == tb.image_id
        assert np.array_equal(ta.arrivals_min, tb.arrivals_min)
