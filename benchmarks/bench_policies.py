"""Policy benchmarks: the prewarm x placement tournament vs the hindsight
oracle, the per-spec oracle-dominance audit, and (full scale only) paper
Table 2's live restore prototypes.

Three parts, all sharing the canonical validated-cell path
(``benchmarks/common.scenario_cell``) so CI checks their samples like every
other simulation bench:

  * **tournament** — every registered prewarm x placement combination over
    ``benchmarks/scenarios/tournament.json`` (``experiments/tournament.py``
    through the resumable sweep executor), each cell scored on P99 latency /
    byte-minutes / cold starts plus its oracle gap, Pareto front marked.
  * **oracle-gap audit** — every checked-in fleet-engine scenario spec
    (disruption specs included) re-run at smoke scale with the hindsight
    floor (``core/oracle.py``) priced on the *same* trace objects; the
    per-method gaps land in the artifact and ``tools/ci/check_bench.py``
    fails the build on any negative or non-finite gap (the dominance
    invariant). Specs beyond ``AUDIT_MAX_ARRIVALS`` are listed as skipped —
    never silently dropped — and stay covered by the shrunken-grid
    dominance sweep in ``tests/test_oracle_properties.py``.
  * **table2** (full scale only) — the live bulk/lazy/no-pageserver/no-lazy
    restore prototypes over the three dependency-heavy serving functions;
    skipped under ``--smoke`` (the JAX model stack dwarfs the CI budget).

The ``oracle_gap`` block this bench returns is surfaced as a headline in
``results/BENCH_smoke.json`` by ``benchmarks/run.py``.
"""
from __future__ import annotations

import os
from glob import glob
from typing import Dict, List, Tuple

from benchmarks.common import (SCENARIOS_DIR, emit, median, save_json,
                               scenario_cell, scenario_path, smoke_mode)

FUNCTIONS = ["lr_serving", "cnn_serving", "rnn_serving"]
ITERS = 3

#: Audit cap: fleet specs whose (smoke-scaled) traces exceed this many
#: arrivals are reported as skipped in the artifact instead of re-simulated
#: here (the azure_scale pair's smoke overrides keep million-request traces).
AUDIT_MAX_ARRIVALS = 200_000


def _run_tournament(smoke: bool) -> Tuple[Dict, Dict]:
    """The prewarm x placement tournament over the checked-in spec; returns
    ``(tournament_report_dict, base_cell)``."""
    from repro.core.scenario import Scenario
    from repro.experiments import run_file
    from repro.experiments.tournament import run_tournament

    path = scenario_path("tournament")
    base_cell = scenario_cell(run_file(path, smoke=smoke),
                              "tournament_base", prefix="policies")
    rep = run_tournament(Scenario.from_file(path), smoke=smoke)
    for c in rep.cells:
        emit(f"policies/tournament/{c.method}/{c.prewarm}/{c.placement}",
             c.p99_s * 1e6,
             f"gap={c.oracle_gap_total_s:.3f}s "
             f"bytemin={c.byte_minutes / 1e9:.2f}GBmin cold={c.n_cold}"
             f"{' pareto' if c.pareto else ''}")
    return rep.to_dict(), base_cell


def _oracle_gap_audit(smoke: bool) -> Tuple[Dict, Dict]:
    """Dominance audit over every checked-in fleet-engine scenario spec:
    engine result vs hindsight floor on shared trace objects. Returns
    ``(per_spec_gaps, skipped)``."""
    from repro.core.oracle import gap_report, oracle_from_scenario
    from repro.core.scenario import RunOverrides, Scenario, run
    from repro.core.trace_stream import TraceStream
    from repro.core.traces import TRACE_GENERATORS

    per_spec: Dict = {}
    skipped: Dict = {}
    for path in sorted(glob(os.path.join(SCENARIOS_DIR, "*.json"))):
        scn = Scenario.from_file(path)
        if scn.engine == "single":
            continue                   # no fleet policies to dominate
        eff = scn.smoke_scaled() if smoke else scn
        traces = TRACE_GENERATORS.build(eff.traces.name, **eff.traces.kwargs)
        if isinstance(traces, TraceStream):
            # the audit shares one trace-object list between engine and
            # oracle; stream/materialized runs are bit-identical by contract
            # (docs/TRACES.md), so materializing changes nothing it measures
            st, traces = traces, traces.materialize()
            if hasattr(st, "close"):
                st.close()
        n = sum(len(t.arrivals_min) for t in traces)
        if n > AUDIT_MAX_ARRIVALS:
            skipped[eff.name] = n
            emit(f"policies/oracle_audit/{eff.name}", 0.0,
                 f"skipped: {n} arrivals > cap {AUDIT_MAX_ARRIVALS} "
                 f"(covered by tests/test_oracle_properties.py)")
            continue
        result = run(eff, overrides=RunOverrides(traces=traces))
        oracles = oracle_from_scenario(eff, traces=traces)
        per_spec[eff.name] = {}
        for m, raw in result.raw.items():
            g = gap_report(oracles[m], raw)
            per_spec[eff.name][m] = g
            emit(f"policies/oracle_audit/{eff.name}/{m}",
                 g["total_gap_s"] * 1e6,
                 f"p99_gap={g['p99_gap_s'] * 1e3:.2f}ms "
                 f"oracle_total={g['oracle_total_s']:.2f}s")
    return per_spec, skipped


def _gap_headline(tournament: Dict, per_spec: Dict, skipped: Dict) -> Dict:
    """The ``oracle_gap`` block ``check_bench`` gates: global minima over
    every tournament cell and every audited spec x method."""
    gaps_total: List[float] = []
    gaps_p99: List[float] = []
    for c in tournament["cells"]:
        gaps_total.append(c["oracle_gap_total_s"])
        gaps_p99.append(c["oracle_gap_p99_s"])
    for methods in per_spec.values():
        for g in methods.values():
            gaps_total.append(g["total_gap_s"])
            gaps_p99.append(g["p99_gap_s"])
    return {
        "min_total_gap_s": min(gaps_total),
        "min_p99_gap_s": min(gaps_p99),
        "n_cells": len(gaps_total),
        "tournament": tournament["min_gaps"],
        "specs": per_spec,
        "skipped_specs": skipped,
    }


def _run_table2() -> Dict:
    """Paper Table 2: cold/warm starts across the four restore prototypes
    (bulk restore, lazy restore, w/o page server, w/o lazy migration) for
    the three dependency-heavy serving functions — live engines, full scale
    only."""
    from benchmarks.common import build_fleet
    from repro.core import RestorePolicy
    from repro.core import workloads as wl

    mgr, reg, orch = build_fleet()
    rows: Dict = {}
    for policy in [RestorePolicy.BULK, RestorePolicy.LAZY,
                   RestorePolicy.NO_PAGESERVER, RestorePolicy.NO_LAZY]:
        rows[policy.value] = {}
        for fn in FUNCTIONS:
            cold, warm = [], []
            stats = None
            for _ in range(ITERS):
                inst, t = orch.cold_start_warmswap(fn, policy=policy)
                cold.append(t.total)
                req = wl.WORKLOADS[fn].request_builder()
                warm.append(min(inst.invoke(req)[1] for _ in range(3)))
                stats = getattr(inst, "migration_stats", None)
            rows[policy.value][fn] = {
                "cold_s": median(cold),
                "warm_s": median(warm),
                "pages": getattr(stats, "pages_transferred", None),
                "requests": getattr(stats, "requests", None),
                "fault_wait_s": getattr(stats, "fault_wait_s", None),
            }
            emit(f"policy/{policy.value}/{fn}", median(cold) * 1e6,
                 f"warm={median(warm)*1e6:.0f}us pages="
                 f"{rows[policy.value][fn]['pages']}")
    return rows


def run() -> Dict:
    smoke = smoke_mode()
    tournament, base_cell = _run_tournament(smoke)
    per_spec, skipped = _oracle_gap_audit(smoke)
    out: Dict = {
        "tournament_base": base_cell,
        "tournament": tournament,
        "oracle_gap": _gap_headline(tournament, per_spec, skipped),
    }
    if not smoke:
        out["table2"] = _run_table2()
    save_json("bench_policies", out)
    return out


if __name__ == "__main__":
    run()
