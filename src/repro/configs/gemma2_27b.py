"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000, head_dim=128.
[arXiv:2408.00118; hf]. Window 4096 on local layers; attn softcap 50, final softcap 30.
"""
from repro.models.config import ArchConfig, LOCAL_ATTN, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256_000,
    head_dim=128,
    attn_pattern=(LOCAL_ATTN, GLOBAL_ATTN),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    emb_scale=True,
)
