"""The provider-side Dependency Manager: a refcounted pool of live images.

Paper Fig. 4a: the Dependency Manager is the central hub on the worker node. It
  * builds and owns live dependency images (RAM tier),
  * serves migration requests (metadata + page server),
  * dumps cold images to a **disk tier** and revives them without re-running
    initialization (§3.2 "checkpoint images on disk"),
  * enforces a pool capacity with LRU eviction (the provider's cache constraint the
    paper's abstract highlights),
  * accounts memory: pool cost is O(#images), not O(#functions) — the measurable
    claim behind the 88 % saving vs Prebaking (Fig. 7).

Elasticity hook: ``reshard_image`` rebuilds an image's pages under a new mesh/layout
without touching the checkpoint store — a failed/resized serving replica re-warms from
the pool rather than from cold storage.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.image import LiveDependencyImage, build_image
from repro.core.migration import LinkModel, MigrationClient, RestoredImage, RestorePolicy
from repro.core.pages import DEFAULT_PAGE_SIZE


@dataclass
class PoolStats:
    builds: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    revivals: int = 0
    build_s: float = 0.0
    revive_s: float = 0.0


class DependencyManager:
    def __init__(
        self,
        capacity_bytes: Optional[int] = None,
        disk_dir: Optional[str] = None,
        link: LinkModel = LinkModel(),
        page_size: int = DEFAULT_PAGE_SIZE,
    ):
        self.capacity_bytes = capacity_bytes
        self.disk_dir = disk_dir
        self.link = link
        self.page_size = page_size
        self._images: Dict[str, LiveDependencyImage] = {}
        self._on_disk: Dict[str, bool] = {}
        self._builders: Dict[str, Callable[[], Any]] = {}
        self._arch_names: Dict[str, str] = {}
        self._executables: Dict[str, Dict[str, Any]] = {}
        self._treedefs: Dict[str, Any] = {}
        self._pinned: set = set()
        self._lock = threading.RLock()
        self.stats = PoolStats()

    # ------------------------------------------------------------------ registry
    def register_image(
        self,
        image_id: str,
        arch_name: str,
        params_builder: Callable[[], Any],
        *,
        executables: Optional[Dict[str, Any]] = None,
        pin: bool = False,
        build_now: bool = True,
    ) -> None:
        with self._lock:
            self._builders[image_id] = params_builder
            self._arch_names[image_id] = arch_name
            self._executables[image_id] = executables or {}
            if pin:
                self._pinned.add(image_id)
        if build_now:
            self._ensure_live(image_id)

    def has_live(self, image_id: str) -> bool:
        return image_id in self._images

    def known(self, image_id: str) -> bool:
        return image_id in self._builders

    # ------------------------------------------------------------------ build/evict
    def _ensure_live(self, image_id: str) -> LiveDependencyImage:
        with self._lock:
            if image_id in self._images:
                self.stats.hits += 1
                img = self._images[image_id]
                img.last_used = time.monotonic()
                return img
            self.stats.misses += 1
            t0 = time.perf_counter()
            if self._on_disk.get(image_id) and self.disk_dir:
                img = LiveDependencyImage.from_disk(
                    self.disk_dir, image_id, self._treedefs[image_id])
                img.executables = self._executables.get(image_id, {})
                self.stats.revivals += 1
                self.stats.revive_s += time.perf_counter() - t0
            else:
                img = build_image(
                    image_id, self._arch_names[image_id], self._builders[image_id],
                    page_size=self.page_size,
                    executables=self._executables.get(image_id))
                self._treedefs[image_id] = img.treedef
                self.stats.builds += 1
                self.stats.build_s += time.perf_counter() - t0
            self._admit(img)
            return img

    def _admit(self, img: LiveDependencyImage) -> None:
        if self.capacity_bytes is not None:
            needed = img.image_bytes
            while self.pool_bytes() + needed > self.capacity_bytes:
                if not self._evict_lru():
                    break
        self._images[img.metadata.image_id] = img

    def _evict_lru(self) -> bool:
        candidates = [(im.last_used, iid) for iid, im in self._images.items()
                      if iid not in self._pinned and im.refcount == 0]
        if not candidates:
            return False
        _, victim = min(candidates)
        self.evict(victim)
        return True

    def evict(self, image_id: str) -> None:
        """RAM -> disk tier (or drop, if no disk dir; rebuildable via builder)."""
        with self._lock:
            img = self._images.pop(image_id, None)
            if img is None:
                return
            if self.disk_dir:
                img.dump_to_disk(self.disk_dir)
                self._on_disk[image_id] = True
            self.stats.evictions += 1

    # ------------------------------------------------------------------ migration
    def request_migration(
        self,
        image_id: str,
        policy: RestorePolicy = RestorePolicy.BULK,
        link: Optional[LinkModel] = None,
    ) -> RestoredImage:
        """Paper Fig. 4c: look up the image, hand metadata + a page server to the
        container's migration client."""
        img = self._ensure_live(image_id)
        with self._lock:
            img.refcount += 1
            img.last_used = time.monotonic()
        client = MigrationClient(link or self.link)
        return client.migrate(img, policy)

    def release(self, image_id: str) -> None:
        with self._lock:
            if image_id in self._images:
                self._images[image_id].refcount = max(
                    0, self._images[image_id].refcount - 1)

    def executables_for(self, image_id: str) -> Dict[str, Any]:
        return self._ensure_live(image_id).executables

    # ------------------------------------------------------------------ elasticity
    def reshard_image(self, image_id: str,
                      transform: Callable[[Any], Any]) -> None:
        """Rebuild an image's pages under a new layout (elastic mesh change) without
        re-running the original initialization."""
        img = self._ensure_live(image_id)
        params = transform(img.params())
        def builder():
            return params
        new_img = build_image(image_id, img.metadata.arch_name, builder,
                              page_size=self.page_size, executables=img.executables)
        with self._lock:
            self._treedefs[image_id] = new_img.treedef
            self._images[image_id] = new_img

    # ------------------------------------------------------------------ accounting
    def pool_bytes(self) -> int:
        return sum(im.image_bytes for im in self._images.values())

    def metadata_bytes(self) -> int:
        return sum(im.metadata_bytes for im in self._images.values())

    def summary(self) -> Dict[str, Any]:
        return {
            "live_images": sorted(self._images.keys()),
            "pool_bytes": self.pool_bytes(),
            "metadata_bytes": self.metadata_bytes(),
            "stats": self.stats.__dict__,
        }
