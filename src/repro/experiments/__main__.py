"""``python -m repro.experiments`` entry point."""
import sys

from repro.experiments import main

if __name__ == "__main__":
    sys.exit(main())
