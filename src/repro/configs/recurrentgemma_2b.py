"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 (Griffin).

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000, head_dim=256.
[arXiv:2402.19427; hf]. Pattern (recurrent, recurrent, local-attn); 26 = 8x3 + 2
remainder recurrent layers. lru_width=2560, local window 2048.
"""
from repro.models.config import ArchConfig, RECURRENT, LOCAL_ATTN

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    attn_pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    window=2048,
    lru_width=2560,
    conv1d_width=4,
    mlp="geglu",
    tie_embeddings=True,
    emb_scale=True,
)
