"""Pure-jnp oracle for the paged weight-restore gather."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def page_gather_ref(pool: jax.Array, page_ids: jax.Array) -> jax.Array:
    """pool: (P, E); page_ids: (K,) int32 -> out (K, E) = pool[page_ids]."""
    return jnp.take(pool, page_ids, axis=0)
