"""Pallas TPU kernel for the diagonal linear recurrence h_t = a_t·h_{t-1} + b_t.

Serves both the Mamba-1 selective scan (channels = d_inner·ssm_state, flattened) and
the RG-LRU (channels = lru_width). Grid ``(B, n_chunks)`` with the chunk axis
innermost and sequential; the inter-chunk state is carried in VMEM scratch (persists
across sequential grid steps on TPU), so HBM traffic is exactly one read of (a, b) and
one write of h — the memory-bound optimum. Within a chunk the recurrence is a
``fori_loop`` over rows of the VMEM-resident block: on TPU this is a (chunk_len)-step
VPU chain over lanes-of-C vectors, which pipelines with the next block's DMA.

Channel blocking (grid dim 2) keeps the block (chunk, block_c) within VMEM for large
C (falcon-mamba: C = d_inner·N = 131072 fp32 -> block_c = 2048 gives 2 MB blocks).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_CHUNK = 128
DEFAULT_BLOCK_C = 2048


def _recurrence_kernel(a_ref, b_ref, h0_ref, h_ref, carry, *, chunk: int):
    j = pl.program_id(1)  # chunk index (sequential)

    @pl.when(j == 0)
    def _init():
        carry[...] = h0_ref[0]

    a = a_ref[0]            # (chunk, bc)
    b = b_ref[0]

    def body(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, carry[...])
    carry[...] = h


def diag_recurrence_pallas(
    a: jax.Array,            # (B, S, C) fp32
    b: jax.Array,            # (B, S, C)
    h0: jax.Array,           # (B, C)
    *,
    chunk: int = DEFAULT_CHUNK,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h_all (B, S, C), h_final (B, C))."""
    B, S, C = a.shape
    chunk = max(1, min(chunk, S))
    block_c = max(8, min(block_c, C))
    pad_s = (-S) % chunk
    pad_c = (-C) % block_c
    if pad_s or pad_c:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_c)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_c)))
    if pad_c:
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c)))
    Sp, Cp = a.shape[1], a.shape[2]
    n_chunks, n_cblocks = Sp // chunk, Cp // block_c

    kernel = functools.partial(_recurrence_kernel, chunk=chunk)
    h_all = pl.pallas_call(
        kernel,
        grid=(B * n_cblocks, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_c),
                         lambda bc, j, n=n_cblocks: (bc // n, j, bc % n)),
            pl.BlockSpec((1, chunk, block_c),
                         lambda bc, j, n=n_cblocks: (bc // n, j, bc % n)),
            pl.BlockSpec((1, block_c), lambda bc, j, n=n_cblocks: (bc // n, bc % n)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_c),
                               lambda bc, j, n=n_cblocks: (bc // n, j, bc % n)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Cp), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_c,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    h_all = h_all[:, :S, :C]
    return h_all, h_all[:, -1]
