"""Regenerate ``azure_sample.csv.gz`` — the checked-in Azure-schema fixture
behind ``benchmarks/scenarios/azure_csv_stream.json`` and the streaming tests.

The layout mirrors the public Azure Functions invocation dataset: leading id
columns (``HashOwner/HashApp/HashFunction/Trigger``), then one integer count
column per minute of one day. Functions sharing a ``HashApp`` share a
dependency image; rates are lognormal-skewed like the paper's §4.5 fit, so
the fixture exercises the same heavy-skew regime as the synthetic fleets.

Byte-deterministic: fixed seed, ``gzip.GzipFile(mtime=0)`` (no timestamp in
the member header). Run from the repo root::

    PYTHONPATH=src python benchmarks/data/make_azure_sample.py
"""
import gzip
import io
import os

import numpy as np

N_FUNCTIONS = 64
N_APPS = 12
MINUTES = 1440
SEED = 20260809

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "azure_sample.csv.gz")


def render_csv() -> bytes:
    rng = np.random.default_rng(SEED)
    # lognormal-skewed per-function rates, clipped so the busiest functions
    # dominate (the Azure regime) but the file stays small
    rates = np.minimum(np.exp(rng.normal(-1.5, 1.6, size=N_FUNCTIONS)), 8.0)
    apps = rng.integers(0, N_APPS, size=N_FUNCTIONS)
    buf = io.StringIO()
    header = ["HashOwner", "HashApp", "HashFunction", "Trigger"]
    header += [str(m) for m in range(1, MINUTES + 1)]
    buf.write(",".join(header) + "\n")
    for f in range(N_FUNCTIONS):
        counts = rng.poisson(rates[f], size=MINUTES)
        row = [f"owner{apps[f]:04x}", f"app{apps[f]:04x}",
               f"fn{f:08x}", "http"]
        # the Azure schema writes absent minutes as empty cells
        row += [str(c) if c else "" for c in counts]
        buf.write(",".join(row) + "\n")
    return buf.getvalue().encode()


def main() -> None:
    raw = render_csv()
    with open(OUT, "wb") as f:
        with gzip.GzipFile(fileobj=f, mode="wb", filename="", mtime=0) as gz:
            gz.write(raw)
    print(f"wrote {OUT} ({os.path.getsize(OUT)} bytes, "
          f"{len(raw)} uncompressed)")


if __name__ == "__main__":
    main()
