"""Paper Fig. 7 + §4.5 case study: ten functions sharing ONE dependency image under
two-week Azure-statistics traces — average latency per invocation-rate quartile and
required warm-up memory, WarmSwap vs Prebaking vs Baseline.

Runs twice: once with the PAPER's measured cost numbers (Table 2; the faithful
simulation) and once with THIS machine's measured cold-start costs (from
bench_coldstart artifacts when present)."""
from __future__ import annotations

import json
import os
from typing import Dict

from benchmarks.common import RESULTS_DIR, emit, save_json, smoke_mode


def _measured_cost_model():
    from repro.core.simulator import CostModel
    path = os.path.join(RESULTS_DIR, "bench_coldstart.json")
    if not os.path.exists(path):
        return None
    rows = json.load(open(path))
    rnn = rows.get("rnn_serving")
    if not rnn:
        return None
    return CostModel(
        cold_warmswap_s=rnn["cold_warmswap_s"],
        cold_prebaking_s=rnn["cold_warmswap_s"] * 1.05,  # prebake ~ bulk restore
        cold_baseline_s=rnn["cold_baseline_s"],
        warm_s=rnn["warm_warmswap_s"],
    )


def run() -> Dict:
    from repro.core.keepalive import KeepAlivePolicy
    from repro.core.simulator import (CostModel, memory_saving_fraction,
                                      quartile_latencies, simulate)
    from repro.core.traces import generate_traces

    horizon_min = (24 * 60 if smoke_mode() else 2 * 7 * 24 * 60)
    traces = generate_traces(10, horizon_min=horizon_min, seed=0)
    out: Dict = {}
    models = {"paper_costs": CostModel.paper_table2()}
    measured = _measured_cost_model()
    if measured is not None:
        models["measured_costs"] = measured

    for label, cm in models.items():
        res = {}
        for method in ("warmswap", "prebaking", "baseline"):
            r = simulate(traces, method, cm, KeepAlivePolicy(15.0))
            res[method] = {
                "avg_latency_s": r.avg_latency_s,
                "cold": r.n_cold, "warm": r.n_warm,
                "memory_mb": r.memory_bytes / 1e6,
                "quartile_latency_s": quartile_latencies(traces, r),
            }
            emit(f"sharing/{label}/{method}", r.avg_latency_s * 1e6,
                 f"mem={r.memory_bytes/1e6:.0f}MB cold={r.n_cold}")
        saving = 1.0 - (res["warmswap"]["memory_mb"] /
                        max(res["prebaking"]["memory_mb"], 1e-9))
        speed = (res["prebaking"]["avg_latency_s"] /
                 max(res["warmswap"]["avg_latency_s"], 1e-12))
        res["memory_saving_vs_prebaking"] = saving
        res["latency_ratio_vs_prebaking"] = speed
        emit(f"sharing/{label}/headline", saving * 100,
             f"memory_saving_pct (paper: 88); warmswap x{speed:.2f} vs prebaking")
        out[label] = res
    save_json("bench_sharing", out)
    return out


if __name__ == "__main__":
    run()
