"""repro-lint determinism checker: each rule flags a seeded violation and
stays quiet on the sanctioned/deterministic twin (docs/ANALYSIS.md)."""
import textwrap

from tools.analysis import determinism
from tools.analysis.base import SourceFile

SCOPED = "src/repro/core/_fixture.py"


def parse(tmp_path, code, rel=SCOPED):
    p = tmp_path / "fixture.py"
    p.write_text(textwrap.dedent(code))
    src = SourceFile.parse(str(p))
    src.rel = rel  # place the tmp fixture inside the checker's scope
    return src


def rules(findings):
    return sorted(f.rule for f in findings)


def test_unseeded_global_rng_flagged(tmp_path):
    src = parse(tmp_path, """
        import numpy as np
        import random

        def draw():
            a = np.random.rand(3)
            b = random.random()
            return a, b
    """)
    assert rules(determinism.check(src)) == ["unseeded-rng", "unseeded-rng"]


def test_seeded_generators_clean_unseeded_factory_flagged(tmp_path):
    src = parse(tmp_path, """
        import numpy as np
        import random

        def good(seed):
            rng = np.random.default_rng(seed)
            r = random.Random(seed)
            return rng.normal(), r.random()

        def bad():
            return np.random.default_rng().normal()
    """)
    found = determinism.check(src)
    assert rules(found) == ["unseeded-rng"]
    assert found[0].scope == "bad"


def test_wall_clock_flagged_interval_timers_sanctioned(tmp_path):
    src = parse(tmp_path, """
        import time
        import datetime

        def stamp():
            t0 = time.perf_counter()      # sanctioned interval timer
            now = time.time()
            mono = time.monotonic()
            today = datetime.datetime.now()
            return now, mono, today, time.perf_counter() - t0
    """)
    assert rules(determinism.check(src)) == ["wall-clock"] * 3


def test_wall_clock_pragma_suppresses(tmp_path):
    src = parse(tmp_path, """
        import time

        def lru_touch(img):
            # live-manager clock  # repro-lint: allow[wall-clock]
            img.last_used = time.monotonic()
    """)
    assert determinism.check(src) == []


def test_hash_randomization_flagged(tmp_path):
    src = parse(tmp_path, """
        def seed_for(tenant):
            return hash(tenant) % 100
    """)
    assert rules(determinism.check(src)) == ["hash-randomization"]


def test_set_iteration_flagged_sorted_clean(tmp_path):
    src = parse(tmp_path, """
        def render(names, sep):
            live = set(names)
            for n in live:
                print(n)
            joined = sep.join(live)
            ordered = sorted(live)      # deterministic: not flagged
            return joined, ordered
    """)
    assert rules(determinism.check(src)) == ["set-iteration", "set-iteration"]


def test_environ_read_flagged_outside_entry_points(tmp_path):
    src = parse(tmp_path, """
        import os

        def knob():
            return os.environ.get("REPRO_SECRET_KNOB", "0")

        def knob2():
            return os.environ["REPRO_SECRET_KNOB"]

        def knob3():
            return os.getenv("REPRO_SECRET_KNOB")
    """)
    assert rules(determinism.check(src)) == ["environ-read"] * 3


def test_environ_sanctioned_entry_point_clean(tmp_path):
    src = parse(tmp_path, """
        import os

        def smoke_mode():
            return os.environ.get("REPRO_BENCH_SMOKE") == "1"
    """, rel="benchmarks/common.py")
    assert determinism.check(src) == []


def test_out_of_scope_file_skipped(tmp_path):
    src = parse(tmp_path, """
        import time

        def live_side():
            return time.time()
    """, rel="src/repro/serving/_fixture.py")
    assert determinism.check(src) == []
