import os

# Smoke tests and benches must see the single real device; ONLY the dry-run launcher
# forces 512 host devices (and it does so in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
