"""qwen3-1.7b [dense] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, head_dim=128.
[hf:Qwen/Qwen3-8B family; hf].
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    head_dim=128,
    attn_pattern=(GLOBAL_ATTN,),
    qk_norm=True,
    mlp="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
