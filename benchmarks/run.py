"""Benchmark driver — one benchmark per paper table/figure + assignment artifacts.

    PYTHONPATH=src python -m benchmarks.run [--only coldstart,...]

Emits ``name,us_per_call,derived`` CSV rows (stdout) and JSON artifacts under
results/.  Mapping to the paper:

    bench_coldstart  ->  Figs. 3, 5, 6 (cold/warm, phase breakdown)
    bench_policies   ->  Table 2 (bulk / lazy / no-pageserver / no-lazy)
    bench_metadata   ->  Table 3 (metadata vs image size)
    bench_sharing    ->  Fig. 7 + 88% memory headline (Azure-trace simulation)
    bench_fleet      ->  multi-worker fleet sweep (workers x capacity x skew x
                         sharing), placement + pre-warm policy comparison,
                         queue-accurate P50/P95/P99 per rate quartile
                         (NaN/negative latencies fail the run)
    bench_kernels    ->  kernel-path microbenches + VMEM accounting
    bench_roofline   ->  assignment §Roofline table (from dry-run artifacts)

``--smoke`` shrinks the simulation suites (sharing, fleet) to CI size; the
measurement suites (coldstart, policies, kernels, ...) always do real work.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

BENCHES = ["coldstart", "policies", "metadata", "sharing", "fleet", "kernels",
           "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {BENCHES}")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized runs for the simulation suites "
                         "(sharing, fleet); pair with --only")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_SMOKE"] = "1"
    todo = args.only.split(",") if args.only else BENCHES

    print("name,us_per_call,derived")
    failures = 0
    for name in todo:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
            print(f"# {name}: ok ({time.perf_counter() - t0:.1f}s)", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}", file=sys.stderr)
    sys.exit(int(failures > 0))


if __name__ == "__main__":
    main()
