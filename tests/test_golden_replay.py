"""Golden replay regression: a tiny canonical trace (tests/data/
golden_trace.json) with its expected per-request latency/wait vectors
(tests/data/golden_expected.json), asserted EXACTLY — `==` on every float —
by BOTH fleet engines.  Any change to event ordering, keep-alive arithmetic,
queue discipline, or the vectorized solver that shifts a single sample by one
ULP fails here with a pinpointed request index.

The fixture stores the arrival floats verbatim (JSON round-trips doubles
exactly), plus the generator kwargs that reproduce them, so the fixture can
be regenerated deliberately — never silently.
"""
import json
import os

import numpy as np
import pytest

from repro.core.fleet import FleetConfig, _simulate_fleet_impl
from repro.core.fleet_vec import simulate_fleet_vec
from repro.core.simulator import CostModel
from repro.core.traces import Trace, generate_fleet_traces

DATA = os.path.join(os.path.dirname(__file__), "data")


def _load():
    doc = json.load(open(os.path.join(DATA, "golden_trace.json")))
    exp = json.load(open(os.path.join(DATA, "golden_expected.json")))
    traces = [Trace(d["fn_index"], d["rate_per_min"],
                    np.array(d["arrivals_min"], np.float64),
                    image_id=d["image_id"])
              for d in doc["traces"]]
    return doc, exp, traces


def _check(r, want, label):
    for name in ("latency_samples_s", "queue_wait_s", "sample_fn"):
        got = getattr(r, name)
        ref = np.array(want[name], got.dtype)
        bad = np.flatnonzero(got != ref)
        assert bad.size == 0, \
            f"{label}: {name} differs at request {bad[0]}: " \
            f"{got[bad[0]]!r} != {ref[bad[0]]!r}"
    assert (r.n_cold, r.n_warm, r.n_queued) == \
        (want["n_cold"], want["n_warm"], want["n_queued"]), label
    assert r.total_latency_s == want["total_latency_s"], label
    assert r.memory_bytes == want["memory_bytes"], label
    assert r.instance_resident_min == want["instance_resident_min"], label


@pytest.mark.parametrize("engine", ["fleet", "fleet_vec"])
@pytest.mark.parametrize("method", ["warmswap", "prebaking", "baseline"])
def test_golden_replay(engine, method):
    doc, exp, traces = _load()
    cost = CostModel.paper_table2()
    fc = FleetConfig(**doc["fleet"])
    impl = simulate_fleet_vec if engine == "fleet_vec" else _simulate_fleet_impl
    r = impl(traces, method, cost, fc)
    _check(r, exp["methods"][method], f"{engine}/{method}")


def test_golden_fixture_regenerates_from_kwargs():
    """The stored arrivals are exactly what the generator kwargs produce —
    the fixture documents its own provenance and stays regenerable."""
    doc, _, traces = _load()
    regen = generate_fleet_traces(**doc["generator_kwargs"])
    assert len(regen) == len(traces)
    for a, b in zip(regen, traces):
        assert (a.fn_index, a.image_id) == (b.fn_index, b.image_id)
        assert a.rate_per_min == b.rate_per_min
        assert np.array_equal(a.arrivals_min, b.arrivals_min)


def test_golden_exercises_queueing():
    """The fixture stays meaningful: it must include cold starts AND queued
    requests, else a queue-discipline regression would pass unnoticed."""
    _, exp, _ = _load()
    for method, want in exp["methods"].items():
        assert want["n_cold"] >= 3, method
        assert want["n_queued"] >= 1, method
        assert any(w > 0 for w in want["queue_wait_s"]), method
