#!/usr/bin/env python
"""Nightly ≥10M-invocation streamed-ingestion cell (out-of-core proof).

Generates an Azure-schema gzip CSV (one week, lognormal-skewed rates) by
*streaming writes* — row by row, never holding the table — then replays it
end-to-end through the chunked path: ``AzureCsvStream`` spills per-window
binaries at parse time and the event engine consumes arrival chunks
natively. Two bounds are CI-asserted:

  * ``ru_maxrss`` stays under ``--rss-budget-mb`` (default 3072 MB): the
    process never holds the materialized trace (~10M arrivals would add
    hundreds of MB *on top of* the engine's unavoidable per-request sample
    buffers);
  * ``peak_resident_arrivals`` — the largest arrival chunk the engine ever
    held — stays under ``--resident-frac`` (default 10 %) of the total, the
    direct out-of-core witness.

Bit-identity of streamed vs in-memory execution is enforced per-spec by
``tests/test_stream_equiv.py`` (tier-1); this cell holds the *scale* line
the paper's 100M target needs. The sha256 of the streamed sample array is
recorded for cross-run determinism. Artifact: ``results/STREAM_scale.json``.

    PYTHONPATH=src python tools/ci/stream_scale.py
"""
import argparse
import gzip
import hashlib
import json
import os
import resource
import sys
import tempfile
import time

import numpy as np

N_FUNCTIONS = 1500
MINUTES = 10080                  # one week of per-minute columns
SEED = 42
BLOCK_MIN = 360.0                # 6-hour spill windows -> small chunks
TARGET_INVOCATIONS = 10_000_000


def write_csv(path: str, target: int) -> int:
    """Stream an Azure-schema gzip CSV with ~``target`` total invocations
    (Poisson-concentrated, so the realized sum is within a fraction of a
    percent). Returns the realized invocation count."""
    rng = np.random.default_rng(SEED)
    raw = np.exp(rng.normal(-1.0, 1.5, size=N_FUNCTIONS))
    # 1% margin over the target so the Poisson realization clears the floor
    rates = raw * (target * 1.01 / (raw.sum() * MINUTES))
    apps = rng.integers(0, 64, size=N_FUNCTIONS)
    total = 0
    with gzip.open(path, "wt", compresslevel=1, newline="") as f:
        header = ["HashOwner", "HashApp", "HashFunction", "Trigger"]
        header += [str(m) for m in range(1, MINUTES + 1)]
        f.write(",".join(header) + "\n")
        for fn in range(N_FUNCTIONS):
            counts = rng.poisson(rates[fn], size=MINUTES)
            total += int(counts.sum())
            row = [f"owner{apps[fn]:04x}", f"app{apps[fn]:04x}",
                   f"fn{fn:08x}", "http"]
            row += [str(c) if c else "" for c in counts]
            f.write(",".join(row) + "\n")
    return total


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target-invocations", type=int,
                    default=TARGET_INVOCATIONS)
    ap.add_argument("--rss-budget-mb", type=float, default=3072.0)
    ap.add_argument("--resident-frac", type=float, default=0.10)
    ap.add_argument("--out", default="results/STREAM_scale.json")
    args = ap.parse_args(argv)

    from repro.core.fleet import FleetConfig, simulate_fleet
    from repro.core.simulator import CostModel
    from repro.core.trace_stream import AzureCsvStream

    with tempfile.TemporaryDirectory(prefix="repro-stream-scale-") as tmp:
        csv_path = os.path.join(tmp, "azure_week.csv.gz")
        t0 = time.perf_counter()
        written = write_csv(csv_path, args.target_invocations)
        gen_wall_s = time.perf_counter() - t0
        csv_mb = os.path.getsize(csv_path) / 1e6
        print(f"# generated {written:,} invocations "
              f"({csv_mb:.0f} MB gz) in {gen_wall_s:.1f}s", file=sys.stderr)

        t0 = time.perf_counter()
        stream = AzureCsvStream(csv_path, n_functions=N_FUNCTIONS,
                                horizon_min=float(MINUTES), seed=0,
                                block_min=BLOCK_MIN, chunk_min=BLOCK_MIN)
        ingest_wall_s = time.perf_counter() - t0
        try:
            t0 = time.perf_counter()
            res = simulate_fleet(stream, "warmswap", CostModel.paper_table2(),
                                 FleetConfig(n_workers=4))
            replay_wall_s = time.perf_counter() - t0
            stats = stream.stats
        finally:
            stream.close()

    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    sha = hashlib.sha256(
        np.ascontiguousarray(res.latency_samples_s).tobytes()).hexdigest()
    frac = stats.peak_resident_arrivals / max(stats.n_arrivals, 1)
    cell = {
        "n_invocations": res.n_invocations,
        "csv_invocations": written,
        "csv_mb_gz": csv_mb,
        "n_chunks": stats.n_chunks,
        "peak_resident_arrivals": stats.peak_resident_arrivals,
        "resident_fraction": frac,
        "ru_maxrss_mb": rss_mb,
        "rss_budget_mb": args.rss_budget_mb,
        "gen_wall_s": gen_wall_s,
        "ingest_wall_s": ingest_wall_s,
        "replay_wall_s": replay_wall_s,
        "invocations_per_s": res.n_invocations / max(replay_wall_s, 1e-9),
        "latency_samples_sha256": sha,
        "total_latency_s": res.total_latency_s,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"stream_scale": cell}, f, indent=1, sort_keys=True)
    print(f"# wrote {args.out}", file=sys.stderr)

    assert res.n_invocations == written, \
        f"streamed replay saw {res.n_invocations:,} of {written:,} " \
        f"CSV invocations — arrivals were dropped"
    assert res.n_invocations >= args.target_invocations, \
        f"replayed only {res.n_invocations:,} invocations " \
        f"(target {args.target_invocations:,})"
    assert frac <= args.resident_frac, \
        f"peak resident arrivals {stats.peak_resident_arrivals:,} is " \
        f"{frac:.1%} of the trace (budget {args.resident_frac:.0%}) — " \
        f"chunking is not actually out-of-core"
    assert rss_mb <= args.rss_budget_mb, \
        f"peak RSS {rss_mb:.0f} MB over the {args.rss_budget_mb:.0f} MB " \
        f"budget — the streaming path is materializing state it must not"
    print(f"ok: {res.n_invocations:,} invocations via {stats.n_chunks} "
          f"chunks in {replay_wall_s:.1f}s, peak resident "
          f"{stats.peak_resident_arrivals:,} ({frac:.1%}), "
          f"RSS {rss_mb:.0f} MB (< {args.rss_budget_mb:.0f} MB)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
