from repro.runtime.fault_tolerance import (
    InjectedFailure,
    ReplicaSet,
    SupervisorConfig,
    TrainSupervisor,
)

__all__ = ["InjectedFailure", "ReplicaSet", "SupervisorConfig", "TrainSupervisor"]
