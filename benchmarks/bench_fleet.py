"""Fleet-scale simulation sweep: workers x pool-capacity x skew x sharing-degree.

Every simulation cell here is **driven by a checked-in scenario spec**
(``benchmarks/scenarios/*.json``) through the experiments CLI's programmatic
entry points (``repro.experiments.run_file`` / ``sweep_file``) — the bench
suite, the CLI, and CI all exercise one code path. Sweep axes are dotted
paths into the spec (``n_workers``, ``traces.kwargs.n_images``,
``placement.name``), expanded by ``repro.core.scenario.sweep``.

Cells (per method — WarmSwap / Prebaking / Baseline — under identical
placement): latency quartiles AND per-request tail percentiles (P50/P95/P99
per invocation-rate quartile, from the event engine's latency samples), peak
resident memory, pool-miss/eviction/queueing behaviour, the pre-warm-policy
comparison, and the page-granular cost model + cluster-shared image cache.

Also re-derives Fig. 7 as the degenerate point (1 worker, unlimited capacity,
one instance per function) and checks it against the legacy
``simulator.simulate()`` wrapper — including the ~88 % memory-saving headline
at sharing degree 10 and the paper's 2.2–3.2x dependency-loading band — so
degenerate equivalence is asserted through the declarative path on every run.

Every cell's latency samples are validated (``benchmarks/common.py``): NaN or
negative latencies fail the run (the CI smoke job relies on this).

    PYTHONPATH=src python -m benchmarks.run --only fleet [--smoke]
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import (emit, pick, save_json, scenario_cell,
                               scenario_path, smoke_mode, validated_samples)

METHODS = ("warmswap", "prebaking", "baseline")


def run() -> Dict:
    from repro.core.keepalive import KeepAlivePolicy
    from repro.core.simulator import CostModel, simulate
    from repro.core.traces import sharing_degrees
    from repro.experiments import run_file, sweep_file

    cm = CostModel.paper_table2()
    smoke = smoke_mode()
    out: Dict = {}

    # ------------------------------------------------------------- degenerate point
    # 1 worker, unlimited capacity, 1 instance/function == simulate() == Fig. 7.
    # The scenario path must agree with the legacy wrapper bit for bit.
    res = run_file(scenario_path("degenerate"), smoke=smoke)
    traces10 = res.traces
    degenerate: Dict = {}
    for method in METHODS:
        rf = res.raw[method]
        rs = simulate(traces10, method, cm, KeepAlivePolicy(15.0))
        drift = abs(rf.total_latency_s - rs.total_latency_s)
        degenerate[method] = {
            "fleet_avg_latency_s": rf.avg_latency_s,
            "simulate_avg_latency_s": rs.avg_latency_s,
            "latency_drift_s": drift,
            "memory_match": rf.memory_bytes == rs.memory_bytes,
        }
        assert drift < 1e-6 and rf.memory_bytes == rs.memory_bytes, \
            f"degenerate scenario run diverged from simulate() for {method}"
    saving = res.summary["memory_saving_vs_prebaking"]
    degenerate["memory_saving_vs_prebaking"] = saving
    emit("fleet/degenerate/headline", saving * 100,
         "memory_saving_pct at sharing degree 10 (paper: 88)")
    out["degenerate"] = degenerate

    # ------------------------------------------------------------------ the sweep
    # One base spec (fleet_base.json), grid axes expanded by sweep().
    img = cm.image_bytes
    out["sweep"] = {}
    for r in sweep_file(scenario_path("fleet_base"),
                        {"n_workers": pick([1, 2, 4, 8], [1, 4])},
                        smoke=smoke):
        w = r.scenario["n_workers"]
        out["sweep"][f"workers={w}"] = scenario_cell(r, f"workers={w}")
    caps = pick([1, 2, 4, None], [2])
    for cap, r in zip(caps, sweep_file(
            scenario_path("fleet_base"),
            {"worker_capacity_bytes": [None if c is None else c * img
                                       for c in caps]}, smoke=smoke)):
        out["sweep"][f"capacity={cap}"] = scenario_cell(r, f"capacity={cap}")
    for r in sweep_file(scenario_path("fleet_base"),
                        {"traces.kwargs.n_images": pick([1, 2, 5, 10],
                                                [4])}, smoke=smoke):
        n_img = r.scenario["traces"]["kwargs"]["n_images"]
        cell = scenario_cell(r, f"images={n_img}")
        cell["sharing_degrees"] = sharing_degrees(r.traces)
        out["sweep"][f"images={n_img}"] = cell
    for r in sweep_file(scenario_path("fleet_base"),
                        {"traces.kwargs.rate_skew": pick([0.6, 1.1, 1.6],
                                                 [1.1])}, smoke=smoke):
        s = r.scenario["traces"]["kwargs"]["rate_skew"]
        out["sweep"][f"skew={s}"] = scenario_cell(r, f"skew={s}")

    # ------------------------------------------------------------ queueing cell
    # Capped concurrency under the same workload: queue delay becomes visible
    # and the tail separates from the mean.
    out["queueing"] = {}
    for cap, r in zip((None, 2, 1), sweep_file(
            scenario_path("queueing"),
            {"max_instances_per_fn": [None, 2, 1]}, smoke=smoke)):
        rw = r.raw["warmswap"]
        s = validated_samples(rw, f"fleet/cap={cap}/warmswap")
        pct = rw.latency_percentiles()
        out["queueing"][f"cap={cap}"] = {
            "avg_latency_s": rw.avg_latency_s,
            "latency_percentiles_s": pct,
            "queued": rw.n_queued, "queue_delay_s": rw.queue_delay_s,
        }
        emit(f"fleet/cap={cap}/warmswap", rw.avg_latency_s * 1e6,
             f"p99={pct['p99'] * 1e3:.1f}ms queued={rw.n_queued} "
             f"queue_delay={rw.queue_delay_s:.2f}s")
        assert s.size == 0 or pct["p99"] >= pct["p50"], "percentiles inverted"

    # --------------------------------------------------------- page-cost model
    # Cold starts priced by page transfer volume (core/costmodel.py) instead
    # of scalar constants, plus the cluster-shared image cache tier. Cells:
    #   * degenerate contract — infinite bandwidth reproduces the scalar
    #     engine exactly (also covered by tests/test_costmodel.py);
    #   * latency vs image size — HotSwap (shared image, half-resident,
    #     remote tier) must lie STRICTLY between warm and cold at every size,
    #     and the dependency-loading speedup at the paper's ~230 MB image
    #     lands inside the paper's 2.2-3.2x band;
    #   * cache footprint — HotSwap's shared tier holds one image per
    #     dependency vs Prebaking's snapshot per function (the 88 % story
    #     restated at the cluster-cache level);
    #   * a capacity-bounded shared cache showing remote hits and source
    #     misses under placement that is bandwidth/residency aware.
    from repro.core.costmodel import PageCostModel

    model = PageCostModel(cost=cm)
    page_out: Dict = {}
    res_deg = run_file(scenario_path("page_degenerate"), smoke=smoke)
    for method in METHODS:
        rf = res_deg.raw[method]
        rs = simulate(res_deg.traces, method, cm, KeepAlivePolicy(15.0))
        assert (abs(rf.total_latency_s - rs.total_latency_s) < 1e-9
                and rf.memory_bytes == rs.memory_bytes), \
            f"degenerate page model diverged from simulate() for {method}"
    page_out["degenerate_equals_scalar"] = True

    sizes_mb = pick([32, 64, 128, 230, 512, 1024], [64, 128, 230, 512])
    size_cell: Dict = {}
    for mb in sizes_mb:
        nbytes = mb << 20
        total = model.image_pages(nbytes)
        warm_s = cm.warm_s
        hotswap_s = model.cold_latency_s("warmswap", tier="remote",
                                         resident_pages=total // 2,
                                         image_bytes=nbytes)
        cold_s = model.cold_latency_s("baseline", image_bytes=nbytes)
        speedup = model.dependency_loading_speedup(tier="local",
                                                   image_bytes=nbytes)
        assert warm_s < hotswap_s < cold_s, \
            f"HotSwap latency not strictly between warm and cold at {mb} MB"
        size_cell[f"{mb}MB"] = {
            "pages": total, "warm_s": warm_s, "hotswap_s": hotswap_s,
            "cold_s": cold_s, "dependency_loading_speedup": speedup,
        }
        emit(f"fleet/page_model/image={mb}MB", hotswap_s * 1e6,
             f"warm={warm_s * 1e3:.1f}ms cold={cold_s * 1e3:.0f}ms "
             f"pages={total} dep_speedup={speedup:.2f}x")
    page_out["latency_vs_image_size"] = size_cell
    paper_speedup = size_cell["230MB"]["dependency_loading_speedup"]
    assert 2.2 <= paper_speedup <= 3.2, \
        f"dependency-loading speedup {paper_speedup:.2f}x outside the " \
        f"paper's 2.2-3.2x band at the ~230 MB paper-scale image"
    page_out["dependency_loading_speedup_paper_scale"] = paper_speedup
    emit("fleet/page_model/dep_speedup_paper_scale", paper_speedup,
         "baseline/warmswap dependency-loading ratio (paper band: 2.2-3.2x)")

    res_page = run_file(scenario_path("page_sharing"), smoke=smoke)
    # the scenario path reports the same speedup through its own summary
    assert res_page.summary["dependency_loading_speedup"] == paper_speedup
    rw, rp = res_page.raw["warmswap"], res_page.raw["prebaking"]
    validated_samples(rw, "fleet/page_model/warmswap")
    validated_samples(rp, "fleet/page_model/prebaking")
    assert rp.shared_cache_peak_bytes > rw.shared_cache_peak_bytes > 0
    footprint_saving = 1.0 - rw.shared_cache_peak_bytes / rp.shared_cache_peak_bytes
    # the same comparison on the HEADLINE workload (10 fns, ONE image): the
    # shared tier holds 1 image vs 10 snapshots -> 90 % (the 88 % headline
    # counts warmswap's per-fn metadata too; the tier holds images only)
    res_head = run_file(scenario_path("page_headline"), smoke=smoke)
    rwh, rph = res_head.raw["warmswap"], res_head.raw["prebaking"]
    headline_saving = 1.0 - (rwh.shared_cache_peak_bytes
                             / rph.shared_cache_peak_bytes)
    assert headline_saving > 0.85
    page_out["cache_footprint"] = {
        "headline_workload_saving_fraction": headline_saving,
        "hotswap_shared_peak_mb": rw.shared_cache_peak_bytes / 1e6,
        "prebaking_shared_peak_mb": rp.shared_cache_peak_bytes / 1e6,
        "hotswap_peak_memory_mb": rw.memory_bytes / 1e6,
        "prebaking_peak_memory_mb": rp.memory_bytes / 1e6,
        "saving_fraction": footprint_saving,
        "hotswap_tiers": {"local": rw.cache_local_hits,
                          "remote": rw.cache_remote_hits,
                          "miss": rw.cache_misses},
        "hotswap_pages_transferred": rw.pages_transferred,
    }
    emit("fleet/page_model/cache_footprint", footprint_saving * 100,
         f"shared-tier saving % (hotswap {rw.shared_cache_peak_bytes >> 20}MB "
         f"vs prebaking {rp.shared_cache_peak_bytes >> 20}MB)")

    rb = run_file(scenario_path("bounded_cache"), smoke=smoke).raw["warmswap"]
    validated_samples(rb, "fleet/page_model/bounded_cache")
    page_out["bounded_shared_cache"] = {
        "avg_latency_s": rb.avg_latency_s,
        "tiers": {"local": rb.cache_local_hits, "remote": rb.cache_remote_hits,
                  "miss": rb.cache_misses},
        "cluster_evictions": rb.shared_cache_evictions,
        "pages_transferred": rb.pages_transferred,
    }
    emit("fleet/page_model/bounded_cache", rb.avg_latency_s * 1e6,
         f"local={rb.cache_local_hits} remote={rb.cache_remote_hits} "
         f"miss={rb.cache_misses} evict={rb.shared_cache_evictions}")
    out["page_model"] = page_out

    # ----------------------------------------------------- production scale
    # The azure_scale scenario replays a ≥1M-invocation week-long Zipf fleet
    # through the hot-path engine (batched trace generation + O(1) placement
    # signals + dataclass-free events). The invocation floor holds at smoke
    # scale too — smoke only trims the method list — and the wall clock is
    # recorded into the artifact so CI's bench job can hold the "a million
    # invocations simulate in under a minute" line (tools/ci/check_bench.py).
    t0 = time.perf_counter()
    res_scale = run_file(scenario_path("azure_scale"), smoke=smoke)
    scale_wall_s = time.perf_counter() - t0
    n_inv = max(r.n_invocations for r in res_scale.raw.values())
    assert n_inv >= 1_000_000, \
        f"azure_scale must exercise >= 1M invocations, got {n_inv}"
    cell = scenario_cell(res_scale, "azure_scale")
    total_req = sum(r.n_invocations for r in res_scale.raw.values())
    out["azure_scale"] = {
        "n_invocations": n_inv,
        "n_methods": len(res_scale.raw),
        "wall_clock_s": scale_wall_s,
        "invocations_per_s": total_req / max(scale_wall_s, 1e-9),
        "methods": cell,
    }
    emit("fleet/azure_scale", scale_wall_s * 1e6,
         f"{n_inv} invocations x {len(res_scale.raw)} methods in "
         f"{scale_wall_s:.1f}s ({total_req / max(scale_wall_s, 1e-9):,.0f} req/s)")

    # ------------------------------------------------- vectorized-engine scale
    # The azure_scale_xl scenario is the vectorized engine's headline: a
    # ≥10M-invocation two-week fleet through engine='fleet_vec' (bit-identical
    # to the event engine by the differential suite), an order of magnitude
    # past where the Python hot path tops out. Same smoke policy as
    # azure_scale — full invocation count, trimmed method list — and the wall
    # clock is band-checked against the 60s CI budget by check_bench.py.
    t0 = time.perf_counter()
    res_xl = run_file(scenario_path("azure_scale_xl"), smoke=smoke)
    xl_wall_s = time.perf_counter() - t0
    n_inv_xl = max(r.n_invocations for r in res_xl.raw.values())
    assert n_inv_xl >= 10_000_000, \
        f"azure_scale_xl must exercise >= 10M invocations, got {n_inv_xl}"
    cell = scenario_cell(res_xl, "azure_scale_xl")
    total_req_xl = sum(r.n_invocations for r in res_xl.raw.values())
    out["azure_scale_xl"] = {
        "n_invocations": n_inv_xl,
        "n_methods": len(res_xl.raw),
        "wall_clock_s": xl_wall_s,
        "invocations_per_s": total_req_xl / max(xl_wall_s, 1e-9),
        "methods": cell,
    }
    emit("fleet/azure_scale_xl", xl_wall_s * 1e6,
         f"{n_inv_xl} invocations x {len(res_xl.raw)} methods in "
         f"{xl_wall_s:.1f}s ({total_req_xl / max(xl_wall_s, 1e-9):,.0f} req/s)")

    # ------------------------------------------------------ sanitizer overhead
    # repro-san (docs/ANALYSIS.md, "Runtime sanitizer"): the same scenario,
    # plain and under the invariant sanitizer. Results must be bit-identical
    # (the sanitizer is assertions-only) and the wall-clock ratio is recorded
    # into the headline so CI's check_bench.py can hold the 3x budget. Small
    # wall floor damps timer noise at smoke scale.
    from repro.core.scenario import Scenario
    from repro.core.scenario import run as run_scenario

    scn = Scenario.from_file(scenario_path("fleet_base"))
    t0 = time.perf_counter()
    plain = run_scenario(scn, smoke=smoke, sanitize=False)
    plain_wall_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    checked = run_scenario(scn, smoke=smoke, sanitize=True)
    sanitized_wall_s = time.perf_counter() - t0
    assert plain.to_dict() == checked.to_dict(), \
        "sanitized run diverged — the sanitizer must be assertions-only"
    floor_s = 0.05
    ratio = max(sanitized_wall_s, floor_s) / max(plain_wall_s, floor_s)
    out["sanitize_overhead"] = {
        "plain_wall_s": plain_wall_s,
        "sanitized_wall_s": sanitized_wall_s,
        "ratio": ratio,
        "bit_identical": True,
    }
    emit("fleet/sanitize_overhead", sanitized_wall_s * 1e6,
         f"plain={plain_wall_s:.2f}s ratio={ratio:.2f}x (budget 3x)")

    # ------------------------------------------------------ streaming ingestion
    # The azure_csv_stream scenario replays the checked-in Azure-schema gzip
    # fixture through the out-of-core chunked path (core/trace_stream.py):
    # the CSV is validated and spilled into per-window binaries at parse time
    # and the event engine consumes arrival chunks natively — the trace is
    # never materialized. The streaming contract (stream=true is invisible in
    # the results) is asserted end-to-end here: the same spec rerun with
    # stream=false must be sha256-identical per method. Peak resident
    # arrivals are recorded so the nightly ≥10M scale run
    # (tools/ci/stream_scale.py) has a smoke-scale twin in the artifact.
    import hashlib

    import numpy as np

    from repro.core.traces import TRACE_GENERATORS

    def _sha(a) -> str:
        return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()

    scn_stream = Scenario.from_file(scenario_path("azure_csv_stream"))
    t0 = time.perf_counter()
    res_stream = run_scenario(scn_stream, smoke=smoke)
    stream_wall_s = time.perf_counter() - t0
    res_mem = run_scenario(
        scn_stream.with_overrides({"traces.kwargs.stream": False}),
        smoke=smoke)
    for method, rw in res_stream.raw.items():
        validated_samples(rw, f"fleet/stream_ingest/{method}")
        assert _sha(rw.latency_samples_s) == \
            _sha(res_mem.raw[method].latency_samples_s), \
            f"stream_ingest/{method}: streamed and in-memory runs diverged " \
            f"— the streaming bit-identity contract is broken"
    st = TRACE_GENERATORS.build(scn_stream.traces.name,
                                **scn_stream.traces.kwargs)
    for _ in st.chunks():
        pass
    n_inv_stream = max(r.n_invocations for r in res_stream.raw.values())
    out["stream_ingest"] = {
        "n_invocations": n_inv_stream,
        "n_methods": len(res_stream.raw),
        "wall_clock_s": stream_wall_s,
        "n_chunks": st.stats.n_chunks,
        "peak_resident_arrivals": st.stats.peak_resident_arrivals,
        "resident_fraction": (st.stats.peak_resident_arrivals
                              / max(st.stats.n_arrivals, 1)),
        "bit_identical_to_in_memory": True,
    }
    if hasattr(st, "close"):
        st.close()
    emit("fleet/stream_ingest", stream_wall_s * 1e6,
         f"{n_inv_stream} invocations via {out['stream_ingest']['n_chunks']} "
         f"chunks, peak resident "
         f"{out['stream_ingest']['peak_resident_arrivals']} "
         f"({out['stream_ingest']['resident_fraction']:.1%}), sha-equal to "
         f"in-memory")

    # ------------------------------------------------------- placement + pre-warm
    out["placement"] = {}
    for r in sweep_file(scenario_path("placement"),
                        {"placement.name": ["affinity", "least_loaded",
                                            "round_robin"]}, smoke=smoke):
        placement = r.scenario["placement"]["name"]
        out["placement"][placement] = scenario_cell(
            r, f"placement={placement}")
    out["prewarm"] = {}
    for r in sweep_file(scenario_path("prewarm"),
                        {"prewarm.name": ["none", "histogram", "spes"]},
                        smoke=smoke):
        pw = r.scenario["prewarm"]["name"]
        rw = r.raw["warmswap"]
        validated_samples(rw, f"fleet/prewarm={pw}/warmswap")
        out["prewarm"][pw] = {
            "avg_latency_s": rw.avg_latency_s, "cold": rw.n_cold,
            "latency_percentiles_s": rw.latency_percentiles(),
            "prewarm_spawns": rw.prewarm_spawns, "prewarm_hits": rw.prewarm_hits,
            "prewarm_dropped": rw.prewarm_dropped,
            "instance_resident_min": rw.instance_resident_min,
        }
        emit(f"fleet/prewarm={pw}/warmswap", rw.avg_latency_s * 1e6,
             f"cold={rw.n_cold} resident_min={rw.instance_resident_min:.0f} "
             f"dropped={rw.prewarm_dropped}")

    save_json("bench_fleet", out)
    return out


if __name__ == "__main__":
    run()
