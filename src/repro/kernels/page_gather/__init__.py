from repro.kernels.page_gather.ops import page_gather
from repro.kernels.page_gather.ref import page_gather_ref

__all__ = ["page_gather", "page_gather_ref"]
