"""Parallel resumable sweep executor + JSONL results store.

The contracts under test (docs/API.md "Large sweeps"):

  * serial and parallel runs of one grid produce **byte-identical** stores;
  * a killed sweep (torn trailing line included) resumes by skipping every
    completed point and recomputing only what is missing;
  * the store refuses schema mismatches and interior corruption, and only
    tolerates (drops + repairs) a torn *final* line;
  * derived per-point seeds are deterministic and distinct per point.
"""
from __future__ import annotations

import json
import os

import pytest

from repro.core.scenario import Scenario
from repro.experiments import main as cli_main
from repro.experiments.executor import (point_seed, resolve_points, run_sweep,
                                        summarize_store)
from repro.experiments.store import (CorruptStoreError, ResultStore,
                                     StoreError, StoreSchemaError, spec_key)


def _base() -> Scenario:
    # single-engine + tiny horizon: each point runs in milliseconds, and the
    # executor path (resolve -> run -> validate -> store) is fully exercised
    return Scenario(name="exec_base", engine="single",
                    methods=["warmswap", "prebaking"],
                    traces={"name": "azure",
                            "kwargs": {"n_functions": 3, "horizon_min": 300,
                                       "seed": 0}})


AXES = {"traces.kwargs.seed": [0, 1, 2]}


# ---------------------------------------------------------------------------------
# serial == parallel
# ---------------------------------------------------------------------------------

def test_serial_and_parallel_sweeps_bit_identical(tmp_path):
    p_serial = str(tmp_path / "serial.jsonl")
    p_par = str(tmp_path / "parallel.jsonl")
    rs = run_sweep(_base(), AXES, store_path=p_serial)
    rp = run_sweep(_base(), AXES, store_path=p_par, parallel=2)
    assert rs.n_run == rp.n_run == 3
    assert open(p_serial, "rb").read() == open(p_par, "rb").read()
    assert rs.results == rp.results
    # and the stored results round-trip through the store reader
    assert [r["result"] for r in ResultStore(p_serial).records()] == rs.results


def test_results_in_grid_order_and_headline_through_executor(tmp_path):
    report = run_sweep(_base(), AXES, store_path=str(tmp_path / "s.jsonl"))
    names = [p.name for p in report.points]
    assert names == [f"exec_base[traces.kwargs.seed={s}]" for s in (0, 1, 2)]
    for result in report.results:
        # the 88 % headline survives the executor path (degenerate memory
        # model: 1 shared image over 3 fns is not the 10-fn headline, but
        # the summary key must exist and be in (0, 1))
        assert 0.0 < result["summary"]["memory_saving_vs_prebaking"] < 1.0


# ---------------------------------------------------------------------------------
# resume
# ---------------------------------------------------------------------------------

def test_resume_after_kill_skips_completed_points(tmp_path):
    full = str(tmp_path / "full.jsonl")
    run_sweep(_base(), AXES, store_path=full)
    full_bytes = open(full, "rb").read()
    lines = full_bytes.split(b"\n")          # header, 3 records, trailing ""

    # simulate a kill mid-append: header + first record committed, second
    # record torn halfway through its line
    killed = str(tmp_path / "killed.jsonl")
    with open(killed, "wb") as f:
        f.write(lines[0] + b"\n" + lines[1] + b"\n" + lines[2][: len(lines[2]) // 2])

    report = run_sweep(_base(), AXES, store_path=killed, resume=True)
    assert report.n_skipped == 1                 # the committed point
    assert report.n_run == 2                     # torn + missing recomputed
    # the repaired store holds exactly the full run's records (the torn line
    # was truncated away, not duplicated)
    assert ResultStore(killed).records() == ResultStore(full).records()
    # resuming a complete store runs nothing
    again = run_sweep(_base(), AXES, store_path=killed, resume=True)
    assert again.n_run == 0 and again.n_skipped == 3
    assert again.results == report.results


def test_existing_store_without_resume_is_refused(tmp_path):
    path = str(tmp_path / "s.jsonl")
    run_sweep(_base(), AXES, store_path=path)
    with pytest.raises(StoreError, match="resume"):
        run_sweep(_base(), AXES, store_path=path)


# ---------------------------------------------------------------------------------
# store integrity
# ---------------------------------------------------------------------------------

def test_store_rejects_store_schema_mismatch(tmp_path):
    path = str(tmp_path / "s.jsonl")
    with open(path, "w") as f:
        f.write('{"store_schema_version": 99, "result_schema_version": 1}\n')
    with pytest.raises(StoreSchemaError, match="store_schema_version"):
        ResultStore(path).records()


def test_store_rejects_future_result_schema(tmp_path):
    path = str(tmp_path / "s.jsonl")
    with open(path, "w") as f:
        f.write('{"store_schema_version": 1, "result_schema_version": 999}\n')
    with pytest.raises(StoreSchemaError, match="result_schema_version"):
        ResultStore(path).records()
    # and the executor surfaces it rather than appending blind
    with pytest.raises(StoreSchemaError):
        run_sweep(_base(), AXES, store_path=path, resume=True)


def test_store_rejects_non_header_file(tmp_path):
    path = str(tmp_path / "s.jsonl")
    with open(path, "w") as f:
        f.write('{"not": "a store"}\n')
    with pytest.raises(StoreSchemaError, match="header"):
        ResultStore(path).records()


def test_store_rejects_corrupt_interior_line(tmp_path):
    path = str(tmp_path / "s.jsonl")
    run_sweep(_base(), AXES, store_path=path)
    lines = open(path, "rb").read().split(b"\n")
    lines[2] = lines[2][: len(lines[2]) // 2]    # damage a MIDDLE record
    with open(path, "wb") as f:
        f.write(b"\n".join(lines))
    with pytest.raises(CorruptStoreError, match="corrupt line"):
        ResultStore(path).records()


def test_torn_trailing_line_dropped_then_repaired_by_append(tmp_path):
    path = str(tmp_path / "s.jsonl")
    report = run_sweep(_base(), AXES, store_path=path)
    with open(path, "ab") as f:
        f.write(b'{"key": "half-written')          # no newline: torn
    store = ResultStore(path)
    assert [r["key"] for r in store.records()] == \
        [p.key for p in report.points]
    assert store.torn_tail
    # the next append truncates the torn tail before writing
    store.append("extra", report.results[0], name="extra")
    records = ResultStore(path).records()
    assert [r["key"] for r in records] == [p.key for p in report.points] + \
        ["extra"]
    raw = open(path, "rb").read()
    assert b"half-written" not in raw and raw.endswith(b"\n")


# ---------------------------------------------------------------------------------
# keys and seeds
# ---------------------------------------------------------------------------------

def test_spec_key_is_content_hash_of_resolved_spec():
    points = resolve_points(_base(), AXES)
    assert len({p.key for p in points}) == 3     # distinct specs, distinct keys
    assert all(p.key == spec_key(p.spec) for p in points)
    # resolution is deterministic: same base + axes -> same keys
    assert [p.key for p in resolve_points(_base(), AXES)] == \
        [p.key for p in points]


def test_smoke_resolution_changes_the_key():
    base = _base()
    base.smoke_overrides = {"traces.kwargs.horizon_min": 100}
    full = resolve_points(base, {})
    smoke = resolve_points(base, {}, smoke=True)
    assert full[0].key != smoke[0].key
    assert smoke[0].spec["traces"]["kwargs"]["horizon_min"] == 100


def test_derived_seeds_deterministic_and_distinct():
    axes = {"keep_alive_min": [5.0, 10.0, 20.0]}
    pts = resolve_points(_base(), axes, derive_seeds=True)
    seeds = [p.spec["traces"]["kwargs"]["seed"] for p in pts]
    assert len(set(seeds)) == 3                  # independent per point
    assert seeds == [p.spec["traces"]["kwargs"]["seed"]
                     for p in resolve_points(_base(), axes, derive_seeds=True)]
    # the derived seed is a function of the spec WITHOUT its previous seed
    spec = pts[0].spec
    reseeded = json.loads(json.dumps(spec))
    reseeded["traces"]["kwargs"]["seed"] = 12345
    assert point_seed(spec) == point_seed(reseeded)


# ---------------------------------------------------------------------------------
# CLI + report
# ---------------------------------------------------------------------------------

def test_cli_sweep_store_resume_and_report(tmp_path, capsys):
    spec_path = str(tmp_path / "base.json")
    with open(spec_path, "w") as f:
        f.write(_base().to_json())
    store_path = str(tmp_path / "cli.jsonl")
    assert cli_main(["sweep", spec_path, "--axis", "traces.kwargs.seed=0,1",
                     "--parallel", "2", "--store", store_path]) == 0
    assert cli_main(["sweep", spec_path, "--axis", "traces.kwargs.seed=0,1",
                     "--store", store_path, "--resume"]) == 0
    out = capsys.readouterr().out
    assert "memory_saving_vs_prebaking" in out
    report_out = str(tmp_path / "report.json")
    assert cli_main(["report", store_path, "--out", report_out]) == 0
    summary = json.load(open(report_out))
    assert summary["n_points"] == 2
    assert len(summary["results"]) == 2

    summary2 = summarize_store(store_path)
    assert [r["key"] for r in summary2["points"]] == \
        [r["key"] for r in summary["points"]]


def test_resume_requires_store(tmp_path):
    # programmatic and CLI callers both hit the run_sweep guard
    with pytest.raises(StoreError, match="resume"):
        run_sweep(_base(), AXES, resume=True)
    spec_path = str(tmp_path / "base.json")
    with open(spec_path, "w") as f:
        f.write(_base().to_json())
    with pytest.raises(ValueError, match="--resume needs --store"):
        cli_main(["sweep", spec_path, "--axis", "n_workers=1", "--resume"])
