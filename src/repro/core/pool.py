"""The provider-side Dependency Manager: a refcounted pool of live images.

Paper Fig. 4a: the Dependency Manager is the central hub on the worker node. It
  * builds and owns live dependency images (RAM tier),
  * serves migration requests (metadata + page server),
  * dumps cold images to a **disk tier** and revives them without re-running
    initialization (§3.2 "checkpoint images on disk"),
  * enforces a pool capacity with LRU eviction (the provider's cache constraint the
    paper's abstract highlights),
  * accounts memory: pool cost is O(#images), not O(#functions) — the measurable
    claim behind the 88 % saving vs Prebaking (Fig. 7).

:class:`CapacityLedger` is the admission/eviction decision logic factored out
of the manager; :class:`ClusterImageCache` lifts it to the cluster: one ledger
of *distinct* images resident anywhere plus per-image holder sets, giving the
fleet simulator the shared tier where an image is fetched from source once
and then served worker-to-worker (local hit / remote hit / miss — priced by
``core/costmodel.py``, contract in docs/SIMULATION.md).

Elasticity hook: ``reshard_image`` rebuilds an image's pages under a new mesh/layout
without touching the checkpoint store — a failed/resized serving replica re-warms from
the pool rather than from cold storage.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.core.image import LiveDependencyImage, build_image
from repro.core.migration import LinkModel, MigrationClient, RestoredImage, RestorePolicy
from repro.core.pages import DEFAULT_PAGE_SIZE


@dataclass
class PoolStats:
    builds: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    revivals: int = 0
    build_s: float = 0.0
    revive_s: float = 0.0


@dataclass
class LedgerEntry:
    nbytes: int
    last_used: float = 0.0
    refcount: int = 0
    pinned: bool = False


class CapacityLedger:
    """Pure capacity + LRU accounting over named residents.

    This is the pool's admission/eviction *decision logic* factored out of
    :class:`DependencyManager` so the fleet simulator (``core/fleet.py``) can
    model one per-worker pool with exactly the same semantics the real manager
    applies to live images: admit up to ``capacity_bytes``, evicting the
    least-recently-used unpinned entry with no in-flight references first.
    """

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity_bytes = capacity_bytes
        self.entries: Dict[str, LedgerEntry] = {}
        self.evictions = 0
        # incremental byte total, updated at every admit/evict/resize: the
        # eviction loop reads it per iteration, and the sanitizer's
        # books-balance check recomputes the sum to audit it
        self._used_bytes = 0

    def holds(self, key: str) -> bool:
        """True if ``key`` is resident."""
        return key in self.entries

    def used_bytes(self) -> int:
        """Total bytes of resident entries."""
        return self._used_bytes

    def touch(self, key: str, now: float) -> None:
        """Refresh ``key``'s LRU timestamp (``now``: any monotone clock —
        the fleet simulator passes minutes, the live manager passes
        ``time.monotonic()`` seconds; only the ordering matters)."""
        if key in self.entries:
            self.entries[key].last_used = now

    def acquire(self, key: str) -> None:
        """Take an in-flight reference on ``key``; referenced entries are
        never chosen as eviction victims."""
        if key in self.entries:
            self.entries[key].refcount += 1

    def release(self, key: str) -> None:
        """Drop one in-flight reference on ``key`` (floors at zero)."""
        if key in self.entries:
            self.entries[key].refcount = max(0, self.entries[key].refcount - 1)

    def _pick_victim(self, exclude: Optional[str] = None) -> Optional[str]:
        candidates = [(e.last_used, k) for k, e in self.entries.items()
                      if not e.pinned and e.refcount == 0 and k != exclude]
        return min(candidates)[1] if candidates else None

    def _reclaim(self, headroom: int, exclude: Optional[str] = None) -> list:
        """Evict LRU entries until ``headroom`` more bytes fit; returns the
        evicted keys. ``exclude`` protects the entry being (re-)admitted."""
        evicted = []
        if self.capacity_bytes is None:
            return evicted
        while self._used_bytes + headroom > self.capacity_bytes:
            victim = self._pick_victim(exclude)
            if victim is None:
                break
            self._used_bytes -= self.entries[victim].nbytes
            del self.entries[victim]
            self.evictions += 1
            evicted.append(victim)
        return evicted

    def admit(self, key: str, nbytes: int, now: float,
              pinned: bool = False) -> list:
        """Admit ``key``; returns the keys evicted to make room. The entry is
        admitted even if eviction cannot free enough space (the pool never
        refuses the image it was asked for — same as the manager).

        Re-admitting a resident key refreshes its size (a resized/reshared
        image must not keep its stale ``nbytes``) and re-runs eviction if it
        grew — the entry itself is never its own victim."""
        if key in self.entries:
            entry = self.entries[key]
            grew = nbytes > entry.nbytes
            self._used_bytes += nbytes - entry.nbytes
            entry.nbytes = nbytes
            entry.pinned = pinned          # refresh pin state, not just size
            self.touch(key, now)
            return self._reclaim(0, exclude=key) if grew else []
        evicted = self._reclaim(nbytes)
        self.entries[key] = LedgerEntry(nbytes=nbytes, last_used=now,
                                        pinned=pinned)
        self._used_bytes += nbytes
        return evicted

    def evict(self, key: str) -> None:
        entry = self.entries.pop(key, None)
        if entry is not None:
            self._used_bytes -= entry.nbytes

    def resize(self, key: str, nbytes: int) -> None:
        if key in self.entries:
            self._used_bytes += nbytes - self.entries[key].nbytes
            self.entries[key].nbytes = nbytes


class ClusterImageCache:
    """Cluster-wide shared image tier over :class:`CapacityLedger`.

    The fleet's workers each run a private pool, but the *cluster* holds each
    distinct pre-warmed image at most once per fetch from the source store:
    the first worker to need an image pays the source fetch, every later
    worker pulls the pages from a peer over the network (remote hit), and a
    worker whose own pool already holds it pays host-memcpy only (local hit).
    This class is the index that makes that sharing decidable: one
    capacity-bounded ledger of *distinct* images plus, per image, the set of
    workers currently holding it.

    Units: ``nbytes`` in bytes, ``now`` in simulation minutes (any monotone
    clock works — it only orders LRU decisions).

    Args:
        capacity_bytes: total bytes of distinct images the shared tier may
            hold cluster-wide; ``None`` = unbounded. Exceeding it evicts the
            least-recently-used image *everywhere* (``on_evict`` is called so
            the owner can drop per-worker residents too). An image larger
            than the whole capacity is **rejected** — it can never fit the
            shared tier, so every non-local access to it is a source miss.
        on_evict: callback ``(key) -> None`` fired for each cluster-wide
            eviction, before the holder set is cleared.
    """

    def __init__(self, capacity_bytes: Optional[int] = None,
                 on_evict: Optional[Callable[[str], None]] = None):
        self.ledger = CapacityLedger(capacity_bytes)
        self.holders: Dict[str, set] = {}
        self.on_evict = on_evict
        self.local_hits = 0
        self.remote_hits = 0
        self.misses = 0
        self.rejected = 0           # admits refused because nbytes > capacity
        self.peak_bytes = 0         # high-water mark of distinct-image bytes

    def classify(self, key: str, worker) -> str:
        """Pure read: ``'local'`` (``worker`` holds ``key``), ``'remote'``
        (some other worker does), or ``'miss'`` (nobody — the pages must
        come from the source store). No counters move."""
        held_by = self.holders.get(key)
        if held_by and worker in held_by:
            return "local"
        return "remote" if held_by else "miss"

    def count(self, tier: str) -> None:
        """Record one access at ``tier`` in the hit/miss counters. Split
        from :meth:`classify` so a caller that refines the classification
        (the fleet engine treats worker-pool residency as 'local' even when
        the bounded tier rejected the image) can still keep these counters
        truthful."""
        if tier == "local":
            self.local_hits += 1
        elif tier == "remote":
            self.remote_hits += 1
        else:
            self.misses += 1

    def lookup(self, key: str, worker) -> str:
        """:meth:`classify` + :meth:`count` in one step."""
        tier = self.classify(key, worker)
        self.count(tier)
        return tier

    def holds(self, key: str) -> bool:
        """True if any worker in the cluster holds ``key``."""
        return bool(self.holders.get(key))

    def used_bytes(self) -> int:
        """Bytes of *distinct* images resident anywhere (each counted once)."""
        return self.ledger.used_bytes()

    @property
    def evictions(self) -> int:
        """Cluster-wide evictions forced by ``capacity_bytes``."""
        return self.ledger.evictions

    def admit(self, key: str, nbytes: int, worker, now: float) -> list:
        """Record that ``worker`` now holds ``key`` (``nbytes`` bytes).

        Returns the keys evicted cluster-wide to make room (``on_evict`` has
        already run for each). An image larger than ``capacity_bytes`` is
        rejected (counted in ``rejected``) and nothing changes."""
        cap = self.ledger.capacity_bytes
        if cap is not None and nbytes > cap:
            self.rejected += 1
            return []
        evicted = self.ledger.admit(key, nbytes, now=now)
        for victim in evicted:
            if self.on_evict is not None:
                self.on_evict(victim)
            self.holders.pop(victim, None)
        self.holders.setdefault(key, set()).add(worker)
        self.peak_bytes = max(self.peak_bytes, self.used_bytes())
        return evicted

    def touch(self, key: str, now: float) -> None:
        """Refresh ``key``'s LRU timestamp (any-tier hit keeps it alive)."""
        self.ledger.touch(key, now)

    def worker_evicted(self, worker, key: str) -> None:
        """A worker's private pool dropped ``key``. When the last holder goes,
        the image leaves the shared tier too (the tier is the union of worker
        pools, not separate storage), without counting a capacity eviction."""
        held_by = self.holders.get(key)
        if held_by is None:
            return
        held_by.discard(worker)
        if not held_by:
            del self.holders[key]
            self.ledger.evict(key)

    def summary(self) -> Dict[str, Any]:
        return {
            "images": sorted(self.holders),
            "used_bytes": self.used_bytes(),
            "peak_bytes": self.peak_bytes,
            "local_hits": self.local_hits,
            "remote_hits": self.remote_hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "rejected": self.rejected,
        }


class DependencyManager:
    def __init__(
        self,
        capacity_bytes: Optional[int] = None,
        disk_dir: Optional[str] = None,
        link: Optional[LinkModel] = None,
        page_size: int = DEFAULT_PAGE_SIZE,
    ):
        self.capacity_bytes = capacity_bytes
        self.disk_dir = disk_dir
        # per-manager default link: a shared class-level instance would leak
        # latency/bandwidth mutations across managers
        self.link = link if link is not None else LinkModel()
        self.page_size = page_size
        # Shared manager state below is annotated for repro-lint's
        # lock-discipline checker (docs/ANALYSIS.md): every access outside
        # __init__ must sit inside `with self._lock` (or a method declared
        # `# requires-lock: _lock`), which CI verifies statically.
        self._images: Dict[str, LiveDependencyImage] = {}   # guarded-by: _lock
        self._ledger = CapacityLedger(capacity_bytes)       # guarded-by: _lock
        self._on_disk: Dict[str, bool] = {}                 # guarded-by: _lock
        self._builders: Dict[str, Callable[[], Any]] = {}   # guarded-by: _lock
        self._arch_names: Dict[str, str] = {}               # guarded-by: _lock
        self._executables: Dict[str, Dict[str, Any]] = {}   # guarded-by: _lock
        self._treedefs: Dict[str, Any] = {}                 # guarded-by: _lock
        self._pinned: set = set()                           # guarded-by: _lock
        self._lock = threading.RLock()
        self.stats = PoolStats()                            # guarded-by: _lock

    # ------------------------------------------------------------------ registry
    def register_image(
        self,
        image_id: str,
        arch_name: str,
        params_builder: Callable[[], Any],
        *,
        executables: Optional[Dict[str, Any]] = None,
        pin: bool = False,
        build_now: bool = True,
    ) -> None:
        with self._lock:
            self._builders[image_id] = params_builder
            self._arch_names[image_id] = arch_name
            self._executables[image_id] = executables or {}
            if pin:
                self._pinned.add(image_id)
        if build_now:
            self._ensure_live(image_id)

    def has_live(self, image_id: str) -> bool:
        """True if ``image_id`` is currently resident in the RAM tier."""
        with self._lock:
            return image_id in self._images

    def live_image_bytes(self, image_id: str) -> Optional[int]:
        """Page-store size (bytes) of a LIVE image, or ``None`` when the
        image is not resident — a pure read that never builds or revives
        (unlike ``_ensure_live``)."""
        with self._lock:
            img = self._images.get(image_id)
            return None if img is None else img.image_bytes

    def known(self, image_id: str) -> bool:
        """True if a builder for ``image_id`` has been registered."""
        with self._lock:
            return image_id in self._builders

    # ------------------------------------------------------------------ build/evict
    def _ensure_live(self, image_id: str) -> LiveDependencyImage:
        with self._lock:
            if image_id in self._images:
                self.stats.hits += 1
                img = self._images[image_id]
                # LRU recency clock for the live manager tier — not part of
                # any simulated result.  # repro-lint: allow[wall-clock]
                img.last_used = time.monotonic()
                self._ledger.touch(image_id, img.last_used)
                return img
            self.stats.misses += 1
            t0 = time.perf_counter()
            if self._on_disk.get(image_id) and self.disk_dir:
                img = LiveDependencyImage.from_disk(
                    self.disk_dir, image_id, self._treedefs[image_id])
                img.executables = self._executables.get(image_id, {})
                self.stats.revivals += 1
                self.stats.revive_s += time.perf_counter() - t0
            else:
                img = build_image(
                    image_id, self._arch_names[image_id], self._builders[image_id],
                    page_size=self.page_size,
                    executables=self._executables.get(image_id))
                self._treedefs[image_id] = img.treedef
                self.stats.builds += 1
                self.stats.build_s += time.perf_counter() - t0
            self._admit(img)
            return img

    def _admit(self, img: LiveDependencyImage) -> None:  # requires-lock: _lock
        image_id = img.metadata.image_id
        evicted = self._ledger.admit(image_id, img.image_bytes, img.last_used,
                                     pinned=image_id in self._pinned)
        for victim in evicted:
            self._spill(victim)
        self._images[image_id] = img

    def evict(self, image_id: str) -> None:
        """RAM -> disk tier (or drop, if no disk dir; rebuildable via builder)."""
        with self._lock:
            self._ledger.evict(image_id)
            self._spill(image_id)

    def _spill(self, image_id: str) -> None:  # requires-lock: _lock
        img = self._images.pop(image_id, None)
        if img is None:
            return
        if self.disk_dir:
            img.dump_to_disk(self.disk_dir)
            self._on_disk[image_id] = True
        self.stats.evictions += 1

    # ------------------------------------------------------------------ migration
    def request_migration(
        self,
        image_id: str,
        policy: RestorePolicy = RestorePolicy.BULK,
        link: Optional[LinkModel] = None,
    ) -> RestoredImage:
        """Paper Fig. 4c: look up the image, hand metadata + a page server to the
        container's migration client."""
        img = self._ensure_live(image_id)
        with self._lock:
            img.refcount += 1
            # Live-manager LRU clock.  # repro-lint: allow[wall-clock]
            img.last_used = time.monotonic()
            self._ledger.acquire(image_id)
            self._ledger.touch(image_id, img.last_used)
        client = MigrationClient(link or self.link)
        return client.migrate(img, policy)

    def release(self, image_id: str) -> None:
        with self._lock:
            if image_id in self._images:
                self._images[image_id].refcount = max(
                    0, self._images[image_id].refcount - 1)
                self._ledger.release(image_id)

    def executables_for(self, image_id: str) -> Dict[str, Any]:
        return self._ensure_live(image_id).executables

    # ------------------------------------------------------------------ elasticity
    def reshard_image(self, image_id: str,
                      transform: Callable[[Any], Any]) -> None:
        """Rebuild an image's pages under a new layout (elastic mesh change) without
        re-running the original initialization."""
        img = self._ensure_live(image_id)
        params = transform(img.params())
        def builder():
            return params
        new_img = build_image(image_id, img.metadata.arch_name, builder,
                              page_size=self.page_size, executables=img.executables)
        with self._lock:
            self._treedefs[image_id] = new_img.treedef
            self._images[image_id] = new_img
            self._ledger.resize(image_id, new_img.image_bytes)

    # ------------------------------------------------------------------ accounting
    def pool_bytes(self) -> int:
        with self._lock:
            return sum(im.image_bytes for im in self._images.values())

    def metadata_bytes(self) -> int:
        with self._lock:
            return sum(im.metadata_bytes for im in self._images.values())

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "live_images": sorted(self._images.keys()),
                "pool_bytes": self.pool_bytes(),
                "metadata_bytes": self.metadata_bytes(),
                "stats": self.stats.__dict__,
            }
