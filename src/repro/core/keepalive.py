"""Keep-alive / cold-start arrival math (paper §2.2, Fig. 1).

With Poisson invocations at rate λ (per minute) and keep-alive T minutes:

    P(no invocation within T)  =  e^(−λT)                       (paper Eq. 1)
    E[cold starts in D min]    =  D · λ · e^(−λT)                (paper Eq. 2)

maximized at λ* = 1/T. Function-specific tuning pays off only when
w·E_cs(λ) > c (Eq. 3) — the long tail fails this test, which is WarmSwap's
raison d'être.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.registry import Registry


def p_no_invocation(lam: float, keep_alive_min: float) -> float:
    return math.exp(-lam * keep_alive_min)


def expected_cold_starts(lam, keep_alive_min: float, horizon_min: float):
    """Vectorized Eq. 2."""
    lam = np.asarray(lam, dtype=np.float64)
    return horizon_min * lam * np.exp(-lam * keep_alive_min)


def argmax_rate(keep_alive_min: float) -> float:
    """The invocation rate with the most expected cold starts: λ* = 1/T."""
    return 1.0 / keep_alive_min


def worth_function_specific_tuning(lam: float, keep_alive_min: float,
                                   horizon_min: float, benefit_per_cs: float,
                                   cost: float) -> bool:
    """Paper Eq. 3: w·E_cs(λ) > c."""
    return benefit_per_cs * float(expected_cold_starts(lam, keep_alive_min,
                                                       horizon_min)) > cost


@dataclass(frozen=True)
class KeepAlivePolicy:
    keep_alive_min: float = 15.0     # paper's default (§4.5); AWS/Azure use 5–30

    def expires_at(self, last_use_min: float) -> float:
        return last_use_min + self.keep_alive_min


# ---------------------------------------------------------------------------------
# Pluggable pre-warm policies for the fleet simulator (core/fleet.py).
#
# A policy answers two questions per function, from its observed arrival history:
#   * keep_alive_min(fn, image_bytes=...) — how long an idle instance stays warm
#     after completion. The engine passes the BYTES the idle instance pins
#     (warmswap: per-fn metadata; prebaking: its private snapshot; baseline: its
#     privately initialized dependencies), so policies can reason about memory
#     cost, not just time — see BytesAwareKeepAlive;
#   * prewarm_after(fn,t) — optionally, a (spawn_at, expire_at) window in which a
#     predictively pre-warmed instance should be standing by for the next arrival.
# The fleet engine also feeds completion events (on_completion) so policies can
# anchor decisions to when an instance actually went idle, not just when the
# request arrived (under queueing the two diverge).
#
# Policies are registry-pluggable: ``@PREWARM_POLICIES.register("name")`` makes
# a policy addressable by string key from FleetConfig.prewarm, scenario specs,
# and the experiments CLI without touching the engine.
# ---------------------------------------------------------------------------------

#: Name -> policy class. New policies self-register with
#: ``@PREWARM_POLICIES.register("name")``; the fleet engine and scenario specs
#: look them up by key (per-component kwargs go to the constructor).
PREWARM_POLICIES = Registry("prewarm policy")


@PREWARM_POLICIES.register("none")
class PrewarmPolicy:
    """Base: fixed keep-alive (the paper's §4.5 setting), no prediction."""

    name = "none"

    def __init__(self, keep_alive_min: float = 15.0):
        self._keep_alive_min = keep_alive_min
        self._last_arrival: dict = {}
        self._last_completion: dict = {}  # fn -> last instance-free time (min)
        self._iats: dict = {}        # fn -> list of recent inter-arrival times (min)
        self.max_history = 64

    def on_arrival(self, fn: int, t_min: float) -> None:
        last = self._last_arrival.get(fn)
        if last is not None and t_min > last:
            hist = self._iats.setdefault(fn, [])
            hist.append(t_min - last)
            if len(hist) > self.max_history:
                del hist[0]
        self._last_arrival[fn] = t_min

    def on_completion(self, fn: int, t_min: float) -> None:
        """The fleet engine's instance-free event: a request of ``fn`` finished
        at ``t_min``. The keep-alive window runs from here — under queueing the
        completion diverges from the arrival — so this is the anchor for
        idle-time reasoning. The base class records it for subclasses; the
        built-in policies are arrival-driven and don't consult it."""
        self._last_completion[fn] = t_min

    def keep_alive_min(self, fn: int,
                       image_bytes: Optional[int] = None) -> float:
        """Keep-alive window (minutes) for an idle instance of ``fn``.

        Args:
            fn: function index.
            image_bytes: bytes the idle instance pins in memory (``None``
                when the caller has no size information). The base policy and
                the time-only subclasses ignore it; byte-aware policies scale
                the window by it.
        """
        return self._keep_alive_min

    def prewarm_after(self, fn: int, t_min: float):
        """Return (spawn_at_min, expire_at_min) for a predictive pre-warm, or
        None. Called after each arrival has been served."""
        return None


@PREWARM_POLICIES.register("histogram")
class HistogramKeepAlive(PrewarmPolicy):
    """Serverless-in-the-wild-style adaptive keep-alive: per function, keep the
    instance warm for a high percentile of the observed inter-arrival times,
    clamped to [lo, hi]. Rarely-invoked functions stop wasting memory on a
    window they never hit; chatty functions get a window that covers them."""

    name = "histogram"

    def __init__(self, percentile: float = 99.0, lo_min: float = 1.0,
                 hi_min: float = 60.0, min_samples: int = 4,
                 default_min: float = 15.0):
        super().__init__(keep_alive_min=default_min)
        self.percentile = percentile
        self.lo_min = lo_min
        self.hi_min = hi_min
        self.min_samples = min_samples

    def keep_alive_min(self, fn: int,
                       image_bytes: Optional[int] = None) -> float:
        hist = self._iats.get(fn, ())
        if len(hist) < self.min_samples:
            return self._keep_alive_min
        ka = float(np.percentile(np.asarray(hist), self.percentile))
        return min(max(ka, self.lo_min), self.hi_min)


@PREWARM_POLICIES.register("spes")
class SpesPrewarm(PrewarmPolicy):
    """SPES-style (arXiv 2403.17574) predictive pre-warming: keep-alive is cut
    short (cheap), and instead the next arrival is predicted from the median
    inter-arrival time; an instance is pre-warmed shortly before the predicted
    time and kept only for a margin around it. Trades a little spawn work for
    much less idle residency on predictable functions."""

    name = "spes"

    def __init__(self, keep_alive_min: float = 2.0, margin_frac: float = 0.25,
                 min_samples: int = 4, max_window_min: float = 120.0):
        super().__init__(keep_alive_min=keep_alive_min)
        self.margin_frac = margin_frac
        self.min_samples = min_samples
        self.max_window_min = max_window_min

    def prewarm_after(self, fn: int, t_min: float):
        hist = self._iats.get(fn, ())
        if len(hist) < self.min_samples:
            return None
        med = float(np.median(np.asarray(hist)))
        if med <= 0 or med > self.max_window_min:
            return None                      # too unpredictable / too rare
        margin = max(self.margin_frac * med, 1e-3)
        return (t_min + med - margin, t_min + med + margin)


@PREWARM_POLICIES.register("bytes")
class BytesAwareKeepAlive(PrewarmPolicy):
    """Keep-alive priced in byte-minutes, not minutes.

    A fixed time window treats a 3 MB idle handler and a 2.3 GB idle snapshot
    as equally cheap; a provider's cache does not. This policy grants every
    idle instance the same *byte-minute* budget, so the window scales
    inversely with the bytes the instance pins: tiny WarmSwap metadata idles
    for a long time (the shared image is already paid for), a private
    Prebaking snapshot gets a short leash. With the default budget a 230 MB
    resident gets exactly the paper's 15-minute window.

    Args:
        budget_byte_min: byte-minutes one idle instance may consume
            (default: 230 MiB x 15 min).
        lo_min / hi_min: clamp on the resulting window (minutes).
        default_min: window when the caller passes no size (minutes).
    """

    name = "bytes"

    def __init__(self, budget_byte_min: float = float(230 << 20) * 15.0,
                 lo_min: float = 1.0, hi_min: float = 240.0,
                 default_min: float = 15.0):
        super().__init__(keep_alive_min=default_min)
        self.budget_byte_min = budget_byte_min
        self.lo_min = lo_min
        self.hi_min = hi_min

    def keep_alive_min(self, fn: int,
                       image_bytes: Optional[int] = None) -> float:
        if not image_bytes or image_bytes <= 0:
            return self._keep_alive_min
        return min(max(self.budget_byte_min / image_bytes, self.lo_min),
                   self.hi_min)


