"""Cold-start orchestration with per-phase timers (paper Figs. 2, 3, 6).

Three start paths, matching the paper's evaluation:

  * ``baseline``  — traditional cold start: boot the runtime, then *dependency
    initialization from scratch*: read the per-function checkpoint from the container
    store (disk), rebuild the parameter pytree, and XLA-compile the step functions.
  * ``warmswap``  — metadata transfer from the Dependency Manager (*communication*),
    live-migrate the shared pre-initialized image (*migration*: page faults / bulk
    stream), attach the image's pre-built executables (compile-cache hit).
  * ``prebaking`` — the function-specific comparison [23]: restore the function's own
    full snapshot (base + handler, one per function) from RAM; no sharing.

Every phase is wall-clock measured around real work (disk IO, memcpy, XLA compiles,
handler execution). ``network_s`` / ``container_s`` are the only modelled constants
(the paper measures them on AWS infrastructure we don't have; both are flat across
functions there — ~0.1 s network, ~0.5 s container — and configurable here, default 0
so micro-benchmarks report pure dependency-path time).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.core.migration import LinkModel, RestorePolicy
from repro.core.pool import DependencyManager
from repro.core.registry import FunctionRegistry, FunctionSpec
from repro.core import workloads as wl


@dataclass
class PhaseTimes:
    network: float = 0.0
    container: float = 0.0
    boot: float = 0.0
    communication: float = 0.0      # warmswap: metadata transfer
    migration: float = 0.0          # warmswap: page restore until params usable
    dependency_init: float = 0.0    # baseline: disk load + pytree rebuild + compile
    dependency_load: float = 0.0    #   ... of which: load + deserialize (paper's phase)
    dependency_compile: float = 0.0 #   ... of which: XLA compile
    handler_import: float = 0.0     # per-function head weights + handler setup
    execution: float = 0.0          # first request

    @property
    def total(self) -> float:
        return (self.network + self.container + self.boot + self.communication +
                self.migration + self.dependency_init + self.handler_import +
                self.execution)

    def as_dict(self) -> Dict[str, float]:
        d = {k: getattr(self, k) for k in (
            "network", "container", "boot", "communication", "migration",
            "dependency_init", "dependency_load", "dependency_compile",
            "handler_import", "execution")}
        d["total"] = self.total
        return d


@dataclass
class ColdStartConfig:
    policy: RestorePolicy = RestorePolicy.BULK
    link: LinkModel = field(default_factory=LinkModel)
    network_s: float = 0.0
    container_s: float = 0.0


class FunctionInstance:
    """A live 'container': params + handler + executables, kept warm until evicted."""

    def __init__(self, spec: FunctionSpec, params: Any, handler_weights: Dict,
                 execs: Dict[str, Any]):
        self.spec = spec
        self.params = params
        self.handler_weights = handler_weights
        self.execs = execs
        # Live-side instance age for keep-alive; never enters simulated
        # results.  # repro-lint: allow[wall-clock]
        self.started_at = time.monotonic()

    def invoke(self, request: Any):
        t0 = time.perf_counter()
        result = self.spec.handler_fn(self.params, self.handler_weights, request,
                                      self.execs)
        if hasattr(result, "block_until_ready"):
            result.block_until_ready()
        return result, time.perf_counter() - t0


class ColdStartOrchestrator:
    def __init__(self, manager: DependencyManager, registry: FunctionRegistry,
                 cfg: Optional[ColdStartConfig] = None):
        self.manager = manager
        self.registry = registry
        # a fresh config per orchestrator: a shared default instance would leak
        # policy/link mutations across orchestrators
        self.cfg = cfg if cfg is not None else ColdStartConfig()
        # Prebaking store: per-function full snapshots in RAM (paper stores them in
        # memory "to enhance fairness", §4.5)
        self._prebaked: Dict[str, Dict[str, Any]] = {}

    # ------------------------------------------------------------------ helpers
    def predicted_cold_latency_s(self, fn_id: str, model,
                                 method: str = "warmswap",
                                 tier: str = "local",
                                 resident_pages: int = 0) -> float:
        """Price a cold start of ``fn_id`` with the page-granular model
        (``core/costmodel.PageCostModel``) using the *real* registered
        image's size, so simulated-vs-measured comparisons share one payload.

        Args:
            fn_id: registered function id.
            model: a :class:`~repro.core.costmodel.PageCostModel`.
            method: ``'warmswap' | 'prebaking' | 'baseline'``.
            tier: where the pages would come from (``'local' | 'remote' |
                'miss'`` — see the cost-model docstring).
            resident_pages: pages already present container-side.

        Returns:
            Predicted cold-start latency in seconds. Compare against the
            measured ``PhaseTimes.total`` of the same start path to judge the
            model's calibration on this machine.

        A prediction never materializes state: the real image size is used
        when the image is already live in the pool, otherwise the model's
        configured default — building or reviving the image here would pay
        (and pool-admit) the very cost being estimated.
        """
        spec = self.registry.get(fn_id)
        # None -> the model's configured default (cost.image_bytes)
        image_bytes = self.manager.live_image_bytes(spec.image_id)
        return model.cold_latency_s(method, tier=tier,
                                    resident_pages=resident_pages,
                                    image_bytes=image_bytes)

    def _boot(self) -> float:
        """Runtime boot: backend ready + dispatch path warm (Python+RIC analogue)."""
        t0 = time.perf_counter()
        jax.block_until_ready(jax.numpy.zeros((8,)) + 1)
        return time.perf_counter() - t0

    def _first_request(self, spec: FunctionSpec):
        req_builder = wl.WORKLOADS.get(spec.fn_id)
        if req_builder is not None:
            return req_builder.request_builder()
        if spec.image_id in wl.IMAGE_CONFIGS:   # custom tenant on a model image
            return wl.default_request()
        return {}

    # ------------------------------------------------------------------ baseline
    def cold_start_baseline(self, fn_id: str):
        spec = self.registry.get(fn_id)
        t = PhaseTimes(network=self.cfg.network_s, container=self.cfg.container_s)
        t.boot = self._boot()

        t0 = time.perf_counter()
        params = None
        if spec.checkpoint_path:
            data = np.load(spec.checkpoint_path)              # real disk IO
            img = self.manager._ensure_live(spec.image_id)    # structure reference
            import ml_dtypes
            leaves = []
            for i in range(len(img.metadata.page_table.tree_order)):
                if f"p{i}:bf16" in data:
                    leaves.append(data[f"p{i}:bf16"].view(ml_dtypes.bfloat16))
                else:
                    leaves.append(data[f"p{i}"])
            params = jax.tree_util.tree_unflatten(img.treedef, leaves)
        elif spec.image_id in wl.IMAGE_CONFIGS or spec.image_id == "py-base":
            # no uploaded checkpoint: initialize dependencies from scratch
            if spec.image_id == "py-base":
                params = wl.py_base_builder()
            else:
                params = wl.model_params_builder(spec.image_id)()
        t.dependency_load = time.perf_counter() - t0
        # compile from scratch (fresh jit wrappers -> fresh XLA compile)
        t1 = time.perf_counter()
        execs = {}
        if spec.image_id in wl.IMAGE_CONFIGS:
            execs = wl.make_model_executables(spec.image_id)
            wl.warm_executables(execs, params, spec.image_id)
        t.dependency_compile = time.perf_counter() - t1
        t.dependency_init = time.perf_counter() - t0

        t0 = time.perf_counter()
        hw = spec.handler_builder()
        t.handler_import = time.perf_counter() - t0

        inst = FunctionInstance(spec, params, hw, execs)
        req = self._first_request(spec)
        _, t.execution = inst.invoke(req)
        return inst, t

    # ------------------------------------------------------------------ warmswap
    def cold_start_warmswap(self, fn_id: str,
                            policy: Optional[RestorePolicy] = None):
        spec = self.registry.get(fn_id)
        policy = policy or self.cfg.policy
        t = PhaseTimes(network=self.cfg.network_s, container=self.cfg.container_s)
        t.boot = self._boot()

        # communication: metadata transfer + page-server attach
        t0 = time.perf_counter()
        restored = self.manager.request_migration(spec.image_id, policy,
                                                  self.cfg.link)
        t.communication = time.perf_counter() - t0

        # migration: restore params (policy decides fault vs stream behaviour).
        # Touch leaves in layer order — the execution-order fault pattern.
        t0 = time.perf_counter()
        touch = (wl.WORKLOADS[fn_id].touch_keys
                 if fn_id in wl.WORKLOADS and wl.WORKLOADS[fn_id].touch_keys
                 else None)
        if policy == RestorePolicy.LAZY and touch is not None:
            for key in touch:                                  # sparse touch set
                restored.fault(key)
            leaves = {k: restored.fault(k) for k in touch}
            params = leaves                                   # partial residency
        else:
            for key in restored.metadata.page_table.order[:1]:
                restored.fault(key)                           # first fault
            params = restored.as_pytree()
        execs = self.manager.executables_for(spec.image_id)   # compile-cache hit
        t.migration = time.perf_counter() - t0

        t0 = time.perf_counter()
        hw = spec.handler_builder()
        t.handler_import = time.perf_counter() - t0

        inst = FunctionInstance(spec, params, hw, execs)
        inst.migration_stats = restored.stats                 # type: ignore[attr-defined]
        req = self._first_request(spec)
        _, t.execution = inst.invoke(req)
        self.manager.release(spec.image_id)
        return inst, t

    # ------------------------------------------------------------------ prebaking
    def prebake(self, fn_id: str) -> None:
        """Snapshot the *whole* warm function (base + handler) — one per function."""
        spec = self.registry.get(fn_id)
        img = self.manager._ensure_live(spec.image_id)
        hw = spec.handler_builder()
        snapshot = {
            "store": np.array(img.store),                     # full private copy
            "table": img.metadata.page_table,
            "treedef": img.treedef,
            "handler": {k: np.array(v) for k, v in hw.items()},
            "execs": img.executables,
        }
        self._prebaked[fn_id] = snapshot

    def prebaked_bytes(self) -> int:
        return sum(s["store"].nbytes + sum(v.nbytes for v in s["handler"].values())
                   for s in self._prebaked.values())

    def cold_start_prebaked(self, fn_id: str):
        spec = self.registry.get(fn_id)
        snap = self._prebaked[fn_id]
        t = PhaseTimes(network=self.cfg.network_s, container=self.cfg.container_s)
        t.boot = self._boot()
        t0 = time.perf_counter()
        from repro.core.pages import materialize
        params = materialize(np.array(snap["store"]), snap["table"], snap["treedef"])
        t.migration = time.perf_counter() - t0
        hw = snap["handler"]
        inst = FunctionInstance(spec, params, hw, snap["execs"])
        req = self._first_request(spec)
        _, t.execution = inst.invoke(req)
        return inst, t
