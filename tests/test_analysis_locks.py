"""repro-lint lock-discipline checker: guarded-by/requires-lock grammar on a
minimal fixture, the PR-2 guarded-attribute race shape as a regression, and
the real annotated classes staying clean."""
import os
import textwrap

from tools.analysis import locks
from tools.analysis.base import REPO_ROOT, SourceFile


def parse(tmp_path, code):
    p = tmp_path / "fixture.py"
    p.write_text(textwrap.dedent(code))
    return SourceFile.parse(str(p))


GUARDED_CLASS = """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = {}          # guarded-by: _lock
            self.evictions = 0       # guarded-by: _lock
            self.items["seed"] = 1   # __init__ is exempt

        def _drop(self, key):        # requires-lock: _lock
            self.items.pop(key, None)
            self.evictions += 1

        def locked_get(self, key):
            with self._lock:
                return self.items.get(key)

        def locked_drop(self, key):
            with self._lock:
                self._drop(key)
"""


def test_clean_guarded_class_passes(tmp_path):
    src = parse(tmp_path, GUARDED_CLASS)
    assert locks.check(src) == []


LEAKY_CLASS = """
    class Leaky:
        def __init__(self):
            import threading
            self._lock = threading.Lock()
            self.items = {}          # guarded-by: _lock
            self.evictions = 0       # guarded-by: _lock

        def _drop(self, key):        # requires-lock: _lock
            self.items.pop(key, None)

        def peek(self, key):
            return self.items.get(key)

        def reset(self):
            self.evictions = 0

        def drop(self, key):
            self._drop(key)
"""


def test_unguarded_read_flagged(tmp_path):
    src = parse(tmp_path, LEAKY_CLASS)
    found = {f.scope: f for f in locks.check(src)}
    f = found["Leaky.peek"]
    assert f.rule == "unguarded-access"
    assert "'self.items'" in f.message
    assert f.message.startswith("read")


def test_unguarded_write_flagged_as_write(tmp_path):
    src = parse(tmp_path, LEAKY_CLASS)
    found = {f.scope: f for f in locks.check(src)}
    f = found["Leaky.reset"]
    assert f.rule == "unguarded-access"
    assert f.message.startswith("write")


def test_unlocked_call_to_requires_lock_helper_flagged(tmp_path):
    src = parse(tmp_path, LEAKY_CLASS)
    found = {f.scope: f for f in locks.check(src)}
    # the contract says the *call site* is the bug: it must hold the lock
    assert found["Leaky.drop"].rule == "unlocked-call"
    assert "_drop" in found["Leaky.drop"].message


def test_requires_lock_helper_may_call_requires_lock_helper(tmp_path):
    src = parse(tmp_path, """
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = {}          # guarded-by: _lock

        def _spill(self, key):       # requires-lock: _lock
            self.items.pop(key, None)

        def _admit(self, key):       # requires-lock: _lock
            self._spill(key)
            self.items[key] = 1
    """)
    assert locks.check(src) == []


def test_pr2_bulk_restore_race_shape_regression(tmp_path):
    """The PR-2 race: restore bookkeeping guarded on the slow path but read
    bare on the fast path, so two threads could both miss and double-fetch."""
    src = parse(tmp_path, """
    import threading

    class RestoreSession:
        def __init__(self):
            self._lock = threading.Lock()
            self._fetched = set()      # guarded-by: _lock

        def fetch_bulk(self, pages):
            with self._lock:
                todo = [p for p in pages if p not in self._fetched]
                self._fetched.update(todo)
            return todo

        def fetch_on_demand(self, page):
            if page in self._fetched:      # the race: unlocked check
                return None
            self._fetched.add(page)        # and unlocked insert
            return page
    """)
    found = locks.check(src)
    assert [f.rule for f in found] == ["unguarded-access", "unguarded-access"]
    assert {f.scope for f in found} == {"RestoreSession.fetch_on_demand"}


def test_annotated_repo_classes_stay_clean():
    for rel in ("src/repro/core/pool.py",
                "src/repro/runtime/fault_tolerance.py"):
        src = SourceFile.parse(os.path.join(REPO_ROOT, rel))
        assert "guarded-by:" in src.text, rel  # annotations present
        assert locks.check(src) == [], rel


def test_files_without_annotations_skipped(tmp_path):
    src = parse(tmp_path, """
    class Plain:
        def __init__(self):
            self.items = {}

        def get(self, k):
            return self.items.get(k)
    """)
    assert locks.check(src) == []
