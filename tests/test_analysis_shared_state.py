"""repro-lint shared-state checker: the PR-1 (shared mutable default) and
PR-4 (stale/loop-variable closure capture) bug classes on seeded fixtures,
plus the attribute-store false-positive regression."""
import textwrap

from tools.analysis import shared_state
from tools.analysis.base import SourceFile

SCOPED = "src/repro/core/_fixture.py"


def parse(tmp_path, code, rel=SCOPED):
    p = tmp_path / "fixture.py"
    p.write_text(textwrap.dedent(code))
    src = SourceFile.parse(str(p))
    src.rel = rel
    return src


def rules(findings):
    return sorted(f.rule for f in findings)


def test_mutable_default_flagged(tmp_path):
    src = parse(tmp_path, """
        def collect(x, acc=[]):
            acc.append(x)
            return acc

        def config(opts={}):
            return opts

        def tags(*, seen=set()):
            return seen
    """)
    assert rules(shared_state.check(src)) == ["mutable-default"] * 3


def test_none_default_clean(tmp_path):
    src = parse(tmp_path, """
        def collect(x, acc=None):
            acc = [] if acc is None else acc
            acc.append(x)
            return acc
    """)
    assert shared_state.check(src) == []


def test_module_mutable_flagged_and_pragma_sanctions(tmp_path):
    src = parse(tmp_path, """
        CACHE = {}
        STATS = {"hits": 0}

        def remember(k, v):
            CACHE[k] = v

        def count():
            STATS["hits"] += 1    # repro-lint: allow[module-mutable]
    """)
    found = shared_state.check(src)
    assert rules(found) == ["module-mutable"]
    assert "CACHE" in found[0].message


def test_loop_closure_flagged_immediate_consumers_clean(tmp_path):
    src = parse(tmp_path, """
        def build(workers):
            picks = []
            for w in workers:
                picks.append(lambda: w.load)          # late binding: bug
            ranked = sorted(workers, key=lambda w: w.load)   # consumed now
            bound = [(lambda w=w: w.load) for w in workers]  # default-bound
            return picks, ranked, bound
    """)
    found = shared_state.check(src)
    assert rules(found) == ["loop-closure"]
    assert "'w'" in found[0].message or "['w']" in found[0].message


def test_pr4_stale_capture_shape_regression(tmp_path):
    """The PR-4 shape: a closure reads a local that the enclosing function
    rebinds afterwards, so the counter hook silently saw the new object."""
    src = parse(tmp_path, """
        def run(specs):
            pool = make_pool(specs)

            def on_hit(fn_id):
                pool.hits[fn_id] += 1

            pool = rebuild(pool)     # rebinds: on_hit now sees this one
            return drive(specs, on_hit)
    """)
    found = shared_state.check(src)
    assert rules(found) == ["stale-capture"]
    assert "'pool'" in found[0].message or "['pool']" in found[0].message


def test_attribute_and_subscript_stores_are_not_rebinds(tmp_path):
    """Regression: mutating an object (res.x = ..., d[k] = ...) after the
    closure is fine — only *rebinding the name* makes the capture stale."""
    src = parse(tmp_path, """
        def run(res, table):
            def report():
                return res.total, table

            res.total = 41
            table["done"] = True
            return report
    """)
    assert shared_state.check(src) == []


def test_rebind_before_closure_is_clean(tmp_path):
    src = parse(tmp_path, """
        def run(specs):
            pool = make_pool(specs)
            pool = rebuild(pool)

            def on_hit(fn_id):
                pool.hits[fn_id] += 1

            return drive(specs, on_hit)
    """)
    assert shared_state.check(src) == []


def test_out_of_scope_file_skipped(tmp_path):
    src = parse(tmp_path, "def f(x=[]):\n    return x\n",
                rel="docs/_fixture.py")
    assert shared_state.check(src) == []
