"""Page-granular cold-start cost model for the simulators (paper §3.2, Table 2).

``simulator.CostModel`` charges one scalar latency per cold start. That hides
the thing HotSwap actually optimizes: a cold start *moves pages* — the shared
dependency image is live-migrated into the container page by page, and its
latency depends on how many pages must move, over which link, and how much of
the transfer the BULK policy hides behind execution. This module prices that:

    cold_latency = scalar base (boot + init compute + handler, per method)
                 + blocking page-transfer time
                   = f(image pages, pages already resident, link tier,
                       fault-on-demand vs background-stream mix)

Three link tiers, matching the cluster-shared image cache (``pool.py``):

  * ``local``  — the worker's own Dependency-Manager pool holds the image;
    pages move at host-memcpy speed (near-zero).
  * ``remote`` — some *other* worker's pool holds it (cluster-shared cache
    hit); pages cross the data-center network once.
  * ``miss``   — no pool holds it; pages come from the source store
    (registry / cold checkpoint storage), the slowest tier. The fetch
    populates the shared cache so the cluster pays it once.

The transfer math mirrors ``migration.RestoredImage`` under ``BULK``: a small
fraction of pages is faulted on demand (each fault pays a full per-request
round trip, serial), the rest is background-streamed in one request with most
of its time overlapped with the function's own execution. ``LAZY`` would be
``fault_fraction=1.0``; the paper's "w/o Lazy Migration" is
``stream_overlap=0.0``.

Units throughout: seconds for latencies, bytes for sizes, pages for counts
(one page = ``page_size`` bytes, default 4 MiB — ``pages.DEFAULT_PAGE_SIZE``).

Degenerate contract (asserted in ``tests/test_costmodel.py`` and relied on by
``docs/SIMULATION.md``): :meth:`PageCostModel.degenerate` — zero per-request
latency, infinite bandwidth on every tier — makes every blocking term exactly
0.0, so ``cold_latency_s`` equals ``method_cold_latency_s`` and both
``simulate()`` and ``simulate_fleet()`` reproduce their scalar results bit for
bit, including the 88 % memory-saving headline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.migration import LinkModel
from repro.core.pages import DEFAULT_PAGE_SIZE
from repro.core.registry import Registry
from repro.core.simulator import CostModel, method_cold_latency_s

#: Valid values for the ``tier`` argument of :meth:`PageCostModel.cold_latency_s`.
TIERS = ("local", "remote", "miss")

#: Name -> page-cost-model factory. Every factory takes the resolved scalar
#: ``cost`` model as its first kwarg (scenario specs inject it): ``default``
#: is the page-granular model with its stock link tiers, ``degenerate`` the
#: scalar-equivalent configuration (infinite bandwidth, zero RTT).
PAGE_COST_MODELS = Registry("page cost model")


def _default_local() -> LinkModel:
    """Host memcpy: ~10 GB/s, negligible per-request setup."""
    return LinkModel(latency_s=2e-6, bandwidth_bps=10e9)


def _default_remote() -> LinkModel:
    """Worker-to-worker DCN: 10 Gb/s with a ~200 us request round trip."""
    return LinkModel(latency_s=2e-4, bandwidth_bps=1.25e9)


def _default_source() -> LinkModel:
    """Source store (registry / cold checkpoint storage): ~400 MB/s, 5 ms RTT."""
    return LinkModel(latency_s=5e-3, bandwidth_bps=400e6)


@dataclass
class PageCostModel:
    """Page-granular cold-start pricing on top of a scalar :class:`CostModel`.

    Args:
        cost: the scalar per-method model. Its ``cold_*_s`` values are read as
            the *zero-transfer* base (container + boot + init compute +
            handler); this model adds the data-movement term on top. Its
            ``image_bytes`` / ``snapshot_bytes`` provide the default payload
            sizes.
        page_size: bytes per page (the transfer/sharing unit).
        local / remote / source: per-tier transports (see module docstring).
        fault_fraction: fraction of the missing pages fetched via synchronous
            page faults (each pays one full per-request round trip, serially).
            The remainder moves in one background bulk stream. 0.0..1.0.
        stream_overlap: fraction of the bulk-stream time hidden behind the
            function's own execution (BULK restore overlaps the stream with
            useful work). 0.0 = fully blocking, 1.0 = fully hidden.
    """
    cost: CostModel
    page_size: int = DEFAULT_PAGE_SIZE
    local: LinkModel = field(default_factory=_default_local)
    remote: LinkModel = field(default_factory=_default_remote)
    source: LinkModel = field(default_factory=_default_source)
    fault_fraction: float = 0.05
    stream_overlap: float = 0.85

    def __post_init__(self) -> None:
        if not (0.0 <= self.fault_fraction <= 1.0):
            raise ValueError(f"fault_fraction must be in [0, 1], "
                             f"got {self.fault_fraction}")
        if not (0.0 <= self.stream_overlap <= 1.0):
            raise ValueError(f"stream_overlap must be in [0, 1], "
                             f"got {self.stream_overlap}")
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, got {self.page_size}")

    # ------------------------------------------------------------- constructors
    @classmethod
    def degenerate(cls, cost: CostModel) -> "PageCostModel":
        """The scalar-equivalent configuration: infinite bandwidth, zero
        per-request latency on every tier, so every transfer term is exactly
        0.0 and ``cold_latency_s`` == ``method_cold_latency_s`` for all
        methods, tiers, and residencies. This is the documented bridge between
        the page model and the pre-existing scalar engine."""
        return cls(cost=cost, local=LinkModel(), remote=LinkModel(),
                   source=LinkModel(), fault_fraction=0.0, stream_overlap=1.0)

    # ------------------------------------------------------------------ helpers
    def n_pages(self, nbytes: int) -> int:
        """Pages needed to hold ``nbytes`` (ceil division; >= 0)."""
        return max(0, -(-int(nbytes) // self.page_size))

    def image_pages(self, image_bytes: Optional[int] = None) -> int:
        """Page count of a dependency image (default: ``cost.image_bytes``)."""
        return self.n_pages(self.cost.image_bytes if image_bytes is None
                            else image_bytes)

    def _link(self, tier: str) -> LinkModel:
        try:
            return {"local": self.local, "remote": self.remote,
                    "miss": self.source}[tier]
        except KeyError:
            raise ValueError(f"unknown tier: {tier!r} (choose from {TIERS})")

    def blocking_s(self, missing_pages: int, link: LinkModel) -> float:
        """Execution-blocking seconds to migrate ``missing_pages`` over ``link``.

        BULK-style split: ``ceil(fault_fraction * missing)`` pages arrive via
        synchronous faults (one request each, serial); the rest arrives in one
        background stream whose time is ``(1 - stream_overlap)`` blocking.
        Returns exactly 0.0 when nothing is missing, and 0.0 under a
        :meth:`degenerate` link (no bandwidth term, no latency term).
        """
        missing = int(missing_pages)
        if missing <= 0:
            return 0.0
        fault_pages = min(missing, math.ceil(self.fault_fraction * missing))
        stream_pages = missing - fault_pages
        t = fault_pages * link.delay_for(self.page_size)
        if stream_pages:
            t += (1.0 - self.stream_overlap) * link.delay_for(
                stream_pages * self.page_size)
        return t

    def transfer_blocking_s(self, tier: str, resident_pages: int = 0,
                            image_bytes: Optional[int] = None) -> float:
        """The warmswap page-transfer term alone (no scalar base): blocking
        seconds to bring the image's non-resident pages in over ``tier``.
        This is the quantity placement ranks workers by (same base everywhere,
        only the transfer differs per worker)."""
        total = self.image_pages(image_bytes)
        return self.blocking_s(total - min(int(resident_pages), total),
                               self._link(tier))

    # ------------------------------------------------------------- the cold path
    def cold_latency_s(self, method: str, tier: str = "local",
                       resident_pages: int = 0,
                       image_bytes: Optional[int] = None) -> float:
        """Cold-start latency (seconds) for ``method`` under the page model.

        Args:
            method: ``'warmswap' | 'prebaking' | 'baseline'``.
            tier: where the warmswap image's pages come from (``'local'`` =
                this worker's pool, ``'remote'`` = another worker's pool via
                the cluster-shared cache, ``'miss'`` = source store). Ignored
                for prebaking (snapshots restore from local RAM) and baseline
                (everything always comes from the source store).
            resident_pages: pages already present at the destination
                (container-side partial residency); only the remainder moves.
                Ignored for baseline, which caches nothing.
            image_bytes: payload size override (default: the scalar model's
                ``image_bytes`` for warmswap/baseline, ``snapshot_bytes`` for
                prebaking).

        Returns:
            ``method_cold_latency_s(cost, method)`` plus the blocking transfer
            term. Under :meth:`degenerate` the transfer term is exactly 0.0.
        """
        if method not in ("warmswap", "prebaking", "baseline"):
            raise ValueError(f"unknown method: {method!r}")
        base = method_cold_latency_s(self.cost, method)
        resident = max(0, int(resident_pages))
        if method == "warmswap":
            total = self.image_pages(image_bytes)
            return base + self.blocking_s(total - min(resident, total),
                                          self._link(tier))
        if method == "prebaking":
            # one whole-snapshot restore: a single eager copy, no page
            # server, nothing overlapped. Tier picks the link: 'local' =
            # this worker's RAM, 'remote' = a peer's snapshot over the
            # network, 'miss' = the source snapshot store.
            total = self.n_pages(self.cost.snapshot_bytes if image_bytes is None
                                 else image_bytes)
            missing = total - min(resident, total)
            return base + (self._link(tier).delay_for(missing * self.page_size)
                           if missing else 0.0)
        # method == "baseline": the full dependency payload from the source
        # store, every time (nothing is ever cached)
        total = self.image_pages(image_bytes)
        return base + (self.source.delay_for(total * self.page_size)
                       if total else 0.0)

    def dependency_loading_speedup(self, tier: str = "local",
                                   image_bytes: Optional[int] = None) -> float:
        """Baseline-vs-WarmSwap *dependency-loading* ratio (the paper's
        2.2-3.2x band): time to make dependencies usable from scratch vs by
        live migration over ``tier``, excluding the shared container overhead
        both methods pay."""
        total = self.image_pages(image_bytes)
        base_s = (self.cost.cold_baseline_s
                  + (self.source.delay_for(total * self.page_size)
                     if total else 0.0))
        ws_s = (self.cost.cold_warmswap_s
                + self.blocking_s(total, self._link(tier)))
        return base_s / max(ws_s, 1e-12)


def _link_from(value) -> LinkModel:
    """A :class:`LinkModel` from a JSON-shaped dict (scenario kwargs) or a
    ready instance."""
    if isinstance(value, LinkModel):
        return value
    return LinkModel(**value)


@PAGE_COST_MODELS.register("default")
def _build_default(cost: CostModel, *, local=None, remote=None, source=None,
                   **kwargs) -> PageCostModel:
    """The stock page-granular model; ``local``/``remote``/``source`` accept
    ``{"latency_s": ..., "bandwidth_bps": ...}`` dicts so scenario specs can
    re-parameterize the link tiers from JSON."""
    for name, value in (("local", local), ("remote", remote),
                        ("source", source)):
        if value is not None:
            kwargs[name] = _link_from(value)
    return PageCostModel(cost=cost, **kwargs)


PAGE_COST_MODELS.register("degenerate", PageCostModel.degenerate)
