"""Shared benchmark scaffolding: fleet setup, timing, CSV emission."""
from __future__ import annotations

import json
import os
import statistics
import tempfile
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def smoke_mode() -> bool:
    """True when the driver was invoked with ``--smoke`` (CI-sized runs)."""
    return os.environ.get("REPRO_SMOKE") == "1"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """The assignment's CSV contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def median(xs: List[float]) -> float:
    return statistics.median(xs) if xs else 0.0


_STACK = None


def build_fleet(functions: Optional[List[str]] = None, link=None):
    """One shared provider stack for all cold-start benchmarks (images built once,
    exactly like a provider would)."""
    global _STACK
    from repro.core import (ColdStartConfig, ColdStartOrchestrator,
                            DependencyManager, FunctionRegistry)
    from repro.core import workloads as wl

    if _STACK is not None:
        return _STACK
    functions = functions or list(wl.WORKLOADS)
    tmp = tempfile.mkdtemp(prefix="warmswap-bench-")
    mgr = DependencyManager(disk_dir=os.path.join(tmp, "pool"),
                            link=link or __import__(
                                "repro.core.migration", fromlist=["LinkModel"]
                            ).LinkModel())
    reg = FunctionRegistry(store_dir=os.path.join(tmp, "store"))
    mgr.register_image("py-base", "py-base", wl.py_base_builder)
    needed_images = {wl.WORKLOADS[f].image_id for f in functions}
    for img_id in sorted(needed_images - {"py-base"}):
        builder = wl.model_params_builder(img_id)
        execs = wl.make_model_executables(img_id)
        wl.warm_executables(execs, builder(), img_id)
        mgr.register_image(img_id, img_id, builder, executables=execs)
    for fn in functions:
        w = wl.WORKLOADS[fn]
        bb = (wl.model_params_builder(w.image_id)
              if w.image_id in wl.IMAGE_CONFIGS else wl.py_base_builder)
        reg.register(fn, w.image_id, w.handler_builder, w.handler_fn,
                     base_params_builder=bb, write_baseline_checkpoint=True)
    orch = ColdStartOrchestrator(mgr, reg, ColdStartConfig())
    _STACK = (mgr, reg, orch)
    return _STACK
