"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, head_dim=64.
[hf:ibm-granite/granite-3.0 family; hf]. NOTE: the assignment header says
"MoE 40e top-8" while the trailing note says "32 experts"; we follow the primary
spec field (40 experts, top-8). 40 % 16 != 0, so experts are TP-sharded along the
expert hidden dim rather than EP-sharded (see DESIGN.md §5).
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    head_dim=64,
    attn_pattern=(GLOBAL_ATTN,),
    n_experts=40,
    top_k=8,
    # perf iteration B: pad expert tensors to 48 (%16==0) for clean expert
    # parallelism on the production mesh — see EXPERIMENTS.md §Perf
    expert_pad_to=48,
    mlp="swiglu",
    tie_embeddings=True,
)
