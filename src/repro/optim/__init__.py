from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.schedule import cosine_schedule
from repro.optim.compression import (
    CompressionConfig,
    compress_gradients,
    decompress_gradients,
    init_error_feedback,
)

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm", "cosine_schedule",
    "CompressionConfig", "compress_gradients", "decompress_gradients",
    "init_error_feedback",
]
