"""Pure-jnp oracle for single-token flash decode over a (ring) KV cache."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(
    q: jax.Array,            # (B, H, d) — the one new token's queries
    k_cache: jax.Array,      # (B, Hkv, S, d)
    v_cache: jax.Array,      # (B, Hkv, S, d)
    valid: jax.Array,        # (S,) bool — slot validity mask (ring/window aware)
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, d = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(B, Hkv, g, d)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, d).astype(q.dtype)
