"""Hindsight-optimal oracle: offline latency lower bounds for completed traces.

Every online policy in this repo (prewarm × placement, ``core/keepalive.py`` /
``serving/scheduler.py``) decides with *partial* knowledge — past arrivals
only. The oracle answers the question those policies are measured against:
**with the full arrival sequence known in advance, how low could latency go
under the same cost model and constraints?** The per-cell distance to that
bound (the *oracle gap*) is the headline metric of the policy tournament
(``experiments/tournament.py``) and the quantity every future learned policy
chases (ROADMAP "policy frontier").

Two tools, with different contracts:

:func:`hindsight_floor` — the **sound** bound, used by the CI dominance gate.
  A pointwise per-request floor built from only three facts about the
  engines (``core/fleet.py``, ``core/simulator.py``):

    1. queue wait is never negative;
    2. a warm serve costs exactly ``cost.warm_s``; a cold serve costs at
       least :func:`min_cold_latency_s` — the cheapest price the engine can
       ever charge for a cold start of that method (scalar revive and
       page-transfer terms are non-negative, and prebaking's
       snapshot-evicted fallback is priced in);
    3. the **first arrival of each function can never be warm-served**:
       pre-warm spawns for a function are only ever scheduled from a prior
       arrival of that same function (``PrewarmPolicy.prewarm_after`` is
       called inside the arrival handler), so no instance of a function
       exists before its first arrival.

  Pointwise dominance implies dominance of the total, of every percentile
  (sorting preserves pointwise order sample-by-sample, and
  ``np.percentile`` is monotone in the sorted samples), and of the mean —
  the **oracle-dominance invariant** asserted in tier-1
  (``tests/test_oracle_properties.py``) and gated in CI
  (``tools/ci/check_bench.py`` fails on any negative or non-finite gap).

:func:`keepalive_frontier` — the **hindsight-optimal keep-alive plan**, used
  for the Pareto report only. With arrivals known, the optimal
  keep-alive-restricted schedule is a fractional knapsack: each inter-arrival
  gap of a function can be "covered" (instance kept alive across it) for a
  byte-minute price of ``gap × idle_bytes``, converting one cold start into
  a warm one (a constant latency gain), so the cheapest gaps are covered
  first and the LP relaxation yields the latency-vs-byte-minutes frontier.
  This is *not* a sound bound against predictive pre-warming (a policy may
  spawn just-in-time and pay fewer idle byte-minutes than the full gap), so
  it never feeds the dominance gate — see docs/SIMULATION.md, "Oracle and
  disruption semantics".

Disruption note: the floor holds unchanged under any
``core/disruption.py`` schedule — worker failures and eviction storms only
ever *add* wait, requeue delay, or cold-start cost, never undercut the
fair-weather minimum, and the oracle (which may place work on any worker)
is free to avoid disrupted workers entirely.

Units follow the repo convention: minutes for times, seconds for latencies,
bytes for sizes (docs/SIMULATION.md).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.costmodel import PageCostModel
from repro.core.simulator import CostModel, method_cold_latency_s
from repro.core.trace_stream import TraceStream
from repro.core.traces import Trace

#: Percentile keys reported by :meth:`OracleResult.latency_percentiles`,
#: matching the engines' ``latency_percentiles()`` schema.
PERCENTILES = (50, 90, 95, 99)


def idle_bytes_for(method: str, cost: CostModel) -> int:
    """Bytes an idle instance of ``method`` pins — the byte-minute unit cost
    of keep-alive, identical to the fleet engine's accounting: warmswap idles
    on per-function metadata only (the image is shared), prebaking on its
    private snapshot, baseline on its privately initialized dependencies."""
    try:
        return {"warmswap": cost.metadata_bytes,
                "prebaking": cost.snapshot_bytes,
                "baseline": cost.image_bytes}[method]
    except KeyError:
        raise ValueError(f"unknown method: {method!r}")


def min_cold_latency_s(method: str, cost: CostModel,
                       page: Optional[PageCostModel] = None) -> float:
    """The cheapest cold-start price either engine can charge for ``method``.

    This is the floor's cold term, derived from the engines' pricing paths
    (``fleet.cold_start`` / ``cold_start_paged`` / the single-worker
    engine's constant): scalar revive (``image_revive_s``) and page-transfer
    blocking terms are additive and non-negative, so the minimum is the
    zero-transfer, pool-hit base — except prebaking, whose snapshot-evicted
    fallback is priced as a *baseline* start, so a pathological cost model
    with ``cold_baseline_s < cold_prebaking_s`` floors at the baseline base.
    ``page`` is accepted for signature symmetry: the page model only adds
    non-negative transfer terms on top of the same scalar bases.
    """
    base = method_cold_latency_s(cost, method)   # validates the method key
    if method == "warmswap":
        # revive is charged on pool miss; guard against fuzzed negatives
        return min(base, base + cost.image_revive_s)
    if method == "prebaking":
        return min(base, method_cold_latency_s(cost, "baseline"))
    return base


@dataclass(frozen=True)
class OracleResult:
    """The hindsight floor for one (traces, method, cost model) triple.

    ``latency_samples_s`` is in merged-arrival order (stable sort by time,
    trace order breaking ties — the same order both engines emit), so it is
    directly comparable index-by-index against an engine result's
    ``latency_samples_s``.
    """
    method: str
    n_invocations: int
    n_cold: int                       # floor: one unavoidable cold per function
    n_warm: int
    min_cold_s: float                 # the per-request cold floor used
    warm_s: float
    idle_bytes: int
    total_latency_s: float
    latency_samples_s: np.ndarray = field(repr=False)

    @property
    def avg_latency_s(self) -> float:
        return (self.total_latency_s / self.n_invocations
                if self.n_invocations else 0.0)

    def percentile(self, q: float) -> float:
        if not self.n_invocations:
            return 0.0
        return float(np.percentile(self.latency_samples_s, q))

    def latency_percentiles(self) -> Dict[str, float]:
        return {f"p{q}": self.percentile(q) for q in PERCENTILES}

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        del d["latency_samples_s"]
        d["avg_latency_s"] = self.avg_latency_s
        d["latency_percentiles_s"] = self.latency_percentiles()
        return d


def hindsight_floor(traces: Union[Sequence[Trace], TraceStream], method: str,
                    cost: CostModel,
                    page_cost: Optional[PageCostModel] = None) -> OracleResult:
    """The sound per-request latency floor over a completed trace set.

    Accepts a :class:`~repro.core.trace_stream.TraceStream` as well: the
    floor is accumulated chunk by chunk (a seen-set of function indices
    carries first-arrival state across chunks), never materializing the
    arrival arrays, and is bit-identical to the in-memory result.

    Each function's first arrival pays :func:`min_cold_latency_s` (no
    instance of it can predate it — see the module docstring); every other
    request pays ``min(warm_s, min_cold_s)`` (served warm at best, or cold
    if the model prices colds below warms); waits are zero. The result's
    total, mean, and every percentile lower-bound every online policy ×
    placement × disruption combination on the same traces under the same
    cost model — byte-minute budgets, capacity pressure, and worker churn
    can only push real results further above the floor.
    """
    mc = min_cold_latency_s(method, cost, page_cost)
    warm = min(cost.warm_s, mc)
    if isinstance(traces, TraceStream):
        # Chunk-wise accumulation: each chunk arrives in the engines' merge
        # order, so the first chunk position of a not-yet-seen function is
        # exactly its first merged-arrival index. Both branches assign the
        # same two constants at the same global positions => bit-identical.
        parts: List[np.ndarray] = []
        seen: set = set()
        n_cold = 0
        for chunk in traces.chunks():
            part = np.full(len(chunk.fn), warm)
            uniq, first_idx = np.unique(chunk.fn, return_index=True)
            for fn, pos in zip(uniq.tolist(), first_idx.tolist()):
                if fn not in seen:
                    seen.add(fn)
                    part[pos] = mc
                    n_cold += 1
            parts.append(part)
        samples = np.concatenate(parts) if parts else np.empty((0,))
    else:
        all_t = (np.concatenate([np.asarray(t.arrivals_min, np.float64)
                                 for t in traces])
                 if traces else np.empty((0,)))
        all_fn = (np.concatenate([np.full(len(t.arrivals_min), t.fn_index,
                                          np.int64) for t in traces])
                  if traces else np.empty((0,), np.int64))
        order = np.argsort(all_t, kind="stable")   # the engines' merge order
        all_fn = all_fn[order]
        samples = np.full(len(all_fn), warm)
        if len(all_fn):
            # first merged arrival of each function index pays the cold floor
            _, first_idx = np.unique(all_fn, return_index=True)
            samples[first_idx] = mc
            n_cold = len(first_idx)
        else:
            n_cold = 0
    return OracleResult(
        method=method,
        n_invocations=len(samples),
        n_cold=n_cold,
        n_warm=len(samples) - n_cold,
        min_cold_s=mc,
        warm_s=cost.warm_s,
        idle_bytes=idle_bytes_for(method, cost),
        total_latency_s=float(samples.sum()),
        latency_samples_s=samples,
    )


def gap_report(oracle: OracleResult, result) -> Dict[str, float]:
    """Per-cell oracle gap: how far an engine result sits above the floor.

    ``result`` is any engine result with ``total_latency_s``,
    ``n_invocations`` and a ``latency_samples_s`` array (``FleetResult`` /
    ``SimResult``). All gaps are >= 0 whenever the dominance invariant
    holds; the CI gate (``tools/ci/check_bench.py``) fails the build on a
    negative or non-finite gap.
    """
    if result.n_invocations != oracle.n_invocations:
        raise ValueError(
            f"oracle was built for {oracle.n_invocations} request(s) but the "
            f"result has {result.n_invocations}; they must share traces")
    samples = np.asarray(result.latency_samples_s, np.float64)
    p99 = float(np.percentile(samples, 99)) if len(samples) else 0.0
    return {
        "total_gap_s": float(result.total_latency_s) - oracle.total_latency_s,
        "p99_gap_s": p99 - oracle.percentile(99),
        "oracle_total_s": oracle.total_latency_s,
        "oracle_p99_s": oracle.percentile(99),
    }


# ---------------------------------------------------------------------------
# Hindsight-optimal keep-alive: the latency/byte-minute frontier (report only)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FrontierPoint:
    """One point of the hindsight keep-alive frontier: covering the
    ``covered_gaps`` cheapest inter-arrival gaps costs ``byte_minutes``
    (idle residency) and achieves ``total_latency_s``."""
    byte_minutes: float
    total_latency_s: float
    covered_gaps: int

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def keepalive_frontier(traces: Sequence[Trace], method: str, cost: CostModel,
                       page_cost: Optional[PageCostModel] = None,
                       n_points: int = 9) -> List[FrontierPoint]:
    """The hindsight-optimal keep-alive latency-vs-byte-minutes frontier.

    Restricted model (one instance per function, keep-alive decisions only):
    covering a function's inter-arrival gap ``g`` minutes keeps its instance
    resident across it — byte-minute cost ``g * idle_bytes``, latency gain
    ``min_cold_s - warm_s`` seconds (one cold becomes warm). Gains are
    constant, so the optimal plan under any byte-minute budget covers the
    cheapest (shortest) gaps first; sweeping the budget yields this
    frontier, from all-cold (0 byte-minutes) to all-gaps-covered.

    This is a *report* — optimal only among keep-alive-restricted schedules.
    A predictive pre-warm can beat a point here by spawning just-in-time
    (paying less idle residency than the full gap), which is why the CI
    dominance gate uses :func:`hindsight_floor`, never this frontier.

    Returns ``n_points`` points (at least the two endpoints), byte-minutes
    non-decreasing. A :class:`~repro.core.trace_stream.TraceStream` is
    materialized first (gap sorting needs full per-function arrival arrays) —
    this is a report path, not part of the out-of-core contract.
    """
    if isinstance(traces, TraceStream):
        traces = traces.materialize()
    mc = min_cold_latency_s(method, cost, page_cost)
    gain_s = max(0.0, mc - cost.warm_s)
    idle = idle_bytes_for(method, cost)
    gaps = [np.diff(np.asarray(t.arrivals_min, np.float64))
            for t in traces if len(t.arrivals_min) > 1]
    gaps_min = (np.sort(np.concatenate(gaps), kind="stable") if gaps
                else np.empty((0,)))
    n_req = sum(len(t.arrivals_min) for t in traces)
    n_fns = sum(1 for t in traces if len(t.arrivals_min))
    # all-cold baseline: every request pays the cold floor
    all_cold_s = n_req * mc
    costs_bm = np.cumsum(gaps_min) * idle        # cheapest-first cumulative
    n_gaps = len(gaps_min)
    if n_points < 2:
        n_points = 2
    picks = sorted(set(
        int(round(i * n_gaps / (n_points - 1))) for i in range(n_points)))
    out = []
    for k in picks:
        bm = float(costs_bm[k - 1]) if k else 0.0
        out.append(FrontierPoint(
            byte_minutes=bm,
            total_latency_s=all_cold_s - k * gain_s,
            covered_gaps=k,
        ))
    # sanity: covering every gap leaves exactly one cold per function
    assert out[-1].covered_gaps != n_gaps or \
        abs(out[-1].total_latency_s
            - (n_fns * mc + (n_req - n_fns) * cost.warm_s)) < 1e-6 * max(
                1.0, all_cold_s)
    return out


# ---------------------------------------------------------------------------
# Spec-level entry point
# ---------------------------------------------------------------------------

def oracle_from_scenario(scenario, *, smoke: bool = False,
                         traces: Optional[Sequence[Trace]] = None,
                         ) -> Dict[str, OracleResult]:
    """Hindsight floors for every method of a :class:`~repro.core.scenario.
    Scenario`, resolving its trace/cost/page components from the registries
    exactly as :func:`repro.core.scenario.run` would (``smoke`` applies the
    spec's ``smoke_overrides`` first). Pass ``traces`` to reuse
    already-materialized arrivals (e.g. from a ``Result``), guaranteeing the
    floor and the engine run saw the same sequence."""
    from repro.core.costmodel import PAGE_COST_MODELS
    from repro.core.simulator import COST_MODELS
    from repro.core.traces import TRACE_GENERATORS

    scn = scenario.smoke_scaled() if smoke else scenario
    if traces is None:
        traces = TRACE_GENERATORS.build(scn.traces.name, **scn.traces.kwargs)
    cost = COST_MODELS.build(scn.cost.name, **scn.cost.kwargs)
    page = None
    if scn.page_cost is not None:
        page = PAGE_COST_MODELS.build(scn.page_cost.name, cost=cost,
                                      **scn.page_cost.kwargs)
    return {m: hindsight_floor(traces, m, cost, page) for m in scn.methods}
