"""End-to-end serving driver: WarmSwap pool -> engine bring-up -> batched requests.

This is the paper's runtime phase as a service: the provider registers dependency
images once; replicas cold-start by live migration from the pool (compile-cache +
page stream) and then serve continuous-batched decode traffic.

  python -m repro.launch.serve --image model-tiny --requests 16 --slots 4
  python -m repro.launch.serve --arch qwen3_1_7b --reduced --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--image", default=None,
                    help="workload image id (model-tiny/small/medium)")
    ap.add_argument("--arch", default=None, help="or an assigned arch id (reduced)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--policy", default="bulk",
                    choices=["bulk", "lazy", "no_pageserver", "no_lazy"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.core import DependencyManager, RestorePolicy
    from repro.core import workloads as wl
    from repro.models.transformer import init_params
    from repro.serving import ServeConfig, ServingEngine

    policy = RestorePolicy(args.policy)
    mgr = DependencyManager()

    if args.arch:
        from repro.configs import get_config, get_reduced
        cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
        image_id = f"arch-{cfg.name}"
        mgr.register_image(
            image_id, cfg.name,
            lambda: init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32))
    else:
        image_id = args.image or "model-tiny"
        cfg = wl.IMAGE_CONFIGS[image_id]
        mgr.register_image(image_id, image_id, wl.model_params_builder(image_id))

    print(f"[serve] pool ready: {mgr.summary()['live_images']} "
          f"({mgr.pool_bytes()/1e6:.1f} MB)")

    t0 = time.perf_counter()
    engine = ServingEngine.from_pool(
        mgr, image_id, cfg,
        ServeConfig(max_slots=args.slots, max_seq_len=args.max_seq,
                    max_new_tokens=args.max_new),
        policy=policy)
    print(f"[serve] replica cold-start via WarmSwap ({policy.value}): "
          f"{time.perf_counter()-t0:.3f}s")

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, min(64, args.max_seq - args.max_new)))
        engine.submit(rng.integers(0, cfg.vocab_size, plen))
    t1 = time.perf_counter()
    engine.run_until_done()
    dt = time.perf_counter() - t1
    m = engine.metrics()
    total_tokens = sum(len(r.tokens) for r in engine.completed.values())
    print(f"[serve] {m['completed']} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s); mean ttft={m['mean_ttft_s']*1e3:.0f}ms "
          f"mean latency={m['mean_latency_s']*1e3:.0f}ms")


if __name__ == "__main__":
    main()
