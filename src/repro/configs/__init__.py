"""Assigned-architecture registry.

Each module defines ``CONFIG`` (the exact published configuration) — selectable via
``--arch <id>`` in every launcher. ``get_config(name)`` / ``list_archs()`` are the
programmatic API; ``get_reduced(name)`` returns the CPU smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig, SHAPES, ShapeConfig

ARCH_IDS = (
    "gemma2_27b",
    "qwen3_1_7b",
    "h2o_danube3_4b",
    "qwen1_5_0_5b",
    "falcon_mamba_7b",
    "whisper_small",
    "recurrentgemma_2b",
    "granite_moe_3b_a800m",
    "moonshot_v1_16b_a3b",
    "internvl2_1b",
    # paper-workload analogues (serverless function classes from Table 1)
    "fnbench_tiny",
)

_ALIASES = {
    "gemma2-27b": "gemma2_27b",
    "qwen3-1.7b": "qwen3_1_7b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "h2o-danube3-4b": "h2o_danube3_4b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-small": "whisper_small",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "internvl2-1b": "internvl2_1b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def get_reduced(name: str, **overrides) -> ArchConfig:
    return get_config(name).reduced(**overrides)


def list_archs() -> tuple:
    return ARCH_IDS


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
