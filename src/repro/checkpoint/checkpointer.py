"""Atomic, async, resharding-capable checkpointing (fault-tolerance substrate).

Guarantees:
  * **atomicity** — a checkpoint directory appears only fully written (tmp dir +
    ``os.replace``); a crash mid-save never corrupts the latest checkpoint;
  * **integrity** — per-leaf CRC32 recorded in the manifest and verified on restore;
  * **async** — saves run on a background thread off the training loop; ``wait()``
    joins before the next save or at shutdown (bounded staleness of one step);
  * **resharding** — checkpoints store *global* host arrays + the pytree structure,
    so a restart may use a different mesh/DP width (elastic restart): restore returns
    host arrays and the launcher ``device_put``s them under the new shardings;
  * **GC** — keep-last-k, never deleting the newest complete checkpoint.

This is also the WarmSwap disk tier's big sibling: the dependency pool's disk images
hold only base params; training checkpoints add optimizer state + step (which is
exactly the per-function state Prebaking would have to replicate N times).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import numpy as np


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep_last: int = 3
    async_save: bool = True
    verify_on_restore: bool = True


def _leaf_to_np(leaf) -> np.ndarray:
    arr = np.asarray(leaf)
    return arr


def _save_tree(tree: Any, path: str, manifest: Dict[str, Any], prefix: str) -> None:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    entries = []
    for i, (kpath, leaf) in enumerate(leaves):
        arr = _leaf_to_np(leaf)
        fname = f"{prefix}_{i}.npy"
        dtype_name = arr.dtype.name
        if dtype_name == "bfloat16":
            np.save(os.path.join(path, fname), arr.view(np.uint16))
        else:
            np.save(os.path.join(path, fname), arr)
        entries.append({
            "key": jax.tree_util.keystr(kpath),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        })
    manifest[prefix] = entries


def _load_tree(like: Any, path: str, manifest: Dict[str, Any], prefix: str,
               verify: bool) -> Any:
    import ml_dtypes
    entries = manifest[prefix]
    leaves = []
    for e in entries:
        arr = np.load(os.path.join(path, e["file"]))
        if e["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != e["crc32"]:
                raise IOError(f"checkpoint corruption: {e['key']} crc mismatch")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, trees: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> None:
        """trees: e.g. {'params': ..., 'opt_state': ...}. Host-blocking copy happens
        here (cheap vs XLA step); disk IO happens on the async thread."""
        self.wait()
        host_trees = {name: jax.tree.map(lambda a: np.asarray(a), t)
                      for name, t in trees.items()}
        if self.cfg.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_trees, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_trees, extra or {})

    def _write(self, step: int, trees: Dict[str, Any], extra: Dict[str, Any]) -> None:
        try:
            final = os.path.join(self.cfg.directory, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest: Dict[str, Any] = {"step": step, "time": time.time(),
                                        "extra": extra}
            for name, tree in trees.items():
                _save_tree(tree, tmp, manifest, name)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(m.group(1)) for d in os.listdir(self.cfg.directory)
                       if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: -self.cfg.keep_last]:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ restore
    def restore(self, step: Optional[int], like: Dict[str, Any]
                ) -> Optional[Dict[str, Any]]:
        """Returns host-array trees matching the ``like`` structures (shardings are
        applied by the caller — this is what makes elastic restarts possible)."""
        self.wait()
        if step is None:
            step = latest_step(self.cfg.directory)
            if step is None:
                return None
        path = os.path.join(self.cfg.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        out = {name: _load_tree(tree, path, manifest, name,
                                self.cfg.verify_on_restore)
               for name, tree in like.items()}
        out["__manifest__"] = manifest
        return out
