"""Shared infrastructure for repro-lint checkers: parsed source files,
suppression pragmas, scope (qualname) resolution, and file collection.

Pragma grammar (full catalog in docs/ANALYSIS.md):

* ``# repro-lint: allow[rule-a,rule-b]`` — suppress those rules on this
  physical line and the next (so a standalone comment line sanctions the
  statement below it);
* ``# repro-lint: allow-file[rule-a]`` — suppress a rule file-wide;
* ``# guarded-by: <lockattr>`` / ``# requires-lock: <lockattr>`` — the
  lock-discipline annotations, parsed by ``tools/analysis/locks.py``.
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.analysis.findings import Finding

#: Repo root = the directory holding ``tools/`` (fingerprints are relative
#: to it, so runs from any cwd produce identical baselines).
REPO_ROOT = os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))

_PRAGMA = re.compile(r"#\s*repro-lint:\s*(allow|allow-file)\[([^\]]+)\]")


def rel_path(path: str) -> str:
    """``path`` relative to the repo root, posix separators."""
    return os.path.relpath(os.path.abspath(path),
                           REPO_ROOT).replace(os.sep, "/")


def _comment_lines(text: str, lines: List[str]) -> List[Tuple[int, str]]:
    """(lineno, comment text) for every *real* comment token — pragma text
    inside a string literal (e.g. a test fixture) is not a pragma."""
    try:
        return [(tok.start[0], tok.string)
                for tok in tokenize.generate_tokens(
                    io.StringIO(text).readline)
                if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError):
        return [(i, raw) for i, raw in enumerate(lines, start=1)
                if "#" in raw]


@dataclass
class SourceFile:
    """One parsed Python source file plus its suppression pragmas.

    Suppression *usage* is tracked: every time a pragma actually suppresses
    a finding, the declaring ``(line, rule)`` is recorded, so
    :meth:`stale_pragmas` can report dead suppressions after a full-checker
    run (docs/ANALYSIS.md, "Stale pragmas")."""
    path: str                      # absolute
    rel: str                       # repo-relative (fingerprint key)
    text: str
    lines: List[str]               # 1-indexed via line(n)
    tree: ast.Module
    allow: Dict[int, Set[str]] = field(default_factory=dict)
    allow_file: Set[str] = field(default_factory=set)
    #: pragma physical line -> rules an ``allow[...]`` there declares
    pragma_lines: Dict[int, Set[str]] = field(default_factory=dict)
    #: rule -> physical line of its ``allow-file[...]`` pragma
    file_pragma_lines: Dict[str, int] = field(default_factory=dict)
    #: ``(pragma line, rule)`` pairs that suppressed at least one finding
    used_pragmas: Set[Tuple[int, str]] = field(default_factory=set)
    used_file_pragmas: Set[str] = field(default_factory=set)

    @classmethod
    def parse(cls, path: str) -> "SourceFile":
        with open(path) as f:
            text = f.read()
        lines = text.splitlines()
        tree = ast.parse(text, filename=path)
        allow: Dict[int, Set[str]] = {}
        allow_file: Set[str] = set()
        pragma_lines: Dict[int, Set[str]] = {}
        file_pragma_lines: Dict[str, int] = {}
        for i, comment in _comment_lines(text, lines):
            for kind, rules in _PRAGMA.findall(comment):
                names = {r.strip() for r in rules.split(",") if r.strip()}
                if kind == "allow-file":
                    allow_file |= names
                    for name in sorted(names):
                        file_pragma_lines.setdefault(name, i)
                else:
                    # a pragma covers its own line and the one below, so a
                    # standalone comment can sanction the next statement
                    allow.setdefault(i, set()).update(names)
                    allow.setdefault(i + 1, set()).update(names)
                    pragma_lines.setdefault(i, set()).update(names)
        return cls(path=path, rel=rel_path(path), text=text, lines=lines,
                   tree=tree, allow=allow, allow_file=allow_file,
                   pragma_lines=pragma_lines,
                   file_pragma_lines=file_pragma_lines)

    def line(self, n: int) -> str:
        """The 1-indexed physical source line (empty when out of range)."""
        return self.lines[n - 1] if 1 <= n <= len(self.lines) else ""

    def allowed(self, lineno: int, rule: str) -> bool:
        if rule in self.allow_file:
            self.used_file_pragmas.add(rule)
            return True
        if rule in self.allow.get(lineno, ()):
            # credit the declaring pragma: on this line or the one above
            for decl in (lineno, lineno - 1):
                if rule in self.pragma_lines.get(decl, ()):
                    self.used_pragmas.add((decl, rule))
            return True
        return False

    def stale_pragmas(self) -> List[Tuple[int, str]]:
        """``(line, rule)`` for every declared pragma rule that suppressed
        nothing. Only meaningful after *all* AST checkers have run over this
        file — a subset run would report false staleness."""
        stale = [(line, rule)
                 for line, rules in self.pragma_lines.items()
                 for rule in rules if (line, rule) not in self.used_pragmas]
        stale.extend((line, rule)
                     for rule, line in self.file_pragma_lines.items()
                     if rule not in self.used_file_pragmas)
        return sorted(stale)

    def finding(self, checker: str, rule: str, node: ast.AST, message: str,
                scope: str = "", suggestion: str = "") -> Optional[Finding]:
        """A :class:`Finding` at ``node`` — or ``None`` when a pragma on the
        node's line (or the line above) suppresses the rule."""
        lineno = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        if self.allowed(lineno, rule):
            return None
        return Finding(checker=checker, rule=rule, path=self.rel,
                       line=lineno, col=col, message=message, scope=scope,
                       snippet=self.line(lineno).strip(),
                       suggestion=suggestion)


# -------------------------------------------------------------- scope walking

def qualname_index(tree: ast.Module) -> Dict[ast.AST, str]:
    """node -> dotted qualname of the innermost enclosing class/function
    (``""`` at module level), for every node in ``tree``."""
    index: Dict[ast.AST, str] = {}

    def walk(node: ast.AST, scope: str) -> None:
        for child in ast.iter_child_nodes(node):
            child_scope = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                child_scope = f"{scope}.{child.name}" if scope else child.name
            index[child] = child_scope
            walk(child, child_scope)

    index[tree] = ""
    walk(tree, "")
    return index


def enclosing_function_name(index: Dict[ast.AST, str], node: ast.AST) -> str:
    """Last component of the node's scope qualname (``""`` at module level).
    Used to match config-sanctioned entry points by function name."""
    scope = index.get(node, "")
    return scope.rsplit(".", 1)[-1] if scope else ""


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else ``None``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ------------------------------------------------------------ file collection

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "node_modules",
              ".hypothesis", "results"}


def collect_files(paths: Iterable[str]) -> Tuple[List[str], List[str]]:
    """Expand CLI ``paths`` (files or directories) into sorted
    ``(python_files, json_files)`` absolute-path lists."""
    py: Set[str] = set()
    js: Set[str] = set()
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isfile(p):
            (py if p.endswith(".py") else
             js if p.endswith(".json") else set()).add(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for name in files:
                if name.endswith(".py"):
                    py.add(os.path.join(root, name))
                elif name.endswith(".json"):
                    js.add(os.path.join(root, name))
    return sorted(py), sorted(js)
