"""Deterministic stand-in for `hypothesis` when it is not installed.

Several test modules use hypothesis property tests (`@given` over strategies).
The CI/tier-1 environment does not always ship hypothesis, and installing new
packages is not an option there.  Rather than skipping those modules wholesale
(they also contain plain tests), `conftest.py` installs this shim into
``sys.modules['hypothesis']`` **only when the real package is absent**.

The shim re-runs each property test body over `max_examples` pseudo-random
examples drawn from a fixed-seed generator — a seeded fuzz pass rather than
true property-based testing (no shrinking, no example database).  Supported
surface is exactly what the test-suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers / st.floats / st.sampled_from / st.composite / st.lists /
    st.booleans / st.just
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_FALLBACK = True          # conftest checks this to report the substitution
_SEED = 0x5EED


class Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng):
        return self._sample(rng)

    def map(self, fn):
        return Strategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred, _max_tries=1000):
        def sample(rng):
            for _ in range(_max_tries):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate never satisfied")
        return Strategy(sample)


def integers(min_value, max_value):
    return Strategy(lambda rng: int(rng.integers(min_value, int(max_value) + 1)))


def floats(min_value, max_value, **_):
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def just(value):
    return Strategy(lambda rng: value)


def sampled_from(elements):
    elements = list(elements)
    return Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def lists(element, min_size=0, max_size=10, **_):
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [element.example(rng) for _ in range(n)]
    return Strategy(sample)


def composite(fn):
    @functools.wraps(fn)
    def builder(*args, **kwargs):
        def sample(rng):
            def draw(strategy):
                return strategy.example(rng)
            return fn(draw, *args, **kwargs)
        return Strategy(sample)
    return builder


class settings:
    """Decorator-compatible subset: only max_examples is honoured."""

    def __init__(self, max_examples=20, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        # Positional strategies bind to the RIGHTMOST parameters (as in real
        # hypothesis); anything left of them (e.g. fixtures) stays visible to
        # pytest and reaches the wrapper as keyword arguments.
        params = list(inspect.signature(fn).parameters.values())
        strategy_names = [p.name for p in
                          params[len(params) - len(arg_strategies):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_fallback_settings", None)
                   or getattr(fn, "_fallback_settings", None))
            n = cfg.max_examples if cfg else 20
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                drawn = {name: s.example(rng)
                         for name, s in zip(strategy_names, arg_strategies)}
                drawn.update({k: s.example(rng)
                              for k, s in kw_strategies.items()})
                fn(*args, **kwargs, **drawn)

        keep = params[: len(params) - len(arg_strategies)]
        keep = [p for p in keep if p.name not in kw_strategies]
        try:
            del wrapper.__wrapped__          # stop signature() following fn
        except AttributeError:
            pass
        wrapper.__signature__ = inspect.Signature(keep)
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
for _name, _obj in (("integers", integers), ("floats", floats),
                    ("booleans", booleans), ("just", just),
                    ("sampled_from", sampled_from), ("lists", lists),
                    ("composite", composite)):
    setattr(strategies, _name, _obj)
