"""Unit tests for the tools/ci gate scripts: each main() passes on a crafted
good artifact and fails (raises or returns 1) on a crafted bad one, so the CI
gates themselves are regression-tested without running a bench."""
import json
import math

import pytest

from tools.ci import check_bench, check_doc_links, check_latency, \
    check_page_model, check_trend


# ------------------------------------------------------------ check_bench

def bench_artifact(**overrides):
    head = {
        "memory_saving_vs_prebaking": 0.88,
        "sharing_memory_saving_vs_prebaking": 0.88,
        "dependency_loading_speedup": 2.7,
        "azure_scale_n_invocations": 1_200_000,
        "azure_scale_wall_clock_s": 30.0,
        "azure_scale_xl_n_invocations": 12_000_000,
        "azure_scale_xl_wall_clock_s": 40.0,
        "oracle_gap": {"min_total_gap_s": 1.5, "min_p99_gap_s": 0.01,
                       "n_cells": 67},
        "sanitize_overhead_ratio": 1.6,
    }
    head.update(overrides)
    return {"bench_schema_version": 1,
            "cells": {"coldstart": {"ok": True}},
            "headline": head}


def write(tmp_path, data, name="artifact.json"):
    p = tmp_path / name
    p.write_text(json.dumps(data))
    return str(p)


def test_check_bench_passes_in_band(tmp_path):
    assert check_bench.main(write(tmp_path, bench_artifact())) == 0


@pytest.mark.parametrize("overrides,fragment", [
    ({"memory_saving_vs_prebaking": 0.50}, "memory saving"),
    ({"dependency_loading_speedup": 5.0}, "speedup"),
    ({"azure_scale_n_invocations": 10}, "invocations"),
    ({"azure_scale_xl_wall_clock_s": 300.0}, "vectorized engine"),
    ({"oracle_gap": {"min_total_gap_s": -0.1, "min_p99_gap_s": 0.0,
                     "n_cells": 5}}, "dominance invariant"),
    ({"oracle_gap": {"min_total_gap_s": 0.0, "min_p99_gap_s": math.nan,
                     "n_cells": 5}}, "finite"),
    ({"oracle_gap": {"min_total_gap_s": 0.0, "min_p99_gap_s": 0.0,
                     "n_cells": 0}}, "no cells"),
    ({"sanitize_overhead_ratio": 4.5}, "sanitize"),
    ({"sanitize_overhead_ratio": math.nan}, "finite"),
])
def test_check_bench_fails_out_of_band(tmp_path, overrides, fragment):
    path = write(tmp_path, bench_artifact(**overrides))
    with pytest.raises(AssertionError, match=fragment):
        check_bench.main(path)


def test_check_bench_requires_oracle_gap_block(tmp_path):
    data = bench_artifact()
    del data["headline"]["oracle_gap"]
    with pytest.raises(KeyError):
        check_bench.main(write(tmp_path, data))


def test_check_bench_fails_on_failed_cell(tmp_path):
    data = bench_artifact()
    data = {"bench_schema_version": 1,
            "cells": {"coldstart": {"ok": False}},
            "headline": data["headline"]}
    with pytest.raises(AssertionError, match="cells failed"):
        check_bench.main(write(tmp_path, data))


def test_check_bench_rejects_unknown_schema(tmp_path):
    data = bench_artifact()
    data["bench_schema_version"] = 99
    with pytest.raises(AssertionError, match="schema"):
        check_bench.main(write(tmp_path, data))


# ------------------------------------------------------------ check_trend

def trend_artifact(**overrides):
    """A self-consistent BENCH_smoke.json; overrides patch cells/headline."""
    data = {
        "bench_schema_version": 1,
        "smoke": True,
        "cells": {"fleet": {"ok": True, "wall_clock_s": 30.0},
                  "sharing": {"ok": True, "wall_clock_s": 4.0}},
        "headline": {
            "memory_saving_vs_prebaking": 0.88,
            "dependency_loading_speedup": 2.7,
            "azure_scale_n_invocations": 1_200_000,
            "azure_scale_wall_clock_s": 12.0,
            "oracle_gap": {"min_total_gap_s": 1.5, "min_p99_gap_s": 0.01,
                           "n_cells": 67},
        },
    }
    for key, value in overrides.items():
        node = data
        *parents, leaf = key.split(".")
        for p in parents:
            node = node[p]
        node[leaf] = value
    return data


def test_check_trend_passes_on_identical(tmp_path):
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(tmp_path, trend_artifact(), "new.json")
    assert check_trend.main(new, prev) == 0


def test_check_trend_passes_within_slack(tmp_path):
    # +20% relative is inside the 25% + 2s budget
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(tmp_path, trend_artifact(**{"cells.fleet.wall_clock_s": 36.0}),
                "new.json")
    assert check_trend.main(new, prev) == 0


def test_check_trend_fails_on_30pct_wall_clock_regression(tmp_path):
    # the acceptance case: a synthetic 30% regression on a large cell
    # (outside the 25% + 2s budget) must fail the gate
    prev = write(tmp_path,
                 trend_artifact(**{"cells.fleet.wall_clock_s": 100.0}),
                 "prev.json")
    new = write(tmp_path,
                trend_artifact(**{"cells.fleet.wall_clock_s": 130.0}),
                "new.json")
    with pytest.raises(AssertionError, match="wall-clock regression"):
        check_trend.main(new, prev)


def test_check_trend_abs_slack_absorbs_small_cells(tmp_path):
    # 4.0s -> 6.9s is +72% relative but inside 4*1.25 + 2 = 7s
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(tmp_path,
                trend_artifact(**{"cells.sharing.wall_clock_s": 6.9}),
                "new.json")
    assert check_trend.main(new, prev) == 0


def test_check_trend_fails_on_headline_drift(tmp_path):
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(
        tmp_path,
        trend_artifact(**{"headline.memory_saving_vs_prebaking": 0.879}),
        "new.json")
    with pytest.raises(AssertionError, match="deterministic headline drift"):
        check_trend.main(new, prev)


def test_check_trend_fails_on_missing_headline_metric(tmp_path):
    prev = write(tmp_path, trend_artifact(), "prev.json")
    data = trend_artifact()
    del data["headline"]["dependency_loading_speedup"]
    new = write(tmp_path, data, "new.json")
    with pytest.raises(AssertionError, match="disappeared"):
        check_trend.main(new, prev)


def test_check_trend_fails_on_shrinking_oracle_coverage(tmp_path):
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(tmp_path,
                trend_artifact(**{"headline.oracle_gap.n_cells": 12}),
                "new.json")
    with pytest.raises(AssertionError, match="coverage shrank"):
        check_trend.main(new, prev)


def test_check_trend_new_and_removed_cells_pass(tmp_path):
    prev_data = trend_artifact()
    prev_data["cells"]["legacy"] = {"ok": True, "wall_clock_s": 9.0}
    prev = write(tmp_path, prev_data, "prev.json")
    new_data = trend_artifact()
    new_data["cells"]["brand_new"] = {"ok": True, "wall_clock_s": 50.0}
    new = write(tmp_path, new_data, "new.json")
    assert check_trend.main(new, prev) == 0


def test_check_trend_passes_without_previous_artifact(tmp_path):
    new = write(tmp_path, trend_artifact(), "new.json")
    assert check_trend.main(new, str(tmp_path / "nope.json")) == 0


def test_check_trend_writes_job_summary(tmp_path, monkeypatch):
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    prev = write(tmp_path, trend_artifact(), "prev.json")
    new = write(tmp_path, trend_artifact(), "new.json")
    assert check_trend.main(new, prev) == 0
    text = summary.read_text()
    assert "## Bench trend" in text and "cells.fleet" in text


def test_check_trend_rejects_unknown_schema(tmp_path):
    data = trend_artifact()
    data["bench_schema_version"] = 99
    prev = write(tmp_path, trend_artifact(), "prev.json")
    with pytest.raises(AssertionError, match="schema"):
        check_trend.main(write(tmp_path, data, "new.json"), prev)


# ---------------------------------------------------------- check_latency

def test_check_latency_passes_on_finite(tmp_path):
    data = {"fleet": {"warmswap": {"latency": {"p50": 0.1, "p99": 1.2},
                                   "queue_delay_mean": 0.0}}}
    assert check_latency.main(write(tmp_path, data)) == 0


def test_check_latency_fails_on_nan(tmp_path):
    data = {"fleet": {"warmswap": {"latency": {"p99": math.nan}}}}
    assert check_latency.main(write(tmp_path, data)) == 1


def test_check_latency_fails_on_negative(tmp_path):
    data = {"fleet": {"p95": -0.5}}
    assert check_latency.main(write(tmp_path, data)) == 1


def test_check_latency_ignores_non_latency_numbers(tmp_path):
    data = {"fleet": {"n_cold_starts": -1, "notes": {"seed": -7}}}
    assert check_latency.main(write(tmp_path, data)) == 0


# -------------------------------------------------------- check_page_model

def page_artifact():
    return {"page_model": {
        "latency_vs_image_size": {
            "230MB": {"warm_s": 0.05, "hotswap_s": 0.9, "cold_s": 2.4,
                      "dependency_loading_speedup": 2.6}},
        "dependency_loading_speedup_paper_scale": 2.7,
        "cache_footprint": {"saving_fraction": 0.88,
                            "hotswap_shared_peak_mb": 230.0,
                            "prebaking_shared_peak_mb": 1900.0}}}


def test_check_page_model_passes(tmp_path):
    assert check_page_model.main(write(tmp_path, page_artifact())) == 0


def test_check_page_model_fails_when_hotswap_not_between(tmp_path):
    data = page_artifact()
    data["page_model"]["latency_vs_image_size"]["230MB"]["hotswap_s"] = 3.0
    with pytest.raises(AssertionError, match="between warm and cold"):
        check_page_model.main(write(tmp_path, data))


def test_check_page_model_fails_on_speedup_band(tmp_path):
    data = page_artifact()
    data["page_model"]["dependency_loading_speedup_paper_scale"] = 9.0
    with pytest.raises(AssertionError, match="2.2-3.2"):
        check_page_model.main(write(tmp_path, data))


def test_check_page_model_fails_on_footprint_inversion(tmp_path):
    data = page_artifact()
    data["page_model"]["cache_footprint"]["hotswap_shared_peak_mb"] = 2000.0
    with pytest.raises(AssertionError):
        check_page_model.main(write(tmp_path, data))


# -------------------------------------------------------- check_doc_links

def test_check_doc_links_passes_on_resolvable(tmp_path):
    (tmp_path / "TARGET.md").write_text("# target\n")
    doc = tmp_path / "doc.md"
    doc.write_text("[ok](TARGET.md) [anchor](#sec) "
                   "[web](https://example.com/x)\n")
    assert check_doc_links.main(str(doc)) == 0


def test_check_doc_links_fails_on_dangling(tmp_path, capsys):
    doc = tmp_path / "doc.md"
    doc.write_text("[missing](NOPE.md)\n")
    assert check_doc_links.main(str(doc)) == 1
    assert "NOPE.md" in capsys.readouterr().out
