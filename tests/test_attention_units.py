"""Attention unit tests: blockwise == naive, ring-buffer cache semantics,
banded sliding-window path, cache build/update invariants + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    KVCache,
    blockwise_attention,
    build_cache_from_prefill,
    decode_attention,
    empty_cache,
    update_cache,
)
from repro.configs import get_reduced

KEY = jax.random.PRNGKey(3)


def _naive(q, k, v, causal, window, cap):
    from repro.kernels.flash_attention.ref import attention_ref
    return attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=causal, window=window,
                         softcap=cap).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("S,q_chunk,window,cap", [
    (64, 16, None, None), (100, 32, 24, None), (128, 128, None, 30.0),
    (257, 64, 32, None),
])
def test_blockwise_matches_naive(S, q_chunk, window, cap):
    B, H, Hkv, d = 2, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, Hkv, d))
    v = jax.random.normal(ks[2], (B, S, Hkv, d))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = blockwise_attention(q, k, v, q_positions=pos, k_positions=pos,
                              causal=True, window=window, attn_softcap=cap,
                              q_chunk=q_chunk)
    ref = _naive(q, k, v, True, window, cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@given(st.integers(1, 40), st.integers(4, 16))
@settings(max_examples=20, deadline=None)
def test_ring_cache_holds_last_C_positions(S, C):
    """After prefilling S tokens into capacity C, the cache holds exactly the last
    min(S, C) positions, each at slot p % C."""
    B, Hkv, hd = 1, 2, 4
    k = jnp.arange(S, dtype=jnp.float32)[None, :, None, None] * jnp.ones((B, S, Hkv, hd))
    cache = build_cache_from_prefill(k, k, C)          # cache layout (B, Hkv, C, hd)
    kp = np.asarray(cache.k_pos[0])
    want = set(range(max(0, S - C), S))
    got = set(int(p) for p in kp if p >= 0)
    assert got == want
    for slot, p in enumerate(kp):
        if p >= 0:
            assert p % C == slot                      # ring alignment invariant
            assert float(cache.k[0, 0, slot, 0]) == float(p)  # value matches position


@given(st.integers(1, 30), st.integers(4, 12), st.integers(1, 20))
@settings(max_examples=20, deadline=None)
def test_ring_cache_decode_updates(S, C, n_steps):
    """Continuing with single-token updates preserves the last-C invariant."""
    B, Hkv, hd = 1, 1, 2
    k = jnp.arange(S, dtype=jnp.float32)[None, :, None, None] * jnp.ones((B, S, Hkv, hd))
    cache = build_cache_from_prefill(k, k, C)
    for step in range(n_steps):
        p = S + step
        newk = jnp.full((B, 1, Hkv, hd), float(p))
        cache = update_cache(cache, newk, newk, jnp.full((B,), p, jnp.int32))
    kp = np.asarray(cache.k_pos[0])
    total = S + n_steps
    want = set(range(max(0, total - C), total))
    assert set(int(p) for p in kp if p >= 0) == want


def test_decode_attention_ignores_invalid_slots():
    B, H, Hkv, C, hd = 1, 2, 2, 8, 4
    cache = empty_cache(get_reduced("qwen3_1_7b"), "global", B, C, jnp.float32)
    # write two positions; leave rest empty
    k1 = jax.random.normal(KEY, (B, 1, Hkv, 16))[..., :hd] * 0 + 1.0
    cache = KVCache(jnp.zeros((B, Hkv, C, hd)), jnp.zeros((B, Hkv, C, hd)),
                    jnp.full((B, C), -1, jnp.int32))
    cache = update_cache(cache, jnp.ones((B, 1, Hkv, hd)),
                         jnp.ones((B, 1, Hkv, hd)) * 5.0, jnp.zeros((B,), jnp.int32))
    q = jnp.ones((B, 1, H, hd))
    out = decode_attention(q, cache, jnp.zeros((B,), jnp.int32), window=None,
                           attn_softcap=None)
    # only one valid slot with v=5 -> output must be exactly 5
    np.testing.assert_allclose(np.asarray(out), 5.0, atol=1e-5)


def test_banded_equals_unbanded_for_long_window_seq():
    """The banded (dynamic-slice) sliding-window path equals the full-mask path."""
    B, H, Hkv, d, S, W = 1, 2, 1, 8, 300, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, Hkv, d))
    v = jax.random.normal(ks[2], (B, S, Hkv, d))
    pos = jnp.arange(S, dtype=jnp.int32)
    banded = blockwise_attention(q, k, v, q_positions=pos, k_positions=pos,
                                 causal=True, window=W, attn_softcap=None,
                                 q_chunk=64)  # S > W + chunk -> banded path
    ref = _naive(q, k, v, True, W, None)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
