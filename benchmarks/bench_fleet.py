"""Fleet-scale simulation sweep: workers x pool-capacity x skew x sharing-degree.

Extends bench_sharing (single worker, Fig. 7) into the design space the paper's
fleet-level claims live in: per-method (WarmSwap / Prebaking / Baseline)
latency quartiles AND per-request tail percentiles (P50/P95/P99 per
invocation-rate quartile, from the event engine's latency samples), peak
resident memory, pool-miss/eviction/queueing behaviour, and the
pre-warm-policy comparison — all under identical image-affinity placement.

Also re-derives Fig. 7 as the degenerate point (1 worker, unlimited capacity,
one instance per function) and checks it against ``simulator.simulate()``,
including the ~88 % memory-saving headline at sharing degree 10, and runs a
capped-concurrency cell where queue delay is visible (P99 > mean).

Every cell's latency samples are validated: NaN or negative latencies fail the
run (the CI smoke job relies on this).

    PYTHONPATH=src python -m benchmarks.run --only fleet [--smoke]
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import emit, save_json, smoke_mode

METHODS = ("warmswap", "prebaking", "baseline")


def _validated_samples(r, label: str):
    """NaN / negative per-request latencies are impossible under a correct
    queueing model — fail loudly rather than report them."""
    import numpy as np

    s = np.asarray(r.latency_samples_s)
    if s.size and (not np.isfinite(s).all() or (s < 0).any()):
        raise RuntimeError(f"fleet/{label}: NaN or negative latency samples")
    if r.queue_delay_s < 0 or not np.isfinite(r.queue_delay_s):
        raise RuntimeError(f"fleet/{label}: invalid queue delay "
                           f"{r.queue_delay_s!r}")
    return s


def _cell(traces, cm, fleet, label: str) -> Dict:
    from repro.core.fleet import simulate_fleet
    from repro.core.simulator import quartile_latencies, quartile_percentiles

    out: Dict = {}
    for method in METHODS:
        r = simulate_fleet(traces, method, cm, fleet)
        _validated_samples(r, f"{label}/{method}")
        pct = r.latency_percentiles()
        out[method] = {
            "avg_latency_s": r.avg_latency_s,
            "latency_percentiles_s": pct,
            "quartile_latency_s": quartile_latencies(traces, r),
            "quartile_percentiles_s": quartile_percentiles(traces, r),
            "peak_memory_mb": r.memory_bytes / 1e6,
            "cold": r.n_cold, "warm": r.n_warm,
            "queued": r.n_queued, "queue_delay_s": r.queue_delay_s,
            "pool_misses": r.pool_misses, "evictions": r.evictions,
            "max_concurrent_instances": r.max_concurrent_instances,
            "instance_resident_min": r.instance_resident_min,
            "prewarm_dropped": r.prewarm_dropped,
        }
        emit(f"fleet/{label}/{method}", r.avg_latency_s * 1e6,
             f"p99={pct['p99'] * 1e3:.1f}ms mem={r.memory_bytes / 1e6:.0f}MB "
             f"cold={r.n_cold} queued={r.n_queued} "
             f"miss={r.pool_misses} evict={r.evictions}")
    return out


def run() -> Dict:
    from repro.core.fleet import FleetConfig, simulate_fleet
    from repro.core.keepalive import KeepAlivePolicy
    from repro.core.simulator import CostModel, memory_saving_fraction, simulate
    from repro.core.traces import (generate_fleet_traces, generate_traces,
                                   sharing_degrees)

    cm = CostModel.paper_table2()
    smoke = smoke_mode()
    out: Dict = {}

    # ------------------------------------------------------------- degenerate point
    # 1 worker, unlimited capacity, 1 instance/function == simulate() == Fig. 7.
    traces10 = generate_traces(10, horizon_min=(1 if smoke else 14) * 24 * 60,
                               seed=0)
    deg = FleetConfig(n_workers=1, max_instances_per_fn=1)
    degenerate: Dict = {}
    for method in METHODS:
        rf = simulate_fleet(traces10, method, cm, deg)
        rs = simulate(traces10, method, cm, KeepAlivePolicy(15.0))
        drift = abs(rf.total_latency_s - rs.total_latency_s)
        degenerate[method] = {
            "fleet_avg_latency_s": rf.avg_latency_s,
            "simulate_avg_latency_s": rs.avg_latency_s,
            "latency_drift_s": drift,
            "memory_match": rf.memory_bytes == rs.memory_bytes,
        }
        assert drift < 1e-6 and rf.memory_bytes == rs.memory_bytes, \
            f"degenerate fleet sim diverged from simulate() for {method}"
    saving = memory_saving_fraction(
        simulate_fleet(traces10, "warmswap", cm, deg),
        simulate_fleet(traces10, "prebaking", cm, deg))
    degenerate["memory_saving_vs_prebaking"] = saving
    emit("fleet/degenerate/headline", saving * 100,
         "memory_saving_pct at sharing degree 10 (paper: 88)")
    out["degenerate"] = degenerate

    # ------------------------------------------------------------------ the sweep
    n_fns = 12 if smoke else 40
    horizon = (1 if smoke else 7) * 24 * 60
    base = dict(n_functions=n_fns, horizon_min=horizon, seed=1, n_images=4,
                rate_model="zipf", total_rate_per_min=6.0)
    base_fleet = dict(worker_capacity_bytes=2 * cm.image_bytes)

    sweeps: Dict[str, List] = {
        "workers": [1, 4] if smoke else [1, 2, 4, 8],
        "capacity_images": [2] if smoke else [1, 2, 4, None],
        "sharing_images": [4] if smoke else [1, 2, 5, 10],
        "rate_skew": [1.1] if smoke else [0.6, 1.1, 1.6],
    }

    out["sweep"] = {}
    for w in sweeps["workers"]:
        traces = generate_fleet_traces(**base)
        out["sweep"][f"workers={w}"] = _cell(
            traces, cm, FleetConfig(n_workers=w, **base_fleet), f"workers={w}")
    for cap in sweeps["capacity_images"]:
        traces = generate_fleet_traces(**base)
        cfg = FleetConfig(n_workers=4, worker_capacity_bytes=(
            None if cap is None else cap * cm.image_bytes))
        out["sweep"][f"capacity={cap}"] = _cell(traces, cm, cfg,
                                                f"capacity={cap}")
    for n_img in sweeps["sharing_images"]:
        traces = generate_fleet_traces(**{**base, "n_images": n_img})
        cfg = FleetConfig(n_workers=4, **base_fleet)
        cell = _cell(traces, cm, cfg, f"images={n_img}")
        cell["sharing_degrees"] = sharing_degrees(traces)
        out["sweep"][f"images={n_img}"] = cell
    for s in sweeps["rate_skew"]:
        traces = generate_fleet_traces(**{**base, "rate_skew": s})
        out["sweep"][f"skew={s}"] = _cell(
            traces, cm, FleetConfig(n_workers=4, **base_fleet), f"skew={s}")

    # ------------------------------------------------------------ queueing cell
    # Capped concurrency under the same workload: queue delay becomes visible
    # and the tail separates from the mean (the arrival-ordered loop reported
    # impossible flat latencies here).
    traces = generate_fleet_traces(**base)
    out["queueing"] = {}
    for cap in (None, 2, 1):
        r = simulate_fleet(traces, "warmswap", cm,
                           FleetConfig(n_workers=2, max_instances_per_fn=cap,
                                       **base_fleet))
        s = _validated_samples(r, f"cap={cap}/warmswap")
        pct = r.latency_percentiles()
        out["queueing"][f"cap={cap}"] = {
            "avg_latency_s": r.avg_latency_s,
            "latency_percentiles_s": pct,
            "queued": r.n_queued, "queue_delay_s": r.queue_delay_s,
        }
        emit(f"fleet/cap={cap}/warmswap", r.avg_latency_s * 1e6,
             f"p99={pct['p99'] * 1e3:.1f}ms queued={r.n_queued} "
             f"queue_delay={r.queue_delay_s:.2f}s")
        assert s.size == 0 or pct["p99"] >= pct["p50"], "percentiles inverted"

    # --------------------------------------------------------- page-cost model
    # Cold starts priced by page transfer volume (core/costmodel.py) instead
    # of scalar constants, plus the cluster-shared image cache tier. Cells:
    #   * degenerate contract — infinite bandwidth reproduces the scalar
    #     engine exactly (also covered by tests/test_costmodel.py);
    #   * latency vs image size — HotSwap (shared image, half-resident,
    #     remote tier) must lie STRICTLY between warm and cold at every size,
    #     and the dependency-loading speedup at the paper's ~230 MB image
    #     lands inside the paper's 2.2-3.2x band;
    #   * cache footprint — HotSwap's shared tier holds one image per
    #     dependency vs Prebaking's snapshot per function (the 88 % story
    #     restated at the cluster-cache level);
    #   * a capacity-bounded shared cache showing remote hits and source
    #     misses under placement that is bandwidth/residency aware.
    from repro.core.costmodel import PageCostModel

    model = PageCostModel(cost=cm)
    deg_model = PageCostModel.degenerate(cm)
    page_out: Dict = {}
    for method in METHODS:
        rf = simulate_fleet(traces10, method, cm,
                            FleetConfig(n_workers=1, max_instances_per_fn=1,
                                        page_cost=deg_model))
        rs = simulate(traces10, method, cm, KeepAlivePolicy(15.0))
        assert (abs(rf.total_latency_s - rs.total_latency_s) < 1e-9
                and rf.memory_bytes == rs.memory_bytes), \
            f"degenerate page model diverged from simulate() for {method}"
    page_out["degenerate_equals_scalar"] = True

    sizes_mb = [64, 128, 230, 512] if smoke else [32, 64, 128, 230, 512, 1024]
    size_cell: Dict = {}
    for mb in sizes_mb:
        nbytes = mb << 20
        total = model.image_pages(nbytes)
        warm_s = cm.warm_s
        hotswap_s = model.cold_latency_s("warmswap", tier="remote",
                                         resident_pages=total // 2,
                                         image_bytes=nbytes)
        cold_s = model.cold_latency_s("baseline", image_bytes=nbytes)
        speedup = model.dependency_loading_speedup(tier="local",
                                                   image_bytes=nbytes)
        assert warm_s < hotswap_s < cold_s, \
            f"HotSwap latency not strictly between warm and cold at {mb} MB"
        size_cell[f"{mb}MB"] = {
            "pages": total, "warm_s": warm_s, "hotswap_s": hotswap_s,
            "cold_s": cold_s, "dependency_loading_speedup": speedup,
        }
        emit(f"fleet/page_model/image={mb}MB", hotswap_s * 1e6,
             f"warm={warm_s * 1e3:.1f}ms cold={cold_s * 1e3:.0f}ms "
             f"pages={total} dep_speedup={speedup:.2f}x")
    page_out["latency_vs_image_size"] = size_cell
    paper_speedup = size_cell["230MB"]["dependency_loading_speedup"]
    assert 2.2 <= paper_speedup <= 3.2, \
        f"dependency-loading speedup {paper_speedup:.2f}x outside the " \
        f"paper's 2.2-3.2x band at the ~230 MB paper-scale image"
    page_out["dependency_loading_speedup_paper_scale"] = paper_speedup
    emit("fleet/page_model/dep_speedup_paper_scale", paper_speedup,
         "baseline/warmswap dependency-loading ratio (paper band: 2.2-3.2x)")

    rw = simulate_fleet(traces, "warmswap", cm,
                        FleetConfig(n_workers=4, page_cost=model))
    rp = simulate_fleet(traces, "prebaking", cm,
                        FleetConfig(n_workers=4, page_cost=model))
    _validated_samples(rw, "page_model/warmswap")
    _validated_samples(rp, "page_model/prebaking")
    assert rp.shared_cache_peak_bytes > rw.shared_cache_peak_bytes > 0
    footprint_saving = 1.0 - rw.shared_cache_peak_bytes / rp.shared_cache_peak_bytes
    # the same comparison on the HEADLINE workload (10 fns, ONE image): the
    # shared tier holds 1 image vs 10 snapshots -> 90 % (the 88 % headline
    # counts warmswap's per-fn metadata too; the tier holds images only)
    deg_page = FleetConfig(n_workers=1, max_instances_per_fn=1, page_cost=model)
    rwh = simulate_fleet(traces10, "warmswap", cm, deg_page)
    rph = simulate_fleet(traces10, "prebaking", cm, deg_page)
    headline_saving = 1.0 - (rwh.shared_cache_peak_bytes
                             / rph.shared_cache_peak_bytes)
    assert headline_saving > 0.85
    page_out["cache_footprint"] = {
        "headline_workload_saving_fraction": headline_saving,
        "hotswap_shared_peak_mb": rw.shared_cache_peak_bytes / 1e6,
        "prebaking_shared_peak_mb": rp.shared_cache_peak_bytes / 1e6,
        "hotswap_peak_memory_mb": rw.memory_bytes / 1e6,
        "prebaking_peak_memory_mb": rp.memory_bytes / 1e6,
        "saving_fraction": footprint_saving,
        "hotswap_tiers": {"local": rw.cache_local_hits,
                          "remote": rw.cache_remote_hits,
                          "miss": rw.cache_misses},
        "hotswap_pages_transferred": rw.pages_transferred,
    }
    emit("fleet/page_model/cache_footprint", footprint_saving * 100,
         f"shared-tier saving % (hotswap {rw.shared_cache_peak_bytes >> 20}MB "
         f"vs prebaking {rp.shared_cache_peak_bytes >> 20}MB)")

    rb = simulate_fleet(traces, "warmswap", cm,
                        FleetConfig(n_workers=4, placement="round_robin",
                                    page_cost=model,
                                    worker_capacity_bytes=cm.image_bytes,
                                    shared_cache_bytes=2 * cm.image_bytes))
    _validated_samples(rb, "page_model/bounded_cache")
    page_out["bounded_shared_cache"] = {
        "avg_latency_s": rb.avg_latency_s,
        "tiers": {"local": rb.cache_local_hits, "remote": rb.cache_remote_hits,
                  "miss": rb.cache_misses},
        "cluster_evictions": rb.shared_cache_evictions,
        "pages_transferred": rb.pages_transferred,
    }
    emit("fleet/page_model/bounded_cache", rb.avg_latency_s * 1e6,
         f"local={rb.cache_local_hits} remote={rb.cache_remote_hits} "
         f"miss={rb.cache_misses} evict={rb.shared_cache_evictions}")
    out["page_model"] = page_out

    # ------------------------------------------------------- placement + pre-warm
    out["placement"] = {}
    for placement in ("affinity", "least_loaded", "round_robin"):
        cfg = FleetConfig(n_workers=4, placement=placement, **base_fleet)
        out["placement"][placement] = _cell(traces, cm, cfg,
                                            f"placement={placement}")
    out["prewarm"] = {}
    for pw in ("none", "histogram", "spes"):
        r = simulate_fleet(traces, "warmswap", cm,
                           FleetConfig(n_workers=4, prewarm=pw, **base_fleet))
        _validated_samples(r, f"prewarm={pw}/warmswap")
        out["prewarm"][pw] = {
            "avg_latency_s": r.avg_latency_s, "cold": r.n_cold,
            "latency_percentiles_s": r.latency_percentiles(),
            "prewarm_spawns": r.prewarm_spawns, "prewarm_hits": r.prewarm_hits,
            "prewarm_dropped": r.prewarm_dropped,
            "instance_resident_min": r.instance_resident_min,
        }
        emit(f"fleet/prewarm={pw}/warmswap", r.avg_latency_s * 1e6,
             f"cold={r.n_cold} resident_min={r.instance_resident_min:.0f} "
             f"dropped={r.prewarm_dropped}")

    save_json("bench_fleet", out)
    return out


if __name__ == "__main__":
    run()
