"""float-determinism checker: order-sensitive reductions that can break the
bit-identity contract between the fleet engines (docs/ANALYSIS.md)."""
import textwrap

from tools.analysis import float_determinism
from tools.analysis.base import SourceFile


def _check(tmp_path, code, rel="src/repro/core/_fixture.py"):
    p = tmp_path / "fixture.py"
    p.write_text(textwrap.dedent(code))
    src = SourceFile.parse(str(p))
    src.rel = rel
    return float_determinism.check(src)


def rules(findings):
    return [f.rule for f in findings]


def test_np_sort_without_kind_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        import numpy as np
        out = np.sort(values)
    """)
    assert rules(fs) == ["unstable-sort"]


def test_np_argsort_without_kind_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        import numpy as np
        order = np.argsort(keys)
    """)
    assert rules(fs) == ["unstable-sort"]


def test_stable_kind_is_clean(tmp_path):
    fs = _check(tmp_path, """
        import numpy as np
        a = np.sort(values, kind="stable")
        b = np.argsort(keys, kind="mergesort")
    """)
    assert fs == []


def test_numpy_alias_is_tracked(tmp_path):
    fs = _check(tmp_path, """
        import numpy as xp
        out = xp.sort(values)
    """)
    assert rules(fs) == ["unstable-sort"]


def test_non_numpy_sort_is_ignored(tmp_path):
    fs = _check(tmp_path, """
        import mylib
        out = mylib.sort(values)
    """)
    assert fs == []


def test_sum_over_set_literal_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        total = sum({1.0, 2.0, 3.0})
    """)
    assert rules(fs) == ["set-reduction"]


def test_sum_over_generator_from_set_var_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        def f(items):
            pending = set(items)
            return sum(x * 2.0 for x in pending)
    """)
    assert rules(fs) == ["set-reduction"]


def test_fsum_over_set_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        import math
        total = math.fsum({0.1, 0.2})
    """)
    assert rules(fs) == ["set-reduction"]


def test_sum_over_list_is_clean(tmp_path):
    fs = _check(tmp_path, """
        def f(items):
            vals = [x.cost for x in items]
            return sum(vals) + sum(x * 2.0 for x in vals)
    """)
    assert fs == []


def test_keyed_extremum_over_set_is_flagged(tmp_path):
    fs = _check(tmp_path, """
        def pick(candidates):
            live = set(candidates)
            return min(live, key=lambda w: w.load)
    """)
    assert rules(fs) == ["keyed-extremum-over-set"]


def test_keyed_extremum_over_list_is_clean(tmp_path):
    fs = _check(tmp_path, """
        def pick(candidates):
            return min(candidates, key=lambda w: w.load)
    """)
    assert fs == []


def test_out_of_scope_file_is_skipped(tmp_path):
    fs = _check(tmp_path, """
        import numpy as np
        out = np.sort(values)
    """, rel="examples/demo.py")
    assert fs == []


def test_pragma_suppresses(tmp_path):
    fs = _check(tmp_path, """
        import numpy as np
        out = np.sort(values)  # repro-lint: allow[unstable-sort]
    """)
    assert fs == []
