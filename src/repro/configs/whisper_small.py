"""whisper-small [audio] — encoder-decoder, conv frontend (STUB).

12L (decoder; +12 encoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
[arXiv:2212.04356; unverified]. Per the assignment the conv audio frontend is a stub:
``input_specs()`` supplies precomputed (batch, 1500, d_model) frame embeddings.
"""
from repro.models.config import ArchConfig, GLOBAL_ATTN

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    attn_pattern=(GLOBAL_ATTN,),
    mlp="gelu",
    is_encoder_decoder=True,
    n_enc_layers=12,
    n_enc_positions=1500,
    frontend="audio_frames",
    tie_embeddings=True,
    rope_theta=0.0,     # whisper uses learned/sinusoidal positions, not RoPE
)
