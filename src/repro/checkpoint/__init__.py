from repro.checkpoint.checkpointer import (
    CheckpointConfig,
    Checkpointer,
    latest_step,
)

__all__ = ["CheckpointConfig", "Checkpointer", "latest_step"]
