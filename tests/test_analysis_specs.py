"""repro-lint spec/registry cross-validator: a stale scenario fixture (renamed
component, extra kwarg, missing required arg) is caught without running a
simulation, and every checked-in benchmarks/scenarios spec stays clean."""
import glob
import json
import os

from tools.analysis import specs
from tools.analysis.base import REPO_ROOT


def valid_spec():
    return {
        "name": "fixture",
        "schema_version": 1,
        "engine": "fleet",
        "methods": ["warmswap"],
        "traces": {"name": "fleet",
                   "kwargs": {"n_functions": 4, "horizon_min": 60.0,
                              "seed": 0}},
        "cost": {"name": "paper_table2", "kwargs": {}},
        "prewarm": {"name": "none", "kwargs": {}},
        "placement": {"name": "affinity", "kwargs": {}},
    }


def rules(findings):
    return sorted(f.rule for f in findings)


def test_valid_spec_clean():
    assert specs.check_spec(valid_spec(), "x.json") == []


def test_renamed_component_unknown_with_did_you_mean():
    spec = valid_spec()
    spec["traces"]["name"] = "fleet_traces"      # renamed out from under us
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["unknown-component"]
    assert "'fleet'" in found[0].message         # did-you-mean
    assert found[0].scope == "traces.fleet_traces"


def test_extra_kwarg_unknown_with_did_you_mean():
    spec = valid_spec()
    spec["prewarm"] = {"name": "none",
                       "kwargs": {"keep_alive_mins": 15.0}}   # typo'd kwarg
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["unknown-kwarg"]
    assert "keep_alive_min" in found[0].message  # did-you-mean

def test_missing_required_arg():
    spec = valid_spec()
    del spec["traces"]["kwargs"]["n_functions"]
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["missing-required-arg"]
    assert "'n_functions'" in found[0].message


def test_runtime_injected_kwargs_not_required():
    # page_cost factories take the resolved CostModel as 'cost' — injected by
    # run(), so the spec must NOT be asked to provide it
    spec = valid_spec()
    spec["page_cost"] = {"name": "degenerate", "kwargs": {}}
    assert specs.check_spec(spec, "x.json") == []


def test_malformed_component_shape_invalid_spec():
    spec = valid_spec()
    spec["cost"] = {"nm": "paper_table2"}
    found = specs.check_spec(spec, "x.json")
    assert rules(found) == ["invalid-spec"]


def test_string_component_form_accepted():
    spec = valid_spec()
    spec["cost"] = "paper_table2"
    assert specs.check_spec(spec, "x.json") == []


def test_non_scenario_json_passes_through(tmp_path):
    p = tmp_path / "artifact.json"
    p.write_text(json.dumps({"headline": {"speedup": 2.7}}))
    assert specs.check_file(str(p)) == []


def test_unreadable_json_invalid_spec(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    assert rules(specs.check_file(str(p))) == ["invalid-spec"]


def test_stale_spec_fixture_file_roundtrip(tmp_path):
    """One file carrying all three rot shapes at once (the checker keeps
    going past the first bad component)."""
    spec = valid_spec()
    spec["traces"]["name"] = "fleet_traces"
    spec["prewarm"] = {"name": "none", "kwargs": {"keep_alive_mins": 1.0}}
    spec["placement"] = {"name": "affinty", "kwargs": {}}
    p = tmp_path / "stale.json"
    p.write_text(json.dumps(spec))
    found = specs.check_file(str(p))
    assert rules(found) == ["unknown-component", "unknown-component",
                            "unknown-kwarg"]


def test_all_checked_in_scenarios_clean():
    paths = sorted(glob.glob(
        os.path.join(REPO_ROOT, "benchmarks", "scenarios", "*.json")))
    assert paths, "no checked-in scenario specs found"
    for p in paths:
        assert specs.check_file(p) == [], p
