#!/usr/bin/env python
"""Bench trend gate: the fresh ``BENCH_smoke.json`` vs the checked-in one.

``check_bench.py`` holds the *absolute* paper bands; this gate holds the
*trajectory* — each PR's smoke bench is compared against the artifact the
repo shipped with, so a slow drift that never leaves a band still fails the
moment it regresses a wall clock by more than the threshold:

  * per-bench wall clocks (``cells.*.wall_clock_s``) and headline wall
    clocks (``*_wall_clock_s``): fail when
    ``new > prev * 1.25 + 2.0`` (25 % relative + 2 s absolute slack, so
    sub-second cells don't flap on runner noise);
  * deterministic headline metrics (savings, speedups, invocation counts):
    the engines are deterministic functions of the specs, so any drift
    beyond 1e-6 relative means the *simulation* changed, not the hardware —
    that is a correctness failure, not noise;
  * ``oracle_gap.n_cells`` must not shrink: dominance coverage only grows;
  * cells/metrics added or removed are reported in the table, never failed
    (new benches land with their first baseline).

A markdown trend table goes to stdout and, when ``$GITHUB_STEP_SUMMARY`` is
set, to the job summary. CI snapshots the checked-in artifact *before* the
bench overwrites it:

    cp results/BENCH_smoke.json /tmp/BENCH_prev.json
    PYTHONPATH=src python -m benchmarks.run --smoke ...
    python tools/ci/check_trend.py results/BENCH_smoke.json /tmp/BENCH_prev.json
"""
import json
import math
import os
import sys

WALL_REGRESSION_RATIO = 1.25     # >25 % wall-clock regression fails
WALL_ABS_SLACK_S = 2.0           # plus 2 s absolute slack (runner noise)
DETERMINISTIC_REL_TOL = 1e-6     # deterministic metrics must not drift

#: Headline keys that are deterministic functions of the checked-in specs.
DETERMINISTIC_KEYS = (
    "memory_saving_vs_prebaking",
    "sharing_memory_saving_vs_prebaking",
    "dependency_loading_speedup",
    "azure_scale_n_invocations",
    "azure_scale_xl_n_invocations",
    "stream_ingest_n_invocations",
)


def _load(path):
    data = json.load(open(path))
    assert data.get("bench_schema_version") == 1, \
        f"unknown bench schema in {path}"
    return data


def _wall_clocks(data):
    """name -> wall-clock seconds, cells and headline keys merged."""
    out = {}
    for name, cell in data.get("cells", {}).items():
        w = cell.get("wall_clock_s")
        if isinstance(w, (int, float)) and math.isfinite(w):
            out[f"cells.{name}"] = float(w)
    for key, v in data.get("headline", {}).items():
        if key.endswith("_wall_clock_s") and isinstance(v, (int, float)) \
                and math.isfinite(v):
            out[f"headline.{key}"] = float(v)
    return out


def _drifted(new, prev):
    denom = max(abs(prev), abs(new), 1e-12)
    return abs(new - prev) / denom > DETERMINISTIC_REL_TOL


def _emit(table_lines):
    text = "\n".join(table_lines) + "\n"
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)


def main(new_path="results/BENCH_smoke.json", prev_path=None):
    new = _load(new_path)
    if prev_path is None or not os.path.exists(prev_path):
        print(f"no previous artifact at {prev_path!r}: trend gate passes "
              f"vacuously (seeding the trajectory)")
        return 0
    prev = _load(prev_path)

    rows = ["## Bench trend", "",
            "| metric | previous | current | Δ | verdict |",
            "|---|---|---|---|---|"]
    failures = []

    new_walls, prev_walls = _wall_clocks(new), _wall_clocks(prev)
    for name in sorted(set(new_walls) | set(prev_walls)):
        if name not in new_walls:
            rows.append(f"| {name} | {prev_walls[name]:.2f}s | — | — | "
                        f"removed |")
            continue
        if name not in prev_walls:
            rows.append(f"| {name} | — | {new_walls[name]:.2f}s | — | "
                        f"new baseline |")
            continue
        p, n = prev_walls[name], new_walls[name]
        budget = p * WALL_REGRESSION_RATIO + WALL_ABS_SLACK_S
        delta = (n - p) / p if p else math.inf
        ok = n <= budget
        rows.append(f"| {name} | {p:.2f}s | {n:.2f}s | {delta:+.1%} | "
                    f"{'ok' if ok else '**FAIL**'} |")
        if not ok:
            failures.append(
                f"wall-clock regression: {name} took {n:.2f}s vs previous "
                f"{p:.2f}s (budget {budget:.2f}s = prev x "
                f"{WALL_REGRESSION_RATIO} + {WALL_ABS_SLACK_S}s)")

    new_head, prev_head = new.get("headline", {}), prev.get("headline", {})
    for key in DETERMINISTIC_KEYS:
        if key not in prev_head:
            if key in new_head:
                rows.append(f"| headline.{key} | — | {new_head[key]} | — | "
                            f"new baseline |")
            continue
        if key not in new_head:
            failures.append(
                f"headline metric disappeared: {key!r} was in the previous "
                f"artifact but the fresh bench did not produce it")
            rows.append(f"| headline.{key} | {prev_head[key]} | — | — | "
                        f"**FAIL** (missing) |")
            continue
        p, n = float(prev_head[key]), float(new_head[key])
        ok = not _drifted(n, p)
        rows.append(f"| headline.{key} | {p:g} | {n:g} | "
                    f"{n - p:+g} | {'ok' if ok else '**FAIL**'} |")
        if not ok:
            failures.append(
                f"deterministic headline drift: {key} = {n!r} vs previous "
                f"{p!r} — the engines are deterministic functions of the "
                f"specs, so this is a simulation change, not noise")

    p_cells = (prev_head.get("oracle_gap") or {}).get("n_cells")
    n_cells = (new_head.get("oracle_gap") or {}).get("n_cells")
    if p_cells is not None and n_cells is not None:
        ok = n_cells >= p_cells
        rows.append(f"| oracle_gap.n_cells | {p_cells} | {n_cells} | "
                    f"{n_cells - p_cells:+d} | {'ok' if ok else '**FAIL**'} |")
        if not ok:
            failures.append(
                f"oracle dominance coverage shrank: {n_cells} audited "
                f"cell(s) vs previous {p_cells}")

    _emit(rows)
    assert not failures, "bench trend gate failed:\n  " + \
        "\n  ".join(failures)
    print(f"ok: {len(new_walls)} wall clock(s) within "
          f"prev x {WALL_REGRESSION_RATIO} + {WALL_ABS_SLACK_S}s, "
          f"deterministic headline metrics unchanged")
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
