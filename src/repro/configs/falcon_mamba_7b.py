"""falcon-mamba-7b [ssm] — Mamba-1, attention-free.

64L d_model=4096 d_ff=0 vocab=65024, ssm_state=16, expand=2 (d_inner=8192),
d_conv=4, dt_rank=256. [arXiv:2410.05355; unverified].
"""
from repro.models.config import ArchConfig, SSM

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,             # mamba block subsumes the MLP
    vocab_size=65_024,
    attn_pattern=(SSM,),
    ssm_state=16,
    d_conv=4,
    expand=2,
    mlp="swiglu",       # unused
    tie_embeddings=False,
)
