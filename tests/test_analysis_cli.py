"""repro-lint CLI: baseline diff workflow (grandfathered vs new), the JSON
artifact, exit codes, and fingerprint stability under line churn."""
import json
import textwrap

from tools.analysis import diff_baseline, load_baseline
from tools.analysis.__main__ import main
from tools.analysis.findings import Finding

VIOLATION = textwrap.dedent("""
    import threading

    class Pool:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = {}          # guarded-by: _lock

        def peek(self, key):
            return self.items.get(key)
""")


def test_clean_file_exits_zero(tmp_path, capsys):
    p = tmp_path / "clean.py"
    p.write_text("x = 1\n")
    assert main([str(p), "--no-baseline"]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_new_finding_exits_one_and_renders(tmp_path, capsys):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION)
    assert main([str(p), "--no-baseline", "--fix-suggestions"]) == 1
    out = capsys.readouterr().out
    assert "lock-discipline/unguarded-access" in out
    assert "fix:" in out


def test_baseline_grandfathers_then_new_copy_fails(tmp_path, capsys):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION)
    bl = tmp_path / "baseline.json"

    assert main([str(p), "--baseline", str(bl), "--write-baseline"]) == 0
    assert len(load_baseline(str(bl))) == 1

    # grandfathered: same violation passes against the baseline
    assert main([str(p), "--baseline", str(bl)]) == 0

    # a second violation appearing next to the grandfathered one is new
    # (count-limited duplicates are covered in test_diff_baseline_count_limited)
    p.write_text(VIOLATION + textwrap.dedent("""
    class Pool2:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = {}          # guarded-by: _lock

        def peek(self, key):
            return self.items.get(key)
"""))
    capsys.readouterr()
    assert main([str(p), "--baseline", str(bl)]) == 1
    assert "1 baselined, 1 new" in capsys.readouterr().out


def test_missing_baseline_file_is_empty(tmp_path):
    assert load_baseline(str(tmp_path / "absent.json")) == {}


def test_json_artifact_shape(tmp_path):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION)
    out = tmp_path / "findings.json"
    assert main([str(p), "--no-baseline", "--json", str(out)]) == 1
    data = json.loads(out.read_text())
    assert data["analysis_schema_version"] == 1
    assert data["n_findings"] == data["n_new"] == 1
    assert data["n_baselined"] == 0
    f = data["findings"][0]
    assert f["rule"] == "unguarded-access"
    assert f["fingerprint"] in data["new"]


def test_unknown_checker_exits_two(tmp_path):
    p = tmp_path / "clean.py"
    p.write_text("x = 1\n")
    assert main([str(p), "--no-baseline", "--checkers", "bogus"]) == 2


def test_checker_subset_runs_only_selected(tmp_path):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION)
    assert main([str(p), "--no-baseline",
                 "--checkers", "shared-state"]) == 0


def test_syntax_error_is_a_finding(tmp_path, capsys):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    assert main([str(p), "--no-baseline"]) == 1
    assert "parse/syntax-error" in capsys.readouterr().out


def test_fingerprint_survives_line_churn():
    a = Finding("c", "r", "p.py", 10, 0, "m", scope="Pool.peek",
                snippet="return self.items.get(key)")
    b = Finding("c", "r", "p.py", 99, 4, "m", scope="Pool.peek",
                snippet="  return   self.items.get(key)")
    moved = Finding("c", "r", "p.py", 10, 0, "m", scope="Pool.other",
                    snippet="return self.items.get(key)")
    assert a.fingerprint == b.fingerprint      # line/col/whitespace-free
    assert a.fingerprint != moved.fingerprint  # scope is part of identity


def test_diff_baseline_count_limited():
    f = Finding("c", "r", "p.py", 1, 0, "m", snippet="s")
    g = Finding("c", "r", "p.py", 2, 0, "m", snippet="s")  # same fingerprint
    new, old = diff_baseline([f, g], {f.fingerprint: 1})
    assert [x.line for x in old] == [1]
    assert [x.line for x in new] == [2]


# ------------------------------------------------------------- stale pragmas

def test_stale_pragma_is_a_finding(tmp_path, capsys):
    p = tmp_path / "mod.py"
    p.write_text("x = 1  # repro-lint: allow[unguarded-access]\n")
    assert main([str(p), "--no-baseline"]) == 1
    assert "pragma/stale-pragma" in capsys.readouterr().out


def test_used_pragma_is_not_stale(tmp_path):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION.replace(
        "return self.items.get(key)",
        "return self.items.get(key)  "
        "# repro-lint: allow[unguarded-access]"))
    assert main([str(p), "--no-baseline"]) == 0


def test_stale_file_pragma_is_a_finding(tmp_path, capsys):
    p = tmp_path / "mod.py"
    p.write_text("# repro-lint: allow-file[unguarded-access]\nx = 1\n")
    assert main([str(p), "--no-baseline"]) == 1
    assert "pragma/stale-pragma" in capsys.readouterr().out


def test_pragma_inside_string_literal_is_ignored(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text('s = "# repro-lint: allow[unguarded-access]"\n')
    assert main([str(p), "--no-baseline"]) == 0


def test_subset_run_skips_stale_pragma_detection(tmp_path):
    # "unused" is meaningless unless every AST checker ran over the file
    p = tmp_path / "mod.py"
    p.write_text("x = 1  # repro-lint: allow[unguarded-access]\n")
    assert main([str(p), "--no-baseline",
                 "--checkers", "lock-discipline"]) == 0


# ----------------------------------------------------- stale baseline entries

def test_stale_baseline_entry_is_a_finding(tmp_path, capsys):
    p = tmp_path / "pool.py"
    p.write_text(VIOLATION)
    bl = tmp_path / "baseline.json"
    assert main([str(p), "--baseline", str(bl), "--write-baseline"]) == 0

    # the grandfathered violation gets fixed: its entry is now stale
    p.write_text("x = 1\n")
    capsys.readouterr()
    assert main([str(p), "--baseline", str(bl)]) == 1
    assert "baseline/stale-entry" in capsys.readouterr().out


def test_stale_baseline_skipped_when_path_not_scanned(tmp_path):
    a = tmp_path / "pool.py"
    a.write_text(VIOLATION)
    bl = tmp_path / "baseline.json"
    assert main([str(a), "--baseline", str(bl), "--write-baseline"]) == 0

    # scanning an unrelated file says nothing about pool.py's entry
    b = tmp_path / "other.py"
    b.write_text("x = 1\n")
    assert main([str(b), "--baseline", str(bl)]) == 0
