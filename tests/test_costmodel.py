"""Page-granular cost model (core/costmodel.py) + cluster-shared image cache:
edge cases, the degenerate scalar-equivalence contract (incl. the 88 %
headline), tier ordering properties, fetch-once semantics, bandwidth-aware
placement, and byte-aware keep-alive."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import PageCostModel
from repro.core.fleet import FleetConfig, simulate_fleet
from repro.core.keepalive import BytesAwareKeepAlive, KeepAlivePolicy
from repro.core.migration import LinkModel
from repro.core.pool import ClusterImageCache
from repro.core.simulator import (CostModel, memory_saving_fraction,
                                  method_cold_latency_s, simulate)
from repro.core.traces import Trace, generate_fleet_traces, generate_traces
from repro.serving.scheduler import place_invocation

CM = CostModel.paper_table2()
MODEL = PageCostModel(cost=CM)
DEG = PageCostModel.degenerate(CM)


def _trace(fn, arrivals, image=0):
    arr = np.asarray(arrivals, np.float64)
    rate = len(arr) / max(float(arr[-1]) if len(arr) else 1.0, 1.0)
    return Trace(fn, rate, arr, image_id=image)


# ---------------------------------------------------------------------------------
# Cost-model edge cases
# ---------------------------------------------------------------------------------

def test_zero_resident_pages_is_pure_cold():
    """Nothing resident: the full image moves, and the latency decomposes as
    scalar base + blocking transfer of every page."""
    total = MODEL.image_pages()
    lat = MODEL.cold_latency_s("warmswap", tier="remote", resident_pages=0)
    base = method_cold_latency_s(CM, "warmswap")
    assert lat == pytest.approx(base + MODEL.blocking_s(total, MODEL.remote))
    assert MODEL.blocking_s(total, MODEL.remote) > 0


def test_fully_resident_image_is_pure_warm_transfer():
    """Every page already resident: the transfer term vanishes exactly and
    only the scalar base remains — on every tier, even the slow ones."""
    total = MODEL.image_pages()
    base = method_cold_latency_s(CM, "warmswap")
    for tier in ("local", "remote", "miss"):
        assert MODEL.cold_latency_s("warmswap", tier=tier,
                                    resident_pages=total) == base
        assert MODEL.transfer_blocking_s(tier, resident_pages=total) == 0.0
    # over-reporting residency never goes negative
    assert MODEL.cold_latency_s("warmswap", resident_pages=10 * total) == base


def test_degenerate_model_equals_scalar_costs_all_methods():
    """Infinite bandwidth + zero per-request latency: the page model IS the
    scalar model, for every method, tier, and residency."""
    for method in ("warmswap", "prebaking", "baseline"):
        scalar = method_cold_latency_s(CM, method)
        for tier in ("local", "remote", "miss"):
            for resident in (0, 7, 10_000):
                assert DEG.cold_latency_s(method, tier=tier,
                                          resident_pages=resident) == scalar


def test_remote_vs_local_latency_ordering():
    """A remote shared-cache hit costs at least a local pool hit and at most
    a source miss — strictly, whenever pages actually move over finite
    bandwidth."""
    for resident in (0, MODEL.image_pages() // 2):
        local = MODEL.cold_latency_s("warmswap", "local", resident)
        remote = MODEL.cold_latency_s("warmswap", "remote", resident)
        miss = MODEL.cold_latency_s("warmswap", "miss", resident)
        assert local < remote < miss
    # ...and degenerately the ordering collapses to equality
    assert (DEG.cold_latency_s("warmswap", "local")
            == DEG.cold_latency_s("warmswap", "remote")
            == DEG.cold_latency_s("warmswap", "miss"))


@given(st.integers(1, 4000), st.integers(0, 4000))
@settings(max_examples=50, deadline=None)
def test_latency_monotone_in_residency_and_size(pages, resident):
    """More resident pages never cost more; bigger images never cost less."""
    nbytes = pages * MODEL.page_size
    lat = MODEL.cold_latency_s("warmswap", "remote", resident, nbytes)
    lat_more = MODEL.cold_latency_s("warmswap", "remote", resident + 1, nbytes)
    lat_bigger = MODEL.cold_latency_s("warmswap", "remote", resident,
                                      nbytes + MODEL.page_size)
    assert lat_more <= lat + 1e-12
    assert lat_bigger >= lat - 1e-12
    assert lat >= method_cold_latency_s(CM, "warmswap") - 1e-12


def test_hotswap_between_warm_and_cold_across_sizes():
    """The bench cell's invariant: a shared, half-resident image restored over
    the network lies strictly between a warm start and a full cold start."""
    for mb in (16, 64, 230, 1024):
        nbytes = mb << 20
        half = MODEL.image_pages(nbytes) // 2
        hot = MODEL.cold_latency_s("warmswap", "remote", half, nbytes)
        cold = MODEL.cold_latency_s("baseline", image_bytes=nbytes)
        assert CM.warm_s < hot < cold


def test_dependency_loading_speedup_in_paper_band_at_paper_scale():
    assert 2.2 <= MODEL.dependency_loading_speedup() <= 3.2


def test_invalid_arguments_raise():
    with pytest.raises(ValueError):
        MODEL.cold_latency_s("warmswap", tier="nearby")
    with pytest.raises(ValueError):
        MODEL.cold_latency_s("snapshotting")
    with pytest.raises(ValueError):
        PageCostModel(cost=CM, fault_fraction=1.5)
    with pytest.raises(ValueError):
        PageCostModel(cost=CM, stream_overlap=-0.1)


# ---------------------------------------------------------------------------------
# Degenerate equivalence with the scalar engine (acceptance criterion)
# ---------------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["warmswap", "prebaking", "baseline"])
def test_degenerate_fleet_reproduces_scalar_simulate_exactly(method):
    """Degenerate page model + unlimited shared cache, in the degenerate fleet
    config, reproduces the pre-page-model simulate() numbers exactly."""
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1, page_cost=DEG)
    rf = simulate_fleet(traces, method, CM, cfg)
    rs = simulate(traces, method, CM, KeepAlivePolicy(15.0))
    assert (rf.n_cold, rf.n_warm) == (rs.n_cold, rs.n_warm)
    assert rf.total_latency_s == pytest.approx(rs.total_latency_s, abs=1e-6)
    assert rf.memory_bytes == rs.memory_bytes
    # page-aware simulate() agrees too
    rs_p = simulate(traces, method, CM, KeepAlivePolicy(15.0), page_cost=DEG)
    assert rs_p.total_latency_s == rs.total_latency_s


def test_degenerate_page_model_preserves_88pct_headline():
    traces = generate_traces(10, horizon_min=14 * 24 * 60, seed=0)
    cfg = FleetConfig(n_workers=1, max_instances_per_fn=1, page_cost=DEG)
    rw = simulate_fleet(traces, "warmswap", CM, cfg)
    rp = simulate_fleet(traces, "prebaking", CM, cfg)
    assert 0.85 < memory_saving_fraction(rw, rp) < 0.92


def test_shared_cache_bytes_requires_page_cost():
    with pytest.raises(ValueError):
        simulate_fleet([_trace(0, [1.0])], "warmswap", CM,
                       FleetConfig(shared_cache_bytes=1 << 30))


# ---------------------------------------------------------------------------------
# Cluster-shared image cache
# ---------------------------------------------------------------------------------

def test_cluster_cache_tiers_and_fetch_once():
    cache = ClusterImageCache()
    assert cache.lookup("img:0", 0) == "miss"          # nobody has it yet
    cache.admit("img:0", 100, worker=0, now=1.0)
    assert cache.lookup("img:0", 0) == "local"
    assert cache.lookup("img:0", 1) == "remote"        # peer fetch, not source
    cache.admit("img:0", 100, worker=1, now=2.0)
    assert cache.lookup("img:0", 1) == "local"
    assert cache.used_bytes() == 100                   # distinct images, once
    assert cache.misses == 1 and cache.remote_hits == 1
    # classify is a pure read: counters must not move
    before = (cache.local_hits, cache.remote_hits, cache.misses)
    assert cache.classify("img:0", 1) == "local"
    assert cache.classify("img:none", 0) == "miss"
    assert (cache.local_hits, cache.remote_hits, cache.misses) == before


def test_fleet_keeps_cluster_counters_truthful():
    """The engine classifies tiers itself (worker ledger first) but must keep
    the ClusterImageCache counters in agreement with FleetResult, via
    ClusterImageCache.count — summary() must never contradict the result."""
    cache = ClusterImageCache()
    for tier in ("local", "remote", "miss", "miss"):
        cache.count(tier)
    s = cache.summary()
    assert (s["local_hits"], s["remote_hits"], s["misses"]) == (1, 1, 2)


def test_cluster_cache_last_holder_eviction_drops_image():
    cache = ClusterImageCache()
    cache.admit("img:0", 100, worker=0, now=1.0)
    cache.admit("img:0", 100, worker=1, now=2.0)
    cache.worker_evicted(0, "img:0")
    assert cache.holds("img:0")                        # worker 1 still has it
    cache.worker_evicted(1, "img:0")
    assert not cache.holds("img:0") and cache.used_bytes() == 0
    assert cache.evictions == 0                        # not a capacity eviction


def test_oversized_image_exceeding_shared_cache_is_rejected():
    """An image bigger than the whole shared tier can never be resident in
    it: admits are rejected, every non-local lookup stays a source miss, and
    smaller images are unaffected."""
    cache = ClusterImageCache(capacity_bytes=100)
    cache.admit("img:big", 150, worker=0, now=1.0)
    assert not cache.holds("img:big") and cache.rejected == 1
    assert cache.lookup("img:big", 1) == "miss"
    cache.admit("img:small", 60, worker=0, now=2.0)
    assert cache.holds("img:small")
    # fleet-level: a shared tier smaller than one image -> no remote hits
    # ever; every cross-worker cold start pays the source fetch
    traces = [_trace(i, [10.0 * (i + 1), 500.0 + 10.0 * i], image=0)
              for i in range(4)]
    r = simulate_fleet(traces, "warmswap", CM,
                       FleetConfig(n_workers=2, placement="round_robin",
                                   page_cost=MODEL,
                                   shared_cache_bytes=CM.image_bytes // 2))
    assert r.cache_remote_hits == 0
    assert r.cache_misses > 0


def test_cluster_capacity_eviction_fires_callback():
    dropped = []
    cache = ClusterImageCache(capacity_bytes=100,
                              on_evict=dropped.append)
    cache.admit("a", 60, worker=0, now=1.0)
    cache.admit("b", 60, worker=1, now=2.0)            # evicts LRU 'a'
    assert dropped == ["a"] and not cache.holds("a")
    assert cache.evictions == 1 and cache.peak_bytes == 60


def test_fleet_shared_cache_second_worker_pays_remote_not_source():
    """Fetch-once: function 0's image starts on worker 0; a later cold start
    of a sharing function routed to worker 1 is a remote hit (network
    transfer), not a second source fetch — and its latency sits strictly
    between a local hit and a miss."""
    # two functions share image 0; round-robin forces fn 1 onto worker 1
    traces = [_trace(0, [10.0], image=0), _trace(1, [11.0], image=0)]
    r = simulate_fleet(traces, "warmswap", CM,
                       FleetConfig(n_workers=2, placement="round_robin",
                                   page_cost=MODEL))
    assert r.cache_local_hits == 1 and r.cache_remote_hits == 1
    assert r.cache_misses == 0                         # setup pre-fetched once
    lats = np.sort(r.latency_samples_s)
    local = MODEL.cold_latency_s("warmswap", "local")
    remote = MODEL.cold_latency_s("warmswap", "remote")
    miss = MODEL.cold_latency_s("warmswap", "miss")
    assert lats[0] == pytest.approx(local)
    assert lats[1] == pytest.approx(remote)
    assert local < remote < miss
    assert r.pages_transferred == MODEL.image_pages()  # only the remote hit


# ---------------------------------------------------------------------------------
# Bandwidth/residency-aware placement
# ---------------------------------------------------------------------------------

def test_place_invocation_start_cost_prefers_cheapest_transfer():
    cost = {0: 0.5, 1: 0.0, 2: 0.2}.__getitem__
    load = {0: 0, 1: 9, 2: 0}.__getitem__
    # cheapest transfer wins even against an idle worker...
    assert place_invocation([0, 1, 2], load=load, start_cost=cost) == 1
    # ...warm instances still beat everything...
    assert place_invocation([0, 1, 2], load=load, start_cost=cost,
                            has_warm=lambda w: w == 0) == 0
    # ...and equal costs fall back to load, then position
    flat = lambda w: 0.0  # noqa: E731
    assert place_invocation([0, 1, 2], load=load, start_cost=flat) == 0


def test_paged_affinity_placement_avoids_source_misses():
    """Bandwidth-aware affinity routes cold starts to workers whose pool (or
    the shared tier) already has the image, so it moves strictly fewer pages
    over the network than placement that ignores residency."""
    traces = generate_fleet_traces(12, horizon_min=24 * 60, seed=1,
                                   n_images=4, rate_model="zipf",
                                   total_rate_per_min=4.0)
    aff = simulate_fleet(traces, "warmswap", CM,
                         FleetConfig(n_workers=4, page_cost=MODEL,
                                     worker_capacity_bytes=CM.image_bytes))
    rr = simulate_fleet(traces, "warmswap", CM,
                        FleetConfig(n_workers=4, placement="round_robin",
                                    page_cost=MODEL,
                                    worker_capacity_bytes=CM.image_bytes))
    assert aff.pages_transferred < rr.pages_transferred
    assert aff.n_cold < rr.n_cold
    assert (aff.cache_remote_hits + aff.cache_misses
            < rr.cache_remote_hits + rr.cache_misses)


# ---------------------------------------------------------------------------------
# Byte-aware keep-alive
# ---------------------------------------------------------------------------------

def test_bytes_aware_keepalive_scales_with_image_bytes():
    pol = BytesAwareKeepAlive()                        # 230 MiB x 15 min budget
    assert pol.keep_alive_min(0, image_bytes=230 << 20) == pytest.approx(15.0)
    # tiny warmswap metadata idles far longer than a fat private snapshot
    assert (pol.keep_alive_min(0, image_bytes=3 << 20)
            > pol.keep_alive_min(0, image_bytes=2300 << 20))
    assert pol.keep_alive_min(0, image_bytes=None) == 15.0   # no size info
    assert pol.keep_alive_min(0, image_bytes=1) == pol.hi_min  # clamped


def test_predicted_cold_latency_is_a_pure_read():
    """Pricing a cold start must never build/revive the image (that would pay
    and pool-admit the very cost being estimated): with no live image the
    prediction uses the model's default size and the pool stays empty."""
    from repro.core.pool import DependencyManager
    from repro.core.registry import FunctionRegistry
    from repro.core.coldstart import ColdStartOrchestrator
    import tempfile

    mgr = DependencyManager()
    reg = FunctionRegistry(store_dir=tempfile.mkdtemp(prefix="costmodel-t-"))
    mgr.register_image("img", "arch", lambda: {"w": np.zeros((4,))},
                       build_now=False)
    reg.register("fn", "img", lambda: {}, lambda p, h, r, e: 0,
                 write_baseline_checkpoint=False)
    orch = ColdStartOrchestrator(mgr, reg)
    lat = orch.predicted_cold_latency_s("fn", MODEL, tier="remote")
    assert lat == MODEL.cold_latency_s("warmswap", tier="remote")
    assert not mgr.has_live("img")                     # nothing materialized
    assert mgr.live_image_bytes("img") is None
    assert mgr.stats.builds == 0


def test_bytes_policy_keeps_warmswap_warmer_than_prebaking():
    """Under the byte-minute budget, warmswap's cheap idle metadata earns a
    long window (fewer cold starts) while prebaking's snapshots get a short
    leash — the sharing advantage shows up in the keep-alive economics."""
    traces = [_trace(fn, np.arange(5.0 + fn, 2000.0, 30.0)) for fn in range(4)]
    ws = simulate_fleet(traces, "warmswap", CM,
                        FleetConfig(n_workers=2, prewarm="bytes"))
    pb = simulate_fleet(traces, "prebaking", CM,
                        FleetConfig(n_workers=2, prewarm="bytes"))
    assert ws.n_cold < pb.n_cold
