"""Model-level step functions: loss / train_step / prefill / serve_step.

These are the functions the launchers jit with explicit shardings, and the functions
the dry-run lowers for every (arch x shape) cell:

  * ``train_4k``    -> ``make_train_step(cfg)``   (fwd+bwd+AdamW)
  * ``prefill_32k`` -> ``make_prefill_step(cfg)`` (fwd, builds decode state)
  * ``decode_32k`` / ``long_500k`` -> ``make_serve_step(cfg)`` (one token + cache)

Cross-entropy is computed **chunked over the sequence** (re-materializing one logit
chunk (B, c, V/tp) at a time) so the full (B, S, V) fp32 logits never exist — with a
256k padded vocab that single tensor would otherwise dominate HBM.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import padded_vocab, unembed
from repro.models.transformer import decode_step, forward, init_decode_state, init_params
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


# ---------------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------------

def _ce_from_logits(logits: jax.Array, targets: jax.Array, vocab_size: int):
    """logits (B, C, Vp) fp32; targets (B, C) int32; returns (sum_ce, sum_zloss)."""
    vp = logits.shape[-1]
    if vp > vocab_size:  # mask padded vocab rows out of the softmax
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(vp) < vocab_size, logits, neg)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.sum(lse - gold)
    zloss = jnp.sum(jnp.square(lse))
    return ce, zloss


def chunked_cross_entropy(
    embed_params: dict,
    feats: jax.Array,        # (B, S, D) post-final-norm features
    targets: jax.Array,      # (B, S) int32
    cfg: ArchConfig,
    *,
    chunk: int = 512,
    z_loss_coef: float = 1e-4,
) -> jax.Array:
    B, S, D = feats.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        feats = jnp.pad(feats, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    n = feats.shape[1] // C
    fc = feats.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, C).transpose(1, 0, 2)
    mask = (jnp.arange(n * C).reshape(n, C)[:, None, :] < S)  # (n, 1, C) valid positions

    @jax.checkpoint
    def chunk_loss(args):
        f, t, m = args
        logits = unembed(embed_params, f, cfg)
        vp = logits.shape[-1]
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, neg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        ce = jnp.sum((lse - gold) * m)
        zl = jnp.sum(jnp.square(lse) * m)
        return ce + z_loss_coef * zl

    losses = jax.lax.map(chunk_loss, (fc, tc, mask.astype(jnp.float32)))
    return jnp.sum(losses) / (B * S)


# ---------------------------------------------------------------------------------
# Batch plumbing (modality frontends are stubs per the assignment)
# ---------------------------------------------------------------------------------

def frontend_embeds_from_batch(batch: Dict[str, jax.Array], cfg: ArchConfig):
    if cfg.frontend == "audio_frames":
        return batch["frames"]
    if cfg.frontend == "vision_patches":
        return batch["patches"]
    return None


def loss_fn(
    params,
    batch: Dict[str, jax.Array],
    cfg: ArchConfig,
    *,
    remat: str = "unit",
    q_chunk: int = 512,
    rec_chunk: int = 256,
    ce_chunk: int = 512,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    fe = frontend_embeds_from_batch(batch, cfg)
    feats, aux, _ = forward(
        params, tokens, cfg, frontend_embeds=fe, make_state=False,
        remat=remat, q_chunk=q_chunk, rec_chunk=rec_chunk, return_features=True)
    n_front = 0 if (cfg.is_encoder_decoder or fe is None) else fe.shape[1]
    if n_front > 0:
        # feats index F-1+i predicts token i
        pred = feats[:, n_front - 1:-1]
        targets = tokens
    else:
        pred = feats[:, :-1]
        targets = tokens[:, 1:]
    ce = chunked_cross_entropy(params["embed"], pred, targets, cfg, chunk=ce_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------------

def make_train_step(
    cfg: ArchConfig,
    *,
    adamw: AdamWConfig = AdamWConfig(),
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    remat: str = "unit",
    q_chunk: int = 512,
    rec_chunk: int = 256,
) -> Callable:
    def train_step(params, opt_state, batch, step):
        (loss, parts), grads = jax.value_and_grad(
            partial(loss_fn, cfg=cfg, remat=remat, q_chunk=q_chunk, rec_chunk=rec_chunk),
            has_aux=True)(params, batch)
        lr = cosine_schedule(step, peak_lr=peak_lr, warmup_steps=warmup_steps,
                             total_steps=total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params, lr, adamw)
        metrics = {"loss": loss, "lr": lr, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, state_len: Optional[int] = None,
                      q_chunk: int = 512, rec_chunk: int = 256) -> Callable:
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        fe = frontend_embeds_from_batch(batch, cfg)
        logits, _, state = forward(
            params, tokens, cfg, frontend_embeds=fe, make_state=True,
            state_len=state_len,
            remat="none", q_chunk=q_chunk, rec_chunk=rec_chunk, logits_slice=1)
        next_token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, state

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    def serve_step(params, state, token):
        """token: (B, 1) int32 -> (next_token (B,), new_state)."""
        logits, new_state = decode_step(params, state, token, cfg)
        next_token = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, new_state

    return serve_step


def make_serve_step_with_logits(cfg: ArchConfig) -> Callable:
    def serve_step(params, state, token):
        logits, new_state = decode_step(params, state, token, cfg)
        return logits[:, : cfg.vocab_size], new_state

    return serve_step


__all__ = [
    "loss_fn", "chunked_cross_entropy", "make_train_step", "make_prefill_step",
    "make_serve_step", "make_serve_step_with_logits", "init_params",
    "init_decode_state", "frontend_embeds_from_batch", "padded_vocab",
]
