"""Quickstart: the WarmSwap loop in ~60 lines.

1. Provider registers a live dependency image (base model, pre-initialized once).
2. Two tenants register endpoints that share it.
3. Cold starts: Baseline (load + compile from scratch) vs WarmSwap (live migration).
4. The same comparison as a declarative scenario: one serializable spec, one
   ``run()`` (the fleet-scale API — see docs/API.md).

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
import zlib

from repro.core import (
    ColdStartConfig,
    ColdStartOrchestrator,
    DependencyManager,
    FunctionRegistry,
    RestorePolicy,
)
from repro.core import workloads as wl


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="warmswap-quickstart-")
    manager = DependencyManager(disk_dir=f"{tmp}/pool")
    registry = FunctionRegistry(store_dir=f"{tmp}/store")

    # --- provider setup phase (paper Fig. 4b): build the shared image ONCE -------
    image_id = "model-small"
    builder = wl.model_params_builder(image_id)
    executables = wl.make_model_executables(image_id)
    wl.warm_executables(executables, builder(), image_id)   # pre-compile
    manager.register_image(image_id, image_id, builder, executables=executables)
    print(f"pool: {manager.summary()['live_images']} "
          f"({manager.pool_bytes()/1e6:.1f} MB live)")

    # --- tenants: same dependency, private handlers -------------------------------
    w = wl.WORKLOADS["cnn_serving"]
    for tenant in ("tenant-a", "tenant-b"):
        registry.register(tenant, image_id,
                          wl._head_builder(image_id,
                                           seed=zlib.crc32(tenant.encode()) % 100),
                          w.handler_fn, base_params_builder=builder,
                          write_baseline_checkpoint=True)

    orch = ColdStartOrchestrator(manager, registry,
                                 ColdStartConfig(policy=RestorePolicy.BULK))

    # --- runtime phase (paper Fig. 4c): cold starts -------------------------------
    for tenant in ("tenant-a", "tenant-b"):
        inst_b, tb = orch.cold_start_baseline(tenant)
        inst_w, tw = orch.cold_start_warmswap(tenant)
        req = w.request_builder()
        out_b, _ = inst_b.invoke(req)
        out_w, _ = inst_w.invoke(req)
        assert (out_b == out_w).all(), "migrated instance must match baseline"
        print(f"{tenant}: baseline {tb.total:.3f}s "
              f"(load {tb.dependency_load:.3f}s + compile {tb.dependency_compile:.3f}s)"
              f" | warmswap {tw.total:.3f}s (comm {tw.communication*1e3:.1f}ms + "
              f"migrate {tw.migration*1e3:.1f}ms) -> x{tb.total/tw.total:.1f}")
    print(f"image initialized {manager.stats.builds} time(s) for "
          f"{len(registry.list())} tenants")


def scenario_quickstart() -> None:
    """The scenario API in 10 lines: declare the paper's Fig. 7 comparison as
    data, run it, read the headline."""
    from repro.core import Scenario, run

    spec = Scenario(
        name="quickstart",
        engine="single",                  # the paper-faithful Fig. 7 model
        traces={"name": "azure",          # registry key + kwargs
                "kwargs": {"n_functions": 10, "horizon_min": 24 * 60}},
        cost="paper_table2",              # the paper's measured Table 2 costs
    )
    result = run(Scenario.from_json(spec.to_json()))   # specs round-trip JSON
    print(f"scenario '{spec.name}': warmswap saves "
          f"{result.summary['memory_saving_vs_prebaking'] * 100:.0f} % memory "
          f"vs prebaking (paper: 88 %)")


if __name__ == "__main__":
    main()
    scenario_quickstart()
