"""contract checker: docs/SIMULATION.md + docs/API.md cross-validated against
the code. The shipped tree must be clean, and every mutation (rank flip,
dropped doc entry, phantom field) must be caught — proven by pointing the
monkeypatchable ``*_PATH`` constants at deliberately-broken copies."""
import textwrap

from tools.analysis import contract
from tools.analysis.__main__ import main

EVENTS_FIXTURE = textwrap.dedent("""
    class EventKind(IntEnum):
        INSTANCE_FREE = 0
        PREWARM_SPAWN = 1
        ARRIVAL = 2
        KEEPALIVE_EXPIRY = 3
        WORKER_FAIL = 4
        WORKER_RECOVER = 5
        CACHE_FLUSH = 6
""")


def rules(findings):
    return sorted(f"{f.checker}/{f.rule}" for f in findings)


def test_shipped_tree_is_clean():
    assert contract.check_repo() == []


def test_rank_flip_is_caught(tmp_path, monkeypatch):
    mutated = EVENTS_FIXTURE.replace("KEEPALIVE_EXPIRY = 3",
                                     "KEEPALIVE_EXPIRY = 9")
    p = tmp_path / "events.py"
    p.write_text(mutated)
    monkeypatch.setattr(contract, "EVENTS_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "rank-mismatch"
               and "KEEPALIVE_EXPIRY" in f.message for f in fs)


def test_rank_flip_fails_the_cli(tmp_path, monkeypatch, capsys):
    mutated = EVENTS_FIXTURE.replace("INSTANCE_FREE = 0",
                                     "INSTANCE_FREE = 8")
    p = tmp_path / "events.py"
    p.write_text(mutated)
    monkeypatch.setattr(contract, "EVENTS_PATH", str(p))
    clean = tmp_path / "clean.py"
    clean.write_text("x = 1\n")
    assert main([str(clean), "--no-baseline"]) == 1
    assert "contract/rank-mismatch" in capsys.readouterr().out


def test_new_enum_member_must_be_documented(tmp_path, monkeypatch):
    mutated = EVENTS_FIXTURE + "    NETWORK_PARTITION = 7\n"
    p = tmp_path / "events.py"
    p.write_text(mutated)
    monkeypatch.setattr(contract, "EVENTS_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "undocumented-kind"
               and "NETWORK_PARTITION" in f.message for f in fs)


def test_doc_only_kind_is_caught(tmp_path, monkeypatch):
    doc = textwrap.dedent("""
        ## Event heap tie-break order (`core/events.py`)

          1. `INSTANCE_FREE` (0)
          2. `PREWARM_SPAWN` (1)
          3. *arrivals* (2)
          4. `KEEPALIVE_EXPIRY` (3)
          5. `WORKER_FAIL` (4), `WORKER_RECOVER` (5), `CACHE_FLUSH` (6)
          6. `PHANTOM_KIND` (7)

        ## Next section
    """)
    p = tmp_path / "SIMULATION.md"
    p.write_text(doc)
    monkeypatch.setattr(contract, "DOC_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "unknown-event-kind"
               and "PHANTOM_KIND" in f.message for f in fs)


def test_missing_tiebreak_table_is_caught(tmp_path, monkeypatch):
    p = tmp_path / "SIMULATION.md"
    p.write_text("# nothing here\n")
    monkeypatch.setattr(contract, "DOC_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "unknown-event-kind"
               and "tie-break" in f.message for f in fs)


def test_unknown_disruption_kind_is_caught(tmp_path, monkeypatch):
    p = tmp_path / "disruption.py"
    p.write_text('EVENT_KINDS = ("worker_fail", "meteor_strike")\n')
    monkeypatch.setattr(contract, "DISRUPTION_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "disruption-kind"
               and "meteor_strike" in f.message for f in fs)


def test_undocumented_result_field_is_caught(tmp_path, monkeypatch):
    with open(contract.API_PATH) as f:
        api = f.read()
    assert "`requeued`" in api
    p = tmp_path / "API.md"
    p.write_text(api.replace("`requeued`", "requeued"))
    monkeypatch.setattr(contract, "API_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "undocumented-field"
               and "requeued" in f.message for f in fs)


def test_phantom_doc_field_is_caught(tmp_path, monkeypatch):
    with open(contract.API_PATH) as f:
        api = f.read()
    p = tmp_path / "API.md"
    p.write_text(api.replace("`n_cold`", "`n_cold`, `bogus_field`"))
    monkeypatch.setattr(contract, "API_PATH", str(p))
    fs = contract.check_repo()
    assert any(f.rule == "unknown-field"
               and "bogus_field" in f.message for f in fs)


def test_missing_methods_row_is_caught(tmp_path, monkeypatch):
    p = tmp_path / "API.md"
    p.write_text("# no table\n")
    monkeypatch.setattr(contract, "API_PATH", str(p))
    fs = contract.check_repo()
    assert rules(fs) == ["contract/unknown-field"]
